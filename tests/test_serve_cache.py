"""Two-tier mapping cache (`repro.serve.cache`): hit tiers, LRU
eviction, key separation, negative short-circuit, and the
validator-replay-on-hit invariant."""

import dataclasses

from repro.core import CGRAConfig, make_cnkm, permute_dfg
from repro.core.bandmap import map_dfg
from repro.serve import MappingCache, canonical_form

CGRA = CGRAConfig()

# C5K5 BusMap capped at II = 2: every (II, jitter) combination is
# certified unbindable (the PR 2 straggler), so `map_dfg` fails fast
# with certificates attached — the canonical negative-entry case.
INFEASIBLE_OPTS = {"mode": "busmap", "max_ii": 2}


def _map_and_store(cache, dfg, cgra=CGRA, options=None, seed=0):
    options = options or {}
    res = map_dfg(dfg, cgra, seed=seed, **options)
    canon = canonical_form(dfg)
    cache.store(canon, cgra, options, res)
    return res, canon


def test_memory_hit_is_replayed_and_validated():
    cache = MappingCache()
    _map_and_store(cache, make_cnkm(3, 6))
    perm = permute_dfg(make_cnkm(3, 6), seed=4)
    hit = cache.lookup(canonical_form(perm), CGRA, {})
    assert hit is not None and hit.source == "memory"
    assert not hit.negative
    assert hit.result.ok and hit.result.report is not None
    assert hit.result.report.ok            # validator-accepted
    assert set(perm.ops) <= set(hit.result.sched.dfg.ops)
    assert cache.stats.mem_hits == 1 and cache.stats.replay_rejects == 0
    assert cache.stats.replay_wall_s > 0   # the replay actually ran


def test_miss_on_unknown_graph_and_on_different_options():
    cache = MappingCache()
    _map_and_store(cache, make_cnkm(2, 4))
    assert cache.lookup(canonical_form(make_cnkm(2, 6)), CGRA, {}) is None
    # Same DFG, different map_dfg knobs -> different key.
    assert cache.lookup(canonical_form(make_cnkm(2, 4)), CGRA,
                        {"mode": "busmap"}) is None
    assert cache.stats.misses == 2


def test_no_reuse_across_cgra_configs():
    cache = MappingCache()
    _map_and_store(cache, make_cnkm(2, 4))
    assert cache.lookup(canonical_form(make_cnkm(2, 4)),
                        CGRAConfig(rows=8, cols=8), {}) is None


def test_disk_tier_survives_a_fresh_cache(tmp_path):
    art = str(tmp_path / "serve")
    cache1 = MappingCache(art_dir=art)
    _map_and_store(cache1, make_cnkm(2, 6))
    # Fresh in-memory state, same artifact dir (a restarted service).
    cache2 = MappingCache(art_dir=art)
    canon = canonical_form(permute_dfg(make_cnkm(2, 6), seed=9))
    hit = cache2.lookup(canon, CGRA, {})
    assert hit is not None and hit.source == "disk"
    assert hit.result.ok
    # Promoted to memory: second lookup is a memory hit.
    assert cache2.lookup(canon, CGRA, {}).source == "memory"


def test_negative_result_short_circuits(tmp_path):
    cache = MappingCache(art_dir=str(tmp_path / "serve"))
    bad = make_cnkm(5, 5)
    res, _ = _map_and_store(cache, bad, options=INFEASIBLE_OPTS)
    assert not res.ok and res.certificates
    hit = cache.lookup(canonical_form(permute_dfg(bad, seed=2)), CGRA,
                       INFEASIBLE_OPTS)
    assert hit is not None and hit.negative
    assert not hit.result.ok
    assert len(hit.result.certificates) == len(res.certificates)
    assert cache.stats.neg_hits == 1


def test_heuristic_failure_is_not_cached_negative():
    """An ok=False produced by budget exhaustion under one seed is not a
    proof — caching it would mask feasible mappings under other seeds.
    Only certificate-backed failures (attempts == 0) become negative
    entries."""
    cache = MappingCache()
    opts = {"mode": "busmap", "max_ii": 2, "certify": False,
            "bus_pressure": False, "mis_restarts": 1, "mis_iters": 40}
    res, canon = _map_and_store(cache, make_cnkm(5, 5), options=opts)
    assert not res.ok and res.attempts > 0     # heuristic, not certified
    assert cache.stats.puts == 0 and cache.stats.neg_uncacheable == 1
    assert cache.lookup(canon, CGRA, opts) is None


def test_race_unsat_proof_is_cached_negative():
    """A race-produced UNSAT (``proved_infeasible``) is admissible even
    though the losing portfolio spent validation attempts in parallel —
    the admission rule is "is it a proof", not "did a search run"."""
    cache = MappingCache()
    opts = dict(INFEASIBLE_OPTS, backend="race", certify=False)
    bad = make_cnkm(5, 5)
    res, _ = _map_and_store(cache, bad, options=opts)
    assert not res.ok and res.proved_infeasible
    assert res.backend == "race:exact"
    assert cache.stats.puts == 1
    hit = cache.lookup(canonical_form(permute_dfg(bad, seed=6)), CGRA,
                       opts)
    assert hit is not None and hit.negative
    assert hit.result.proved_infeasible
    assert cache.stats.neg_hits == 1


def test_admission_is_keyed_on_the_proof_flag():
    """Synthesized boundary cases around the store() guard: attempts
    spent + proof flag is admitted, attempts spent without the flag
    (the racing portfolio's budget exhaustion) is refused."""
    cache = MappingCache()
    base = map_dfg(make_cnkm(5, 5), CGRA, **INFEASIBLE_OPTS)
    assert not base.ok and base.proved_infeasible
    canon = canonical_form(make_cnkm(5, 5))
    proof = dataclasses.replace(base, attempts=17)
    assert cache.store(canon, CGRA, {"seed": 1}, proof) is not None
    unsound = dataclasses.replace(base, attempts=17,
                                  proved_infeasible=False)
    assert cache.store(canon, CGRA, {"seed": 2}, unsound) is None
    assert cache.stats.neg_uncacheable == 1


def test_lru_eviction_bounds_memory_not_disk(tmp_path):
    art = str(tmp_path / "serve")
    cache = MappingCache(capacity=2, art_dir=art)
    kernels = [make_cnkm(1, 2), make_cnkm(2, 4), make_cnkm(2, 6)]
    for k in kernels:
        _map_and_store(cache, k)
    assert len(cache) == 2 and cache.stats.evictions == 1
    # The memory-evicted first entry is still served from disk.
    hit = cache.lookup(canonical_form(make_cnkm(1, 2)), CGRA, {})
    assert hit is not None and hit.source == "disk"


def test_blob_mismatch_is_never_reused():
    cache = MappingCache()
    _, canon = _map_and_store(cache, make_cnkm(2, 4))
    key = cache.key(canon, CGRA, {})
    # Simulate a digest collision: entry bytes claim a different graph.
    cache._mem[key] = dataclasses.replace(cache._mem[key],
                                          blob=b"not-this-graph")
    assert cache.lookup(canon, CGRA, {}) is None
    assert cache.stats.blob_mismatches == 1


def test_replay_rejection_evicts_and_reports_miss(tmp_path):
    cache = MappingCache(art_dir=str(tmp_path / "serve"))
    _, canon = _map_and_store(cache, make_cnkm(2, 4))
    key = cache.key(canon, CGRA, {})
    entry = cache._mem[key]
    # Corrupt the stored binding: two ops on one PE instance.
    placement = dict(entry.result.placement)
    quads = [o for o, v in placement.items() if v.kind == "quad"]
    a, b = quads[0], quads[1]
    placement[b] = dataclasses.replace(placement[a], op=b)
    cache._mem[key] = dataclasses.replace(
        entry, result=dataclasses.replace(entry.result,
                                          placement=placement))
    assert cache.lookup(canon, CGRA, {}) is None
    assert cache.stats.replay_rejects == 1
    assert key not in cache._mem          # evicted from both tiers
    assert cache.lookup(canon, CGRA, {}) is None  # disk copy gone too
