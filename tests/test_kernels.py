"""Per-kernel interpret-mode validation against the pure-jnp oracles:
shape/dtype sweeps with assert_allclose (flash attention, SSD scan,
conflict matrix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import segsum, ssd_chunked, ssd_step


# ------------------------------------------------------ flash attention
FA_CASES = [
    # b, sq, sk, hq, hkv, d, window, q_offset
    (2, 128, 128, 4, 2, 64, None, 0),       # GQA causal
    (1, 256, 256, 4, 4, 32, None, 0),       # MHA
    (2, 128, 384, 4, 1, 64, None, 256),     # decode-extend vs long cache
    (1, 256, 256, 8, 2, 64, 100, 0),        # sliding window
    (1, 64, 64, 2, 2, 128, 16, 0),          # small window
    (1, 1, 512, 4, 2, 64, None, 511),       # single-token decode
]


# One representative (case, dtype) combination stays in the fast tier-1
# run; the full interpret-mode sweep is `slow` (several minutes of CPU).
def _sweep(cases, fast_idx=(0,)):
    return [c if i in fast_idx else pytest.param(c, marks=pytest.mark.slow)
            for i, c in enumerate(cases)]


@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16,
                                                marks=pytest.mark.slow)])
@pytest.mark.parametrize("case", _sweep(FA_CASES))
def test_flash_attention_matches_ref(case, dtype):
    b, sq, sk, hq, hkv, d, win, off = case
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), dtype)
    out = flash_attention_pallas(q, k, v, q_offset=off, window=win,
                                 block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, q_offset=off, window=win)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_ref_matches_dense_sdpa():
    """The chunked online-softmax oracle equals dense masked attention."""
    from repro.models.attention import causal_window_mask, sdpa
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, hq, hkv, d = 2, 96, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.arange(s)
    mask = causal_window_mask(pos, pos, None)[None, None]
    ref = sdpa(q, k, v, mask)
    out = flash_attention_ref(q, k, v, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ------------------------------------------------------------------ SSD
SSD_CASES = [
    # B, S, H, P, N, chunk, head_block
    (2, 64, 4, 16, 32, 16, 2),
    (1, 128, 8, 32, 64, 32, 4),
    (2, 128, 4, 64, 128, 64, 4),
]


def _ssd_inputs(case, dtype=jnp.float32):
    b, s, h, p, n, chunk, hb = case
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    a_log = (jax.random.normal(ks[2], (h,)) * 0.3).astype(jnp.float32)
    bb = jax.random.normal(ks[3], (b, s, 1, n), dtype)
    cc = jax.random.normal(ks[4], (b, s, 1, n), dtype)
    return x, dt, a_log, bb, cc


@pytest.mark.parametrize("case", _sweep(SSD_CASES))
def test_ssd_pallas_matches_ref(case):
    x, dt, a_log, b, c = _ssd_inputs(case)
    chunk, hb = case[5], case[6]
    y1, f1 = ssd_pallas(x, dt, a_log, b, c, chunk=chunk, head_block=hb,
                        interpret=True)
    y2, f2 = ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4)


@pytest.mark.slow
def test_ssd_chunked_matches_recurrence():
    """Chunked scan == naive token-by-token recurrence, any chunking."""
    x, dt, a_log, b, c = _ssd_inputs((2, 32, 4, 8, 16, 8, 2))
    state = jnp.zeros((2, 4, 8, 16))
    ys = []
    for t in range(32):
        y_t, state = ssd_step(state, x[:, t], dt[:, t], a_log,
                              b[:, t], c[:, t])
        ys.append(y_t)
    y_naive = jnp.stack(ys, axis=1)
    for chunk in (4, 8, 16, 32):
        y_c, fin = ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_naive),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(fin), np.asarray(state),
                                   atol=2e-5)


def test_segsum():
    la = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    ss = segsum(la)
    assert float(ss[2, 0]) == pytest.approx(0.5, abs=1e-6)   # 0.2+0.3
    assert float(ss[3, 3]) == pytest.approx(0.0)
    assert np.isneginf(np.asarray(ss)[0, 1])


# ------------------------------------------------------ conflict matrix
def test_conflict_matrix_pallas_sweep():
    from repro.core import make_cnkm, schedule_dfg
    from repro.core.cgra import CGRAConfig
    from repro.core.conflict import build_conflict_graph
    from repro.kernels.conflict_matrix.kernel import conflict_matrix_pallas
    from repro.kernels.conflict_matrix.ref import (conflict_matrix_ref,
                                                   encode)
    for (n, m, blk) in [(2, 4, 32), (2, 6, 64), (4, 4, 128)]:
        sched = schedule_dfg(make_cnkm(n, m), CGRAConfig())
        cg = build_conflict_graph(sched, CGRAConfig())
        feat = encode(cg.vertices)
        ref = conflict_matrix_ref(feat)
        out = np.asarray(conflict_matrix_pallas(
            jnp.asarray(feat), block=blk, interpret=True)).astype(bool)
        assert (out == ref).all()


def test_conflict_matrix_packed_matches_bitset_rows():
    """Packed-word kernel variant: uint32 tiles viewed as uint64 rows
    must equal `pack_bool_rows` of the dense-bool oracle, and the
    `build_conflict_graph(use_kernel="packed")` path must reproduce the
    engine's bitset rows byte-for-byte."""
    import numpy as onp

    from repro.core import make_cnkm, schedule_dfg
    from repro.core.bitset import n_words, pack_bool_rows
    from repro.core.cgra import CGRAConfig
    from repro.core.conflict import build_conflict_graph
    from repro.kernels.conflict_matrix.kernel import \
        conflict_matrix_packed_pallas
    from repro.kernels.conflict_matrix.ops import conflict_matrix_packed
    from repro.kernels.conflict_matrix.ref import (conflict_matrix_ref,
                                                   encode)
    for (n, m, bi, bj) in [(2, 4, 32, 64), (2, 6, 64, 128),
                           (4, 4, 128, 256)]:
        sched = schedule_dfg(make_cnkm(n, m), CGRAConfig())
        cg = build_conflict_graph(sched, CGRAConfig())
        feat = encode(cg.vertices)
        ref_rows = pack_bool_rows(conflict_matrix_ref(feat))
        w32 = onp.ascontiguousarray(onp.asarray(conflict_matrix_packed_pallas(
            jnp.asarray(feat), block_i=bi, block_j=bj, interpret=True)))
        rows = w32.view(onp.uint64)[:, :n_words(len(cg.vertices))]
        assert (rows == ref_rows).all()
        # host path (no pallas) packs the oracle
        assert (conflict_matrix_packed(cg.vertices) == ref_rows).all()


def test_conflict_matrix_packed_feeds_bitset_graph():
    from repro.core import make_cnkm, schedule_dfg
    from repro.core.cgra import CGRAConfig
    from repro.core.conflict import build_conflict_graph
    sched = schedule_dfg(make_cnkm(2, 6), CGRAConfig())
    ref = build_conflict_graph(sched, CGRAConfig())
    packed = build_conflict_graph(sched, CGRAConfig(), use_kernel="packed")
    assert (packed.bits.rows == ref.bits.rows).all()
    assert packed.n_edges == ref.n_edges
