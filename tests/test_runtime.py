"""Fault tolerance, elastic re-mesh, straggler mitigation, checkpointing,
and the data pipeline's determinism contract."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.data import DataConfig, make_pipeline
from repro.runtime import (FailureInjector, StragglerMitigator,
                           degraded_mesh_shape, plan_elastic_restart,
                           run_with_recovery)
from repro.runtime.fault import HeartbeatMonitor, SimulatedFailure


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_stateless():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=3)
    p1, p2 = make_pipeline(cfg), make_pipeline(cfg)
    b1 = p1.batch(7)
    b2 = p2.batch(7)            # fresh pipeline, same step -> same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_host_slicing_partitions_batch():
    cfg = DataConfig(vocab=128, seq_len=8, global_batch=6, seed=0)
    p = make_pipeline(cfg)
    full = p.batch(0)["tokens"]
    parts = [p.batch(0, host_slice=slice(i, i + 2))["tokens"]
             for i in (0, 2, 4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_labels_shift():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=2, seed=1)
    b = make_pipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = {"w": jnp.arange(6.0).reshape(2, 3),
             "opt": {"mu": jnp.ones((4,)), "count": jnp.asarray(3)}}
    save_checkpoint(str(tmp_path), state, 42)
    restored, manifest = load_checkpoint(str(tmp_path), state)
    assert manifest["step"] == 42
    np.testing.assert_array_equal(restored["w"], state["w"])
    np.testing.assert_array_equal(restored["opt"]["mu"],
                                  state["opt"]["mu"])


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), {"w": jnp.ones((2, 3))}, 1)
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"w": jnp.ones((3, 3))})


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    for step in range(1, 6):
        mgr.maybe_save({"x": jnp.asarray(step)}, step)
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000004", "step_00000005"]


# -------------------------------------------------------- fault recovery
def _toy_loop(tmp_path, fail_at, n_steps=10, every=2):
    """Counting 'trainer': state = sum of batch means (deterministic)."""
    cfg = DataConfig(vocab=64, seq_len=4, global_batch=2, seed=0)
    data = make_pipeline(cfg)

    def train_step(state, batch):
        s = state + float(batch["tokens"].mean())
        return s, {"loss": jnp.asarray(s)}

    mgr = CheckpointManager(str(tmp_path), every=every)
    inj = FailureInjector({fail_at: (1, "host_down")}) \
        if fail_at is not None else None
    return run_with_recovery(
        train_step=train_step, init_state=jnp.asarray(0.0), data=data,
        ckpt_manager=mgr, n_steps=n_steps, injector=inj)


def test_recovery_reaches_same_final_state(tmp_path):
    ref_state, _, r0 = _toy_loop(tmp_path / "a", None)
    state, _, r1 = _toy_loop(tmp_path / "b", 5)
    assert r0 == 0 and r1 == 1
    # deterministic replay -> identical final state despite the failure
    np.testing.assert_allclose(float(state), float(ref_state), rtol=1e-6)


def test_recovery_bounded_loss(tmp_path):
    """A failure never loses more than ckpt_every steps of work."""
    _, history, restarts = _toy_loop(tmp_path, 7, n_steps=10, every=2)
    assert restarts == 1
    # replayed at most ckpt_every steps: total records <= 10 + 2
    assert len(history) <= 12


def test_max_restarts_exceeded(tmp_path):
    cfg = DataConfig(vocab=64, seq_len=4, global_batch=2, seed=0)
    data = make_pipeline(cfg)
    inj = FailureInjector({i: (0, "flaky") for i in range(100)})
    inj.fired = set()

    def always_fail_check(step):
        raise SimulatedFailure(step, 0)
    inj.check = always_fail_check
    mgr = CheckpointManager(str(tmp_path), every=1)
    with pytest.raises(SimulatedFailure):
        run_with_recovery(train_step=lambda s, b: (s, {}),
                          init_state=jnp.asarray(0.0), data=data,
                          ckpt_manager=mgr, n_steps=3, injector=inj,
                          max_restarts=2)


def test_heartbeat_monitor():
    mon = HeartbeatMonitor(4, timeout_s=10)
    for h in range(4):
        mon.beat(h, 0, t=100.0)
    mon.beat(2, 1, t=105.0)
    assert mon.dead_hosts(now=112.0) == [0, 1, 3]


# ---------------------------------------------------------------- elastic
def test_degraded_mesh_drops_pod_first():
    shape = {"pod": 2, "data": 16, "model": 16}
    out = degraded_mesh_shape(shape, n_failed_hosts=4, chips_per_host=64)
    assert out == {"pod": 1, "data": 16, "model": 16}


def test_degraded_mesh_then_data():
    shape = {"data": 16, "model": 16}
    out = degraded_mesh_shape(shape, n_failed_hosts=1, chips_per_host=16)
    assert out == {"data": 15, "model": 16}
    with pytest.raises(ValueError):
        degraded_mesh_shape({"data": 1, "model": 4}, 1, 16)


def test_elastic_restart_plan_adjusts_batch():
    new_shape, new_batch, notes = plan_elastic_restart(
        None, "train", 4096, 256, {"pod": 2, "data": 16, "model": 16},
        n_failed_hosts=4, chips_per_host=64)
    assert new_shape["pod"] == 1
    assert new_batch == 256           # 256 % 16 == 0 still
    new_shape, new_batch, _ = plan_elastic_restart(
        None, "train", 4096, 250, {"data": 16, "model": 16},
        n_failed_hosts=1, chips_per_host=16)
    assert new_batch % new_shape["data"] == 0


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Checkpoints are mesh-agnostic: save sharded-ish state, restore onto
    a different (1-device) sharding layout."""
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), state, 5)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_checkpoint(str(tmp_path), state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])


# --------------------------------------------------------------- straggler
def test_straggler_rebalances_rows():
    mit = StragglerMitigator(4, 16)
    for _ in range(5):
        for h, t in enumerate([1.0, 1.0, 1.0, 2.0]):   # host 3 slow
            mit.observe(h, t)
        rows = mit.rebalance()
    assert sum(rows) == 16
    assert rows[3] < 4              # slow host shed work
    assert max(rows) > 4            # a fast host absorbed it


def test_straggler_exclusion_after_patience():
    mit = StragglerMitigator(3, 6, exclude_ratio=1.5, patience=2)
    for _ in range(3):
        mit.observe(0, 1.0)
        mit.observe(1, 1.0)
        mit.observe(2, 3.0)
        mit.rebalance()
    assert mit.to_exclude() == [2]


@given(st.lists(st.floats(0.5, 4.0), min_size=2, max_size=8),
       st.integers(8, 64))
@settings(max_examples=30, deadline=None)
def test_straggler_conserves_global_batch(times, batch):
    mit = StragglerMitigator(len(times), batch)
    for _ in range(4):
        for h, t in enumerate(times):
            mit.observe(h, t)
        rows = mit.rebalance()
        assert sum(rows) == batch
        assert all(r >= 1 for r in rows)
    slices = mit.host_slices()
    assert slices[-1].stop == batch
