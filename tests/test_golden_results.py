"""Golden table pinning the paper's headline comparison: (II, routing-PE)
per CnKm kernel in both BandMap and BusMap modes, under the default
`map_dfg` parameters (seed 0).  These values were produced by the seed
(dense) engine and reproduced bit-for-bit by the bitset/portfolio engine;
any future engine change that shifts them must be deliberate.

Since the exact backend (`repro.exact`) landed, every golden II is also
**proven optimal** within the engine's schedule family:
`test_golden_iis_are_proven_optimal` re-derives the whole table with
the complete prover, so a golden II is no longer just "what the
portfolio found under seed 0" but the best any seed could ever find.

The two BusMap stragglers (C2K8, C5K5) burn most of their wall time
proving II=MII infeasible, so they run under ``-m slow``.
"""

import pytest

from repro.core import cnkm_name, make_cnkm, map_dfg
from repro.core.cgra import CGRAConfig

# (n, m, mode) -> (II, routing PEs); every mapping must validate (ok).
GOLDEN = {
    (1, 2, "bandmap"): (1, 0),
    (1, 2, "busmap"): (1, 0),
    (2, 4, "bandmap"): (1, 0),
    (2, 4, "busmap"): (1, 0),
    (2, 6, "bandmap"): (2, 0),
    (2, 6, "busmap"): (2, 2),
    (3, 6, "bandmap"): (2, 0),
    (3, 6, "busmap"): (2, 3),
    (4, 4, "bandmap"): (1, 0),
    (4, 4, "busmap"): (1, 0),
    (2, 8, "bandmap"): (2, 0),
    (2, 8, "busmap"): (3, 4),
    (5, 5, "bandmap"): (3, 0),
    (5, 5, "busmap"): (3, 5),
}

SLOW = {(2, 8, "busmap"), (5, 5, "busmap")}

CASES = [pytest.param(*case, marks=pytest.mark.slow)
         if case in SLOW else case for case in GOLDEN]


@pytest.mark.parametrize("n,m,mode", CASES)
def test_golden_ii_and_routing(n, m, mode):
    r = map_dfg(make_cnkm(n, m), CGRAConfig(), mode=mode)
    assert r.ok, f"{cnkm_name(n, m)}:{mode} failed: {r.summary()}"
    assert (r.ii, r.n_routing_pes) == GOLDEN[(n, m, mode)], r.summary()
    assert r.mis_size == r.n_ops


@pytest.mark.parametrize("n,m,mode", CASES)
def test_golden_iis_are_proven_optimal(n, m, mode):
    """The exact prover terminates in budget on every golden case and
    certifies the golden II as engine-optimal: lower IIs are
    certificate-UNSAT (or unschedulable), this one validates."""
    r = map_dfg(make_cnkm(n, m), CGRAConfig(), mode=mode,
                backend="exact")
    assert r.ok and r.optimal, r.summary()
    assert r.ii == GOLDEN[(n, m, mode)][0], r.summary()


def test_golden_bandmap_beats_busmap():
    """The paper's §IV-B claims hold across the golden table: BandMap II
    <= BusMap II and routing PEs strictly fewer whenever RD > M."""
    for (n, m) in {(n, m) for (n, m, _) in GOLDEN}:
        b_ii, b_rt = GOLDEN[(n, m, "bandmap")]
        u_ii, u_rt = GOLDEN[(n, m, "busmap")]
        assert b_ii <= u_ii
        assert b_rt <= u_rt
        if m > 4:
            assert b_rt < u_rt
