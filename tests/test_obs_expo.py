"""Serve exposition: Prometheus text-format round-trip, the pinned
access-log JSONL schema (+ digest redaction), and deterministic
head-sampling."""

import json
import threading

import pytest

from repro.obs import (ACCESS_LOG_FIELDS, AccessLog, MetricsRegistry,
                       head_sample, parse_prometheus, render_prometheus)


# ------------------------------------------------------------ prometheus

def _snapshot():
    reg = MetricsRegistry()
    reg.record(
        counters={"requests": 200, "hits": 150, "source.computed": 50},
        gauges={"queue_depth": 7},
        observations={"latency_s": [i / 1000 for i in range(1, 101)]})
    return reg.snapshot()


def test_render_prometheus_types_and_labels():
    text = render_prometheus(_snapshot(), labels={"shard": "3"})
    assert "# TYPE bandmap_requests counter" in text
    assert 'bandmap_requests{shard="3"} 200' in text
    assert "# TYPE bandmap_queue_depth gauge" in text
    assert 'bandmap_queue_depth{shard="3"} 7' in text
    assert "# TYPE bandmap_latency_s summary" in text
    assert 'bandmap_latency_s{quantile="0.99",shard="3"}' in text
    # Dotted counter names sanitize to identifier-safe metric names.
    assert 'bandmap_source_computed{shard="3"} 50' in text
    assert text.endswith("\n")


def test_prometheus_round_trip():
    snap = _snapshot()
    parsed = parse_prometheus(
        render_prometheus(snap, labels={"shard": "0"}))
    labels = {"shard": "0"}
    assert parsed["bandmap_requests"] == [(labels, 200.0)]
    assert parsed["bandmap_hits"] == [(labels, 150.0)]
    assert parsed["bandmap_queue_depth"] == [(labels, 7.0)]
    # Summary quantiles match the snapshot's percentiles.
    h = snap["histograms"]["latency_s"]
    by_q = {lab["quantile"]: v
            for lab, v in parsed["bandmap_latency_s"]}
    assert by_q["0.5"] == pytest.approx(h["p50"])
    assert by_q["0.99"] == pytest.approx(h["p99"])
    assert parsed["bandmap_latency_s_count"] == [(labels, 100.0)]
    assert parsed["bandmap_latency_s_sum"][0][1] == \
        pytest.approx(h["mean"] * h["count"])


def test_render_without_labels_or_namespace():
    text = render_prometheus(_snapshot(), namespace="")
    assert "\nrequests 200" in text or text.startswith("requests 200") \
        or "requests 200" in text
    parsed = parse_prometheus(text)
    assert parsed["requests"] == [({}, 200.0)]


# ----------------------------------------------------------- access log

def test_access_log_schema_is_pinned():
    log = AccessLog()
    line = log.log(req_id="r1", digest="a" * 64, ok=True, hit=False,
                   source="computed", wall_s=0.01, ii=3,
                   backend="portfolio", rogue_key="dropped")
    entry = json.loads(line)
    assert tuple(entry) == ACCESS_LOG_FIELDS      # order + exact keys
    assert entry["tenant"] is None                # missing -> None
    assert "rogue_key" not in entry
    assert entry["ts"] > 0
    assert log.tail() == [entry]


def test_access_log_redaction_and_ring(tmp_path):
    path = str(tmp_path / "logs" / "access.jsonl")
    log = AccessLog(path, capacity=3, redact_digests=True)
    for i in range(5):
        log.log(req_id=f"r{i}", digest="abcdef0123456789" * 4)
    assert log.total == 5 and len(log) == 3
    assert [e["req_id"] for e in log.tail()] == ["r2", "r3", "r4"]
    assert all(len(e["digest"]) == 12 for e in log.tail())
    # The file mirror keeps every line (the ring only bounds memory).
    with open(path) as fh:
        lines = [json.loads(ln) for ln in fh if ln.strip()]
    assert len(lines) == 5
    assert all(tuple(e) == ACCESS_LOG_FIELDS for e in lines)
    assert all(len(e["digest"]) == 12 for e in lines)


def test_access_log_thread_safe():
    log = AccessLog(capacity=100_000)
    n_threads, per_thread = 8, 500

    def work(tag):
        for i in range(per_thread):
            log.log(req_id=f"{tag}-{i}")

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert log.total == n_threads * per_thread
    assert len({e["req_id"] for e in log.tail()}) == log.total


# ------------------------------------------------------------- sampling

def test_head_sample_deterministic_and_bounded():
    digests = [f"{i:08x}{'0' * 56}" for i in range(10_000)]
    picked = [d for d in digests if head_sample(d, 0.1)]
    again = [d for d in digests if head_sample(d, 0.1)]
    assert picked == again                       # pure in (digest, rate)
    assert 0 < len(picked) < len(digests)
    frac = len(picked) / len(digests)
    assert 0.05 < frac < 0.2                     # ~rate, hash-spread
    # A sampled set at a lower rate nests inside the higher rate's.
    low = {d for d in digests if head_sample(d, 0.05)}
    assert low <= set(picked)


def test_head_sample_edges():
    assert head_sample("deadbeef", 0.0) is False
    assert head_sample("deadbeef", -1.0) is False
    assert head_sample("deadbeef", 1.0) is True
    assert head_sample("", 1.0) is True
    assert head_sample("", 0.5) is True          # empty digest -> bucket 0
