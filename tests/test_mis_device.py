"""Differential suite for the accelerator-resident portfolio engine
(`core.mis_device.DeviceSBTS`, vmapped Pallas SBTS in interpret mode on
CPU).

The numpy `mis.PortfolioSBTS` stays the oracle: on every paper kernel
and every workload family (small sizes) the device engine must produce
independent sets only, and reach equal-or-better best coverage at an
equal per-seed lock-step iteration budget.  On top of the differential:
the counter-based RNG (`jax.random.fold_in` streams keyed on
(seed, trajectory, iteration)) makes runs bit-reproducible and
resume-safe — `run(a); run(b)` lands in the same state as `run(a+b)` —
and the tabu guard is asserted step-by-step with single-iteration
chunks.  End-to-end, ``engine="device"`` must reproduce the golden
(II, routing-PE) table bit-for-bit through `map_dfg`'s harvest loop.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import MapOptions, PortfolioOptions, map_dfg
from repro.core.bitset import pack_bool
from repro.core.cgra import CGRAConfig
from repro.core.conflict import build_conflict_graph
from repro.core.kernels_cnkm import PAPER_KERNELS, cnkm_name, make_cnkm
from repro.core.mis_device import DeviceSBTS, differential_vs_numpy
from repro.core.schedule import mii, schedule_dfg
from repro.core.workloads import FAMILIES

from test_golden_results import GOLDEN, SLOW

CGRA = CGRAConfig()

# Small instances, one per workload family (the generators' smallest
# interesting shapes — the differential is about engine parity, not
# scale).
FAMILY_CASES = {
    "loop": dict(n_chains=2, chain_len=3),
    "stencil": dict(points=3, taps=2),
    "reduction": dict(width=4),
    "cnkm": dict(n=2, m=4),
    "tight": dict(n_vios=2, fanout=4),
}


def _conflict_graph(dfg, cgra):
    """First schedulable (II, jitter=0) combination's conflict graph."""
    start = mii(dfg, cgra)
    for ii in range(start, start + 6):
        try:
            sched = schedule_dfg(dfg, cgra, mode="bandmap", ii=ii,
                                 max_ii=ii, jitter=0, seed=0)
        except RuntimeError:
            continue
        return build_conflict_graph(sched, cgra), len(sched.dfg.ops)
    raise AssertionError("no schedulable II found")


def _assert_differential(dfg):
    cg, n_ops = _conflict_graph(dfg, CGRA)
    res = differential_vs_numpy(cg.bits, iters=256, k=4, seed=0,
                                target=n_ops)
    assert res["device_independent"], res
    assert res["numpy_independent"], res
    assert res["device_cov"] >= res["numpy_cov"], res


@pytest.mark.parametrize(
    "n,m", PAPER_KERNELS, ids=[cnkm_name(n, m) for n, m in PAPER_KERNELS])
def test_differential_paper_kernel(n, m):
    _assert_differential(make_cnkm(n, m))


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_differential_workload_family(family):
    assert family in FAMILY_CASES, f"new family {family!r}: add a case"
    _assert_differential(FAMILIES[family](**FAMILY_CASES[family]))


# --------------------------------------------------- engine invariants
def _small_graph():
    cg, n_ops = _conflict_graph(make_cnkm(2, 6), CGRA)
    return cg.bits, n_ops


def test_counter_rng_is_reproducible():
    """Two engines built from the same (graph, seed, K) advance through
    identical states — the fold_in streams are pure functions of
    (seed, trajectory, iteration), with no hidden host RNG."""
    g, _ = _small_graph()
    a = DeviceSBTS(g, k=4, seed=11)
    b = DeviceSBTS(g, k=4, seed=11)
    a.run(96)
    b.run(96)
    np.testing.assert_array_equal(a.best, b.best)
    np.testing.assert_array_equal(a.in_s, b.in_s)
    np.testing.assert_array_equal(a.tabu, b.tabu)
    np.testing.assert_array_equal(a.best_size, b.best_size)


def test_resume_is_bit_identical_to_one_shot():
    """run(32) + run(64) == run(96): the iteration counter keys the RNG
    streams, so splitting the budget cannot change any trajectory."""
    g, _ = _small_graph()
    split = DeviceSBTS(g, k=4, seed=5)
    whole = DeviceSBTS(g, k=4, seed=5)
    split.run(32)
    split.run(64)
    whole.run(96)
    assert split.it == whole.it == 96
    np.testing.assert_array_equal(split.in_s, whole.in_s)
    np.testing.assert_array_equal(split.best, whole.best)
    np.testing.assert_array_equal(split.tabu, whole.tabu)


def test_every_best_is_an_independent_set():
    g, _ = _small_graph()
    dev = DeviceSBTS(g, k=8, seed=3)
    dev.run(128)
    for row in dev.best:
        assert not g.any_conflict(pack_bool(row))
    for row in dev.in_s[:, :g.n]:
        assert not g.any_conflict(pack_bool(row))


def test_tabu_is_respected_step_by_step():
    """Single-iteration chunks expose every transition: a vertex may
    only *enter* a working set while its tabu expiry is <= the
    iteration counter (swap evictions push expiries into the future,
    and the add/swap selection must honor them)."""
    g, _ = _small_graph()
    dev = DeviceSBTS(g, k=4, seed=9, chunk=1)
    saw_tabu = False
    for _ in range(80):
        before = dev.in_s.copy()
        tabu = dev.tabu.copy()
        it = dev.it
        dev.run(1)
        entered = dev.in_s & ~before
        assert not (entered & (tabu > it)).any(), \
            f"tabu-active vertex re-entered at it={it}"
        saw_tabu = saw_tabu or (dev.tabu > dev.it).any()
    assert saw_tabu, "80 iterations never produced an active tabu entry"


def test_rearm_and_reset_keep_invariants():
    g, n_ops = _small_graph()
    dev = DeviceSBTS(g, k=4, seed=2)
    dev.run(64)
    dev.rearm(0)
    dev.reset_seed(1)
    assert dev.best_size[1] == 0
    dev.run(64, target=n_ops)
    for row in dev.best:
        assert not g.any_conflict(pack_bool(row))


# ------------------------------------------------------------ end-to-end
DEVICE_GOLDEN = [case for case in GOLDEN if case not in SLOW]


@pytest.mark.parametrize("n,m,mode", DEVICE_GOLDEN)
def test_golden_pairs_unchanged_with_device_engine(n, m, mode):
    """`engine="device"` feeds the same dedupe -> repair -> validate
    harvest loop, so the golden (II, routing-PE) table must hold
    end-to-end (the schedule side is untouched; only the MIS search
    runs on-device)."""
    opts = MapOptions(mode=mode, portfolio=PortfolioOptions(
        engine="device", device_seeds=32, iters=4000))
    r = map_dfg(make_cnkm(n, m), CGRA, opts)
    assert r.ok, f"{cnkm_name(n, m)}:{mode} failed: {r.summary()}"
    assert (r.ii, r.n_routing_pes) == GOLDEN[(n, m, mode)], r.summary()
    assert r.mis_size == r.n_ops
