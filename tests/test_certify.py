"""II-infeasibility certificates (core/certify.py): stage soundness on
constructed instances, exactness against brute force, and the end-to-end
behaviour on the paper kernels (the BusMap II=MII stragglers certify in
well under a second instead of burning the portfolio budget)."""

import itertools
import types

import numpy as np
import pytest

from repro.core import (BitsetGraph, certify_ii_infeasible, make_cnkm,
                        map_dfg, schedule_dfg)
from repro.core.certify import (_clique_merge_bound, _resource_count_bound,
                                _search_complete, _symmetry_attrs)
from repro.core.cgra import CGRAConfig
from repro.core.conflict import build_conflict_graph
from repro.core.dfg import DFG, OpKind
from repro.core.schedule import ScheduledDFG

CGRA = CGRAConfig()


def _mini_cg(n, op_vertices, edges):
    """Duck-typed conflict graph for unit-testing the certificate stages."""
    g = BitsetGraph(n)
    for ids in op_vertices.values():
        g.add_clique(ids)
    for i, j in edges:
        g.add_edge(i, j)
    g.clear_diagonal()
    return types.SimpleNamespace(n=n, bits=g, op_vertices=op_vertices)


# --------------------------------------------------------------- stage 1
def test_resource_count_bound_fires_on_overpacked_schedule():
    d = DFG()
    vouts = [d.add_op(OpKind.VOUT) for _ in range(5)]   # 5 VOOs, 4 OPORTs
    sched = ScheduledDFG(d, 1, 1, {v: 0 for v in vouts}, {}, {})
    assert "oport" in _resource_count_bound(sched, CGRA)


def test_resource_count_bound_silent_on_scheduler_output():
    sched = schedule_dfg(make_cnkm(2, 6), CGRA, mode="busmap")
    assert _resource_count_bound(sched, CGRA) is None


# --------------------------------------------------------------- stage 2
def test_clique_merge_bound_fires_on_mutually_exclusive_ops():
    # ops {0,1} x {2,3}: every cross pair conflicts -> one clique.
    cg = _mini_cg(4, {0: [0, 1], 1: [2, 3]},
                  [(0, 2), (0, 3), (1, 2), (1, 3)])
    assert _clique_merge_bound(cg) is not None


def test_clique_merge_bound_silent_when_one_pair_is_free():
    cg = _mini_cg(4, {0: [0, 1], 1: [2, 3]}, [(0, 2), (0, 3), (1, 2)])
    assert _clique_merge_bound(cg) is None


# --------------------------------------------------------------- stage 3
@pytest.mark.parametrize("seed", range(8))
def test_search_complete_matches_brute_force(seed):
    """Exact verdicts on random small CSPs vs itertools enumeration."""
    rng = np.random.default_rng(seed)
    k, d = 5, 3
    n = k * d
    op_vertices = {o: list(range(o * d, (o + 1) * d)) for o in range(k)}
    cross = [(i, j) for i in range(n) for j in range(i + 1, n)
             if i // d != j // d]
    picked = [cross[t] for t in
              rng.choice(len(cross), size=int(0.35 * len(cross)),
                         replace=False)]
    cg = _mini_cg(n, op_vertices, picked)
    adj = cg.bits.to_dense()
    brute = any(
        all(not adj[a, b] for a, b in itertools.combinations(combo, 2))
        for combo in itertools.product(*op_vertices.values()))
    verdict, placements, nodes = _search_complete(cg, node_budget=10 ** 6,
                                                  n_solutions=3)
    assert verdict is brute
    if verdict:
        assert 1 <= len(placements) <= 3
        assert len({p.tobytes() for p in placements}) == len(placements)
        for p in placements:
            idx = np.flatnonzero(p)
            assert len(idx) == k
            assert not adj[np.ix_(idx, idx)].any()


def test_search_complete_respects_budget():
    cg = _mini_cg(4, {0: [0, 1], 1: [2, 3]}, [])
    verdict, placements, nodes = _search_complete(cg, node_budget=0)
    assert verdict is None and placements == []


# -------------------------------------------------------------- symmetry
@pytest.mark.parametrize("n,m,mode,ii,jitter",
                         [(2, 8, "busmap", 2, 0), (2, 8, "busmap", 2, 3),
                          (2, 6, "busmap", 2, 0)])
def test_symmetry_verdicts_match_plain_search(n, m, mode, ii, jitter):
    """Orbit-representative pruning never changes the verdict: the
    row/column-permutation group is verified per instance, and the
    symmetric and plain exhaustive searches agree (infeasible and
    feasible cases)."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode, ii=ii,
                         max_ii=ii, jitter=jitter)
    cg = build_conflict_graph(sched, CGRA, bus_pressure=True)
    v_sym, p_sym, n_sym = _search_complete(cg, 10 ** 6, cgra=CGRA)
    v_plain, _, n_plain = _search_complete(cg, 10 ** 6)
    assert v_sym == v_plain
    assert n_sym <= n_plain
    if v_sym:
        idx = np.flatnonzero(p_sym[0])
        assert not cg.bits.to_dense()[np.ix_(idx, idx)].any()


def test_symmetry_guard_rejects_perturbed_graph():
    """A graph that is not invariant under the row/column transpositions
    (here: one extra asymmetric edge) fails the per-instance
    verification and falls back to the plain search."""
    sched = schedule_dfg(make_cnkm(2, 6), CGRA, mode="busmap")
    cg = build_conflict_graph(sched, CGRA)
    u8 = cg.bits.rows_u8(np.arange(cg.n)).astype(np.int16)
    assert _symmetry_attrs(cg, CGRA, u8) is not None
    quads = [v.idx for v in cg.vertices
             if v.kind == "quad" and v.pe == (0, 0)]
    others = [v.idx for v in cg.vertices
              if v.kind == "quad" and v.pe == (1, 1)
              and v.op != cg.vertices[quads[0]].op]
    cg.bits.add_edge(quads[0], others[0])
    u8 = cg.bits.rows_u8(np.arange(cg.n)).astype(np.int16)
    assert _symmetry_attrs(cg, CGRA, u8) is None


# ------------------------------------------------------------ end-to-end
@pytest.mark.parametrize("n,m", [(2, 8), (5, 5)])
def test_certifies_busmap_ii2_infeasible(n, m):
    """The ROADMAP stragglers: II=MII=2 BusMap binding is *proven*
    impossible instead of searched for 10+ seconds."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode="busmap", ii=2,
                         max_ii=2)
    cg = build_conflict_graph(sched, CGRA, bus_pressure=True)
    cert, placements = certify_ii_infeasible(cg, sched, CGRA)
    assert cert is not None and placements is None
    assert cert.stage == "exhausted"
    assert cert.ii == 2
    assert cert.wall_s < 2.0          # ms-scale in practice; slack for CI


@pytest.mark.parametrize("n,m,mode,ii", [(2, 6, "busmap", 2),
                                         (3, 6, "bandmap", 2),
                                         (4, 4, "busmap", 1)])
def test_no_certificate_on_feasible_schedules(n, m, mode, ii):
    """Feasible (II, jitter) combinations never produce a certificate and
    the exhaustive stage returns a genuinely independent placement."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode, ii=ii,
                         max_ii=ii)
    cg = build_conflict_graph(sched, CGRA, bus_pressure=True)
    cert, placements = certify_ii_infeasible(cg, sched, CGRA,
                                             n_placements=3)
    assert cert is None
    assert placements
    adj = cg.bits.to_dense()
    for placement in placements:
        idx = np.flatnonzero(placement)
        assert len(idx) == len(sched.dfg.ops)
        ops = {cg.vertices[i].op for i in idx}
        assert ops == set(sched.dfg.ops)
        assert not adj[np.ix_(idx, idx)].any()


def test_map_dfg_records_certificates():
    """With max_ii pinned at the certified-infeasible level, map_dfg
    returns failure with one certificate per (II, jitter) combination
    and never spends the portfolio budget."""
    r = map_dfg(make_cnkm(5, 5), CGRA, mode="busmap", max_ii=2)
    assert not r.ok
    assert len(r.certificates) == 4
    assert {c.jitter for c in r.certificates} == {0, 1, 2, 3}
    assert all(c.ii == 2 for c in r.certificates)
    assert r.attempts == 0            # no portfolio budget spent
    assert r.wall_s < 5.0


def test_map_dfg_flags_reproduce_seed_pipeline():
    """certify=False + bus_pressure=False is the seed pipeline; outcomes
    agree with the default (certified) pipeline on a quick kernel."""
    ref = map_dfg(make_cnkm(2, 6), CGRA, mode="busmap",
                  certify=False, bus_pressure=False)
    new = map_dfg(make_cnkm(2, 6), CGRA, mode="busmap")
    assert (ref.ok, ref.ii, ref.n_routing_pes) == \
        (new.ok, new.ii, new.n_routing_pes) == (True, 2, 2)
