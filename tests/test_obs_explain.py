"""Explain reports: every skipped II gets a definite cause, the golden
C5K5 narrative is stable, a proved-infeasible run reads as a full-range
UNSAT story, and the CLI round-trips a serialized result."""

import json

import pytest

from repro.core import make_cnkm, map_dfg
from repro.core.bandmap import MappingResult
from repro.core.cgra import CGRAConfig
from repro.obs import FlightRecorder, Tracer, explain_result
from repro.obs.explain import main as explain_main


@pytest.fixture(scope="module")
def c5k5_busmap():
    tr = Tracer()
    rec = FlightRecorder()
    res = map_dfg(make_cnkm(5, 5), CGRAConfig(), mode="busmap",
                  tracer=tr, record=rec)
    return res, tr, rec


def test_c5k5_every_skipped_ii_names_a_cause(c5k5_busmap):
    """Acceptance: every II the escalation skipped names a certificate
    stage or the static demand floor — never a bare 'skipped'."""
    res, tr, rec = c5k5_busmap
    assert res.ok
    rep = res.explain(tracer=tr, flight=rec.dump())
    assert [e["ii"] for e in rep.escalation] == \
        list(range(res.mii, res.ii + 1))
    for e in rep.escalation:
        if e["outcome"] != "skipped":
            continue
        assert e["stages"] or "static demand floor" in e["cause"], e
    assert rep.escalation[-1]["outcome"] == "mapped"


def test_c5k5_golden_report(c5k5_busmap):
    """Golden structure for the paper's C5K5 BusMap run: II=2 is fully
    certified (exhausted CSP at every jitter), II=3 maps."""
    res, tr, rec = c5k5_busmap
    rep = res.explain(tracer=tr, flight=rec.dump())
    assert (rep.ok, rep.mode, rep.ii, rep.mii) == (True, "busmap", 3, 2)
    ii2, ii3 = rep.escalation
    assert ii2["outcome"] == "skipped"
    assert ii2["stages"] == ["exhausted"]
    assert ii2["certified_jitters"] == [0, 1, 2, 3]
    assert ii3["outcome"] == "mapped"
    assert rep.routing["n_routing_pes"] == res.n_routing_pes
    text = rep.render()
    assert "II=2: skipped — certified infeasible" in text
    assert "II=3: mapped" in text
    assert "BusMap baseline" in text
    # The structured shape survives JSON.
    blob = json.loads(json.dumps(rep.as_dict(), default=str))
    assert blob["escalation"][0]["stages"] == ["exhausted"]


def test_proved_infeasible_reads_as_unsat_narrative():
    rec = FlightRecorder()
    res = map_dfg(make_cnkm(2, 8), CGRAConfig(rows=4, cols=4),
                  mode="busmap", max_ii=2, record=rec)
    assert not res.ok and res.proved_infeasible
    rep = res.explain()            # flight defaults to result.flight
    assert rep.proved_infeasible and not rep.ok
    assert rep.n_flight_events == len(res.flight) > 0
    assert all(e["outcome"] == "skipped" for e in rep.escalation)
    assert all(e["stages"] for e in rep.escalation)
    text = rep.render()
    assert "proved infeasible" in text
    assert "flight:" in text


def test_coverage_curve_from_flight_events():
    rec = FlightRecorder()
    res = map_dfg(make_cnkm(5, 5), CGRAConfig(), mode="bandmap",
                  record=rec)
    assert res.ok
    rep = explain_result(res, flight=rec.dump())
    assert rep.coverage, "bandmap C5K5 runs the portfolio"
    last = rep.coverage[-1]
    assert 0.0 < last["coverage"] <= 1.0
    assert "harvest round(s)" in rep.render()


def test_race_winner_in_report():
    from repro.exact.race import race_map_dfg
    res = race_map_dfg(make_cnkm(5, 5), CGRAConfig(), mode="bandmap")
    rep = explain_result(res)
    assert rep.race is not None
    assert rep.race["winner"] in ("exact", "portfolio")
    assert f"race: winner={rep.race['winner']}" in rep.render()


def test_cli_renders_and_emits_json(tmp_path, capsys):
    res = map_dfg(make_cnkm(5, 5), CGRAConfig(), mode="busmap")
    path = tmp_path / "result.bin"
    path.write_bytes(res.to_bytes())
    assert explain_main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "explain: busmap — ok" in out
    assert explain_main([str(path), "--json"]) == 0
    blob = json.loads(capsys.readouterr().out)
    assert blob["ii"] == res.ii
    assert blob["escalation"]


def test_explain_duck_types_without_engine_extras():
    """explain_result never needs tracer/flight/certificates — a bare
    duck-typed result still yields a complete narrative."""
    res = MappingResult(
        ok=True, mode="bandmap", ii=2, mii=2, n_routing_pes=0,
        ports_per_vio={7: 2}, placement={}, sched=None, report=None,
        cg_size=(10, 20), mis_size=5, n_ops=5, attempts=3, wall_s=0.1)
    rep = explain_result(res)
    assert rep.escalation[-1]["outcome"] == "mapped"
    assert rep.routing["total_ports"] == 2
    assert rep.race is None and rep.coverage == []
    assert "bandwidth allocation" in rep.render()
