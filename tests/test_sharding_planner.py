"""Sharding rules engine, planner bandwidth allocation, HLO analyzer, and
optimizer/compression substrates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.core import planner as planner_mod
from repro.launch import hlo_analysis as H
from repro.launch import sharding as sh
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.optim import AdamW, cosine_schedule
from repro.optim.compression import (compress_grads, decompress_grads,
                                     error_feedback_update)


def mesh44():
    from jax.sharding import AxisType
    import jax as _jax
    devs = _jax.devices()
    if len(devs) >= 16:
        return _jax.make_mesh((4, 4), ("data", "model"))
    return None


# ------------------------------------------------------------- rules
def _fake_mesh(shape):
    """Rules only need mesh.shape for divisibility logic."""
    class FakeMesh:
        def __init__(self, s):
            self.shape = s
    return FakeMesh(shape)


def test_rules_divisibility_fallback():
    rules = sh.Rules({"heads": "model", "embed": "data"},
                     _fake_mesh({"data": 16, "model": 16}))
    # 20 heads (qwen1.5) on 16-way axis -> replicated
    spec = rules.spec_for(("embed", "heads", None), (2560, 20, 128))
    assert spec == P("data", None, None)
    spec2 = rules.spec_for(("embed", "heads", None), (2560, 32, 128))
    assert spec2 == P("data", "model", None)


def test_rules_duplicate_axis_dropped():
    rules = sh.Rules({"a": "model", "b": "model"},
                     _fake_mesh({"model": 4}))
    spec = rules.spec_for(("a", "b"), (8, 8))
    assert spec == P("model", None)    # first occurrence wins


def test_param_axes_cover_all_archs():
    for arch in ("mixtral-8x7b", "deepseek-v2-lite-16b", "mamba2-2.7b",
                 "zamba2-1.2b", "whisper-tiny"):
        cfg = get_smoke_config(arch)
        specs = M.param_specs(cfg)
        axes = sh.param_axes_tree(specs)
        for s, a in zip(jax.tree.leaves(specs),
                        jax.tree.leaves(axes, is_leaf=lambda x:
                                        isinstance(x, tuple))):
            assert len(a) == len(s.shape), (a, s.shape)


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    y = sh.constrain(x, ("batch", "embed"))
    assert y is x


# ------------------------------------------------------------- planner
def test_planner_rd_matches_mesh_axes():
    cfg = get_config("mixtral-8x7b")
    mesh = _fake_mesh({"data": 16, "model": 16})
    plan = planner_mod.plan(cfg, "train", 4096, 256, mesh)
    by_name = {t.tensor: t for t in plan.transfers}
    # FSDP weight gathers have RD = dp size (the highest-RD VIOs)
    assert by_name["expert_w.fsdp_gather"].rd == 16
    assert by_name["expert_w.fsdp_gather"].strategy == "multicast"
    assert by_name["moe_dispatch"].strategy == "relay"
    assert plan.collective_bytes > 0


def test_planner_long_context_shards_sequence():
    cfg = get_config("mamba2-2.7b")
    mesh = _fake_mesh({"data": 16, "model": 16})
    plan = planner_mod.plan(cfg, "decode", 524288, 1, mesh)
    assert plan.rules["seq"] == "data"      # batch 1 can't use dp
    assert plan.rules["batch"] is None


def test_planner_transfer_dfg_uses_paper_rd():
    """The transfer DFG is a real core.dfg.DFG: RD comes from fan-out."""
    cfg = get_config("glm4-9b")
    dfg, meta = planner_mod.build_transfer_dfg(
        cfg, "train", 4096, 256, {"data": 16, "model": 16})
    for v in dfg.v_i:
        assert dfg.rd(v) == len(dfg.successors(v))
        assert dfg.rd(v) in (16,)           # dp-reused weight classes


def test_planner_transfer_rounds_partition():
    """Bandwidth rounds from the bitset MIS engine: every byte-moving
    transfer appears exactly once, no round reuses a mesh axis, and the
    round count equals the busiest axis's multiplicity (the contention
    graph is a union of per-axis cliques)."""
    from collections import Counter
    cfg = get_config("mixtral-8x7b")
    mesh = _fake_mesh({"data": 16, "model": 16})
    plan = planner_mod.plan(cfg, "train", 4096, 256, mesh)
    rounds = planner_mod.schedule_transfer_rounds(plan)
    act = [t for t in plan.transfers if t.bytes_per_step > 0]
    flat = [name for rnd in rounds for name in rnd]
    assert sorted(flat) == sorted(t.tensor for t in act)
    by_name = {t.tensor: t for t in act}
    for rnd in rounds:
        axes = [by_name[name].axis for name in rnd]
        assert len(axes) == len(set(axes))
    assert len(rounds) == max(Counter(t.axis for t in act).values())


def test_planner_optimized_compresses_cross_pod():
    cfg = get_config("glm4-9b")
    mesh = _fake_mesh({"pod": 2, "data": 16, "model": 16})
    base = planner_mod.plan(cfg, "train", 4096, 256, mesh)
    opt = planner_mod.plan(cfg, "train", 4096, 256, mesh, optimized=True)
    assert opt.grad_compression and not base.grad_compression
    g_base = sum(t.bytes_per_step for t in base.transfers
                 if t.strategy == "reduce")
    g_opt = sum(t.bytes_per_step for t in opt.transfers
                if t.strategy == "reduce")
    assert g_opt < g_base


# --------------------------------------------------------- HLO analyzer
def test_hlo_analyzer_counts_scan_body_times_trip_count():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    comp = jax.jit(f).lower(jnp.ones((32, 32))).compile()
    res = H.analyze(comp.as_text())
    # 8 iterations x 2*32^3 flops
    expected = 8 * 2 * 32 ** 3
    assert res["dot_flops"] == pytest.approx(expected, rel=0.01)


def test_hlo_analyzer_flops_close_to_6nd():
    cfg = get_smoke_config("glm4-9b")
    opt = AdamW()
    ts = M.make_train_step(cfg, opt)
    params = jax.eval_shape(lambda: M.init_params(cfg, 0))
    opts = jax.eval_shape(opt.init, params)
    b, s = 4, 32
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    comp = jax.jit(ts).lower(
        (params, opts, jax.ShapeDtypeStruct((), jnp.int32)),
        batch).compile()
    res = H.analyze(comp.as_text())
    n = M.count_params(cfg)
    ratio = res["dot_flops"] / (6 * n * b * s)
    assert 0.9 < ratio < 2.0, ratio    # 6ND + attention + remat recompute


# ----------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        upd, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(55)) < float(lr(20))


# ---------------------------------------------------------- compression
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal((300,)), jnp.float32)}
    comp = compress_grads(g)
    back = decompress_grads(comp, g)
    err = np.abs(np.asarray(back["a"]) - np.asarray(g["a"]))
    scale = np.abs(np.asarray(g["a"])).max()
    assert err.max() <= scale / 127.0 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((512,)) * 1e-3)}
    err = None
    acc_plain = np.zeros(512)
    acc_ef = np.zeros(512)
    for _ in range(50):
        comp = compress_grads(g)
        acc_plain += np.asarray(decompress_grads(comp, g)["a"])
        _, est, err = error_feedback_update(g, err)
        acc_ef += np.asarray(est["a"])
    target = np.asarray(g["a"]) * 50
    assert np.abs(acc_ef - target).mean() <= \
        np.abs(acc_plain - target).mean() + 1e-9
