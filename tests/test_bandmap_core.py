"""Unit + property tests for the paper's core pipeline (DFG, scheduler,
conflict graph, MIS, validator)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (PAPER_KERNELS, cnkm_name, greedy_mis, make_cnkm,
                        map_dfg, mii, res_mii, schedule_dfg, solve_mis)
from repro.core.cgra import CGRAConfig
from repro.core.conflict import (build_conflict_graph,
                                 dense_conflicts_python)
from repro.core.dfg import DFG, OpKind
from repro.core.mis import ejection_repair, mis_indices
from repro.core.validate import validate_mapping

CGRA = CGRAConfig()


# ------------------------------------------------------------------- DFG
def test_cnkm_structure():
    d = make_cnkm(3, 5)
    assert len(d.v_i) == 3 and len(d.v_o) == 5
    assert len(d.v_r) == 15
    for v in d.v_i:
        assert d.rd(v) == 5          # each input reused by m kernels
    for v in d.v_o:
        assert d.rd(v) == 1          # outputs have no spatial reuse


def test_rec_mii_loop_carried():
    d = DFG()
    a = d.add_op(OpKind.COMPUTE)
    b = d.add_op(OpKind.COMPUTE)
    d.add_edge(a, b)
    d.add_edge(b, a, distance=1)     # carried dependency
    assert d.rec_mii() == 2


def test_res_mii():
    d = make_cnkm(5, 5)              # 25 computing ops on 16 PEs
    assert res_mii(d, CGRA) == 2


# -------------------------------------------------------------- schedule
@pytest.mark.parametrize("mode", ["bandmap", "busmap"])
@pytest.mark.parametrize("n,m", PAPER_KERNELS)
def test_schedule_feasible(n, m, mode):
    dfg = make_cnkm(n, m)
    sched = schedule_dfg(dfg, CGRA, mode=mode)
    ii = sched.ii
    # resource feasibility per modulo slot
    pe, ip, op_ = [0] * ii, [0] * ii, [0] * ii
    for oid, t in sched.time.items():
        kind = sched.dfg.ops[oid].kind
        if kind in (OpKind.COMPUTE, OpKind.ROUTE):
            pe[t % ii] += 1
        elif kind == OpKind.VIN:
            ip[t % ii] += 1
        else:
            op_[t % ii] += 1
    assert max(pe) <= CGRA.n_pes
    assert max(ip) <= CGRA.n_iports
    assert max(op_) <= CGRA.n_oports
    # dependencies respected (delivery may precede use thanks to LRF)
    for e in sched.dfg.edges:
        src_kind = sched.dfg.ops[e.src].kind
        if src_kind == OpKind.VIN:
            assert sched.time[e.src] <= sched.time[e.dst]
        else:
            assert sched.time[e.src] < sched.time[e.dst] + \
                e.distance * ii


def test_bandwidth_allocation_policy():
    """RD > M gets Q = ceil(RD/M) ports (the paper's policy)."""
    dfg = make_cnkm(2, 8)            # RD = 8, M = 4 -> Q = 2
    sched = schedule_dfg(dfg, CGRA, mode="bandmap")
    for q in sched.ports_allocated.values():
        assert q == 2
    # busmap forces one port per datum
    sched_b = schedule_dfg(make_cnkm(2, 8), CGRA, mode="busmap")
    assert all(q == 1 for q in sched_b.ports_allocated.values())
    assert sched_b.n_routing_ops > 0


# ---------------------------------------------------------------- MIS
@given(st.integers(4, 60), st.floats(0.05, 0.5), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_mis_independence_property(n, density, seed):
    """solve_mis always returns an independent set."""
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < density
    adj = np.triu(adj, 1)
    adj = adj | adj.T
    sol = solve_mis(adj, max_iters=500, seed=seed)
    idx = mis_indices(sol)
    assert not adj[np.ix_(idx, idx)].any()
    # maximality of greedy start: every outsider conflicts with S
    g = greedy_mis(adj, rng)
    gi = mis_indices(g)
    for v in range(n):
        if not g[v]:
            assert adj[v, gi].any()


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_ejection_repair_preserves_independence(seed):
    dfg = make_cnkm(3, 6)
    sched = schedule_dfg(dfg, CGRA, mode="bandmap")
    cg = build_conflict_graph(sched, CGRA)
    sol = solve_mis(cg.adj, max_iters=300, seed=seed)
    op_of = np.array([v.op for v in cg.vertices])
    fixed = ejection_repair(cg.adj, sol, cg.op_vertices, op_of, seed=seed)
    idx = mis_indices(fixed)
    assert not cg.adj[np.ix_(idx, idx)].any()
    assert fixed.sum() >= sol.sum()


# ------------------------------------------------------- conflict graph
@pytest.mark.parametrize("n,m,mode", [(2, 6, "bandmap"), (3, 6, "busmap"),
                                      (4, 4, "bandmap")])
def test_conflict_matrix_kernel_equals_python(n, m, mode):
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode)
    cg = build_conflict_graph(sched, CGRA)
    from repro.kernels.conflict_matrix.ops import conflict_matrix
    fast = conflict_matrix(cg.vertices)
    loops = dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
    assert (fast == loops).all()


def test_conflict_graph_has_clique_per_op():
    sched = schedule_dfg(make_cnkm(2, 4), CGRA)
    cg = build_conflict_graph(sched, CGRA)
    for ids in cg.op_vertices.values():
        for a in ids:
            for b in ids:
                if a != b:
                    assert cg.adj[a, b]


# ----------------------------------------------------------- end-to-end
@pytest.mark.parametrize("n,m", [(1, 2), (2, 4), (2, 6), (4, 4)])
def test_map_dfg_valid(n, m):
    r = map_dfg(make_cnkm(n, m), CGRA, mode="bandmap")
    assert r.ok, r.summary()
    assert r.mis_size == r.n_ops
    assert r.report.ok
    # one placement per op, consistent with the schedule
    assert set(r.placement) == set(r.sched.dfg.ops)


def test_validator_catches_pe_clash():
    r = map_dfg(make_cnkm(2, 4), CGRA)
    placement = dict(r.placement)
    quads = [o for o, v in placement.items() if v.kind == "quad"]
    a, b = quads[0], quads[1]
    # force two ops onto one PE instance at the same slot
    va, vb = placement[a], placement[b]
    if va.m == vb.m:
        import dataclasses
        placement[b] = dataclasses.replace(vb, pe=va.pe)
        rep = validate_mapping(r.sched, CGRA, placement)
        assert not rep.ok


def test_paper_claims_no_grf():
    """BandMap: fewer/equal routing PEs and same/better II than BusMap
    (the paper's §IV-B claims), on the quick kernels."""
    for (n, m) in [(2, 4), (2, 6), (4, 4)]:
        rb = map_dfg(make_cnkm(n, m), CGRA, mode="bandmap")
        ru = map_dfg(make_cnkm(n, m), CGRA, mode="busmap")
        assert rb.ok and ru.ok
        assert rb.ii <= ru.ii
        assert rb.n_routing_pes <= ru.n_routing_pes
        if m > 4:
            assert rb.n_routing_pes < ru.n_routing_pes


def test_grf_reaches_mii():
    cgra = CGRAConfig(grf=8)
    for (n, m) in [(2, 6), (3, 6)]:
        r = map_dfg(make_cnkm(n, m), cgra, mode="bandmap")
        assert r.ok and r.ii == r.mii
