"""Per-arch reduced-config smoke tests: one forward + train step + decode
step on CPU, asserting shapes and no NaNs, plus family-specific
behaviour (SWA masking, MLA absorbed==naive, prefill==decode replay)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import AdamW


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    text = s - cfg.n_vision_tokens if cfg.n_vision_tokens else s
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, text)), jnp.int32)}
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab, (b, text)), jnp.int32)
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16) * 0.1
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)),
            jnp.bfloat16) * 0.1
    return batch


# Two cheap archs stay in the fast tier-1 run; the full per-arch sweep
# (10 archs x forward/train/decode, minutes of CPU compile) is `slow`.
FAST_ARCHS = ("qwen1.5-4b", "glm4-9b")


def _arch_sweep(archs):
    return [a if a in FAST_ARCHS
            else pytest.param(a, marks=pytest.mark.slow) for a in archs]


@pytest.mark.parametrize("arch", _arch_sweep(ARCHS))
def test_arch_smoke_forward_and_shapes(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, 0)
    b, s = 2, 32
    batch = make_batch(cfg, b, s)
    logits, aux, _ = T.forward(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(jnp.asarray(aux))


@pytest.mark.parametrize("arch", _arch_sweep(ARCHS))
def test_arch_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = AdamW(lr=1e-3)
    params = M.init_params(cfg, 0)
    state = (params, opt.init(params), jnp.zeros((), jnp.int32))
    step = jax.jit(M.make_train_step(cfg, opt))
    batch = make_batch(cfg)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])   # same batch: must drop
    for leaf in jax.tree.leaves(state[0]):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", _arch_sweep(ARCHS))
def test_arch_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, 0)
    b = 2
    cache = M.init_cache(cfg, b, 16)
    batch = {"tokens": jnp.ones((b, 1), jnp.int32)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                          jnp.bfloat16)
    for _ in range(3):
        nxt, logits, cache = M.serve_step(cfg, params, batch, cache)
        batch = dict(batch, tokens=nxt)
    assert nxt.shape == (b, 1)
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", _arch_sweep(
    ["glm4-9b", "mamba2-2.7b", "zamba2-1.2b", "deepseek-v2-lite-16b"]))
def test_prefill_then_decode_matches_full_forward(arch):
    """Teacher-forced decode after prefill == full forward logits."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, 0)
    b, s = 1, 12
    batch = make_batch(cfg, b, s)
    full_logits, _, _ = T.forward(cfg, params, batch)
    # bf16 compute: chunked-scan prefill vs sequential decode reorder fp
    # ops; SSM recurrences amplify that more than attention does.
    atol = 0.15 if cfg.family in ("ssm", "hybrid") else 3e-2

    cache = M.init_cache(cfg, b, s + 4, dtype=jnp.float32)
    pre = {"tokens": batch["tokens"][:, :8]}
    if "audio_embeds" in batch:
        pre["audio_embeds"] = batch["audio_embeds"]
    logits8, cache = M.prefill_step(cfg, params, pre, cache)
    np.testing.assert_allclose(np.asarray(logits8[:, -1]),
                               np.asarray(full_logits[:, 7]),
                               atol=atol, rtol=atol)
    # decode tokens 8..11 teacher-forced
    for t in range(8, s):
        step_batch = {"tokens": batch["tokens"][:, t:t + 1]}
        if "audio_embeds" in batch and cfg.family == "encdec":
            step_batch["audio_embeds"] = batch["audio_embeds"]
        nxt, logits, cache = M.serve_step(cfg, params, step_batch, cache)
        if t + 1 < s:
            np.testing.assert_allclose(
                np.asarray(logits[:, -1]), np.asarray(full_logits[:, t]),
                atol=atol, rtol=atol)
            # semantic agreement: same argmax token
            assert int(jnp.argmax(logits[:, -1])) == \
                int(jnp.argmax(full_logits[:, t]))


def test_swa_mask_blocks_far_tokens():
    from repro.models.attention import causal_window_mask
    q = jnp.arange(10)
    m = causal_window_mask(q, q, 3)
    assert bool(m[5, 5]) and bool(m[5, 3])
    assert not bool(m[5, 2])          # outside window
    assert not bool(m[5, 6])          # acausal
    m_full = causal_window_mask(q, q, 0)   # 0 = full causal (dynamic)
    assert bool(m_full[9, 0])


def test_mla_absorbed_equals_naive():
    from repro.models import attention as A
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    key = jax.random.PRNGKey(3)
    p = A.mla_init(key, cfg.d_model, cfg.n_heads, kv_lora=cfg.kv_lora,
                   qk_nope_dim=cfg.qk_nope_dim,
                   qk_rope_dim=cfg.qk_rope_dim, v_dim=cfg.v_head_dim)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 1, cfg.d_model))
    cache = {"c_kv": jax.random.normal(jax.random.PRNGKey(5),
                                       (2, 16, cfg.kv_lora)),
             "k_pe": jax.random.normal(jax.random.PRNGKey(6),
                                       (2, 16, cfg.qk_rope_dim)),
             "pos": jnp.asarray(8)}
    kw = dict(n_heads=cfg.n_heads, kv_lora=cfg.kv_lora,
              qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
              v_dim=cfg.v_head_dim)
    pos = jnp.asarray([[8], [8]])
    o1, _ = A.mla_attention(p, x, pos, cache=dict(cache), absorbed=True,
                            **kw)
    o2, _ = A.mla_attention(p, x, pos, cache=dict(cache), absorbed=False,
                            **kw)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=2e-2,
                               rtol=2e-2)


def test_hybrid_shared_attn_is_shared():
    """zamba2's attention block params exist once (weight sharing)."""
    cfg = get_smoke_config("zamba2-1.2b")
    params = M.init_params(cfg, 0)
    assert "shared_attn" in params
    n_inv = T.n_hybrid_attn_invocations(cfg)
    assert n_inv == cfg.n_layers // cfg.hybrid_attn_every
    cache = M.cache_specs(cfg, 2, 16)
    assert cache["layers"]["attn"]["k"].shape[0] == n_inv


def test_moe_load_balancing_loss_positive():
    cfg = get_smoke_config("mixtral-8x7b")
    params = M.init_params(cfg, 0)
    batch = make_batch(cfg)
    _, aux, _ = T.forward(cfg, params, batch)
    assert float(aux) > 0.0
