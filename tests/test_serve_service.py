"""Batching scheduler + `MappingService` facade + transfer-round wiring:
dedupe, deadline-ordered admission, co-tenant co-mapping, trace
end-to-end, and the `schedule_transfer_rounds` serving/roofline hooks."""

import collections

import pytest

from repro.core import (CGRAConfig, make_cnkm, make_loop_kernel,
                        make_request_trace, permute_dfg, serve_catalog)
from repro.core.schedule import mii
from repro.serve import MappingService, MapRequest

CGRA = CGRAConfig()


# ------------------------------------------------------------ scheduler
def test_in_flight_dedupe_single_computation():
    svc = MappingService(max_workers=2)
    base = make_cnkm(3, 6)
    reqs = [MapRequest(dfg=permute_dfg(base, seed=s), cgra=CGRA,
                       deadline=s, req_id=f"r{s}") for s in range(4)]
    outs = svc.map_batch(reqs)
    assert all(o.ok for o in outs)
    sources = collections.Counter(o.source for o in outs)
    assert sources["computed"] == 1 and sources["dedupe"] == 3
    assert svc.cache.stats.puts == 1
    # Every dedupe follower got its own validator-replayed copy.
    assert svc.cache.stats.replay_rejects == 0
    iis = {o.result.ii for o in outs}
    assert len(iis) == 1


def test_deadline_picks_the_dedupe_leader():
    """Arrival order r0, r1 — but r1's deadline is earlier, so r1 must
    be admitted first and become the computing leader."""
    svc = MappingService(max_workers=1)
    base = make_cnkm(2, 6)
    outs = svc.map_batch([
        MapRequest(dfg=base, cgra=CGRA, deadline=10.0, req_id="r0"),
        MapRequest(dfg=permute_dfg(base, seed=1), cgra=CGRA,
                   deadline=1.0, req_id="r1"),
    ])
    assert outs[0].source == "dedupe" and outs[1].source == "computed"


def test_co_tenant_requests_are_co_mapped():
    big = CGRAConfig(rows=16, cols=16)
    svc = MappingService(max_workers=2)
    opts = dict(max_bus_fanout=4, mis_restarts=4, mis_iters=4000,
                max_ii=10)
    reqs = [MapRequest(dfg=make_loop_kernel(n_chains=4, chain_len=4,
                                            seed=s),
                       cgra=big, options=opts, tenant="tenantA",
                       deadline=s) for s in range(2)]
    outs = svc.map_batch(reqs)
    assert all(o.source == "comap" for o in outs)
    assert all(o.ok for o in outs)
    # Common II across the co-resident kernels (the co-mapper invariant).
    assert len({o.result.ii for o in outs}) == 1
    # Region results bind a group-dependent sub-array — never cached.
    assert svc.cache.stats.puts == 0
    # A repeated group re-runs co_map (no stale solo placements).
    outs2 = svc.map_batch(reqs)
    assert all(o.source == "comap" and not o.hit for o in outs2)


def test_mixed_tenants_do_not_co_map():
    svc = MappingService(max_workers=2)
    outs = svc.map_batch([
        MapRequest(dfg=make_cnkm(2, 4), cgra=CGRA, tenant="a"),
        MapRequest(dfg=make_cnkm(2, 6), cgra=CGRA, tenant="b"),
    ])
    assert all(o.source == "computed" for o in outs)
    assert all(o.ok for o in outs)


def test_co_tenants_never_served_from_cache():
    """A cached solo placement must not satisfy a co-resident request:
    it binds the full array and would overlap the co-tenant's
    placement."""
    big = CGRAConfig(rows=16, cols=16)
    opts = dict(max_bus_fanout=4, mis_restarts=4, mis_iters=4000,
                max_ii=10)
    base = make_loop_kernel(n_chains=4, chain_len=4, seed=0)
    svc = MappingService(max_workers=2)
    assert not svc.map(base, big, **opts).hit   # primes the solo cache
    outs = svc.map_batch([
        MapRequest(dfg=permute_dfg(base, seed=1), cgra=big,
                   options=opts, tenant="t"),
        MapRequest(dfg=make_loop_kernel(n_chains=4, chain_len=4, seed=1),
                   cgra=big, options=opts, tenant="t"),
    ])
    assert all(o.source == "comap" and not o.hit for o in outs)
    assert all(o.ok for o in outs)
    assert len({o.result.ii for o in outs}) == 1


def test_co_tenants_honor_min_ii():
    """The II floor a request would get solo must survive co-tenant
    grouping (`co_map` gained ``min_ii`` for exactly this)."""
    big = CGRAConfig(rows=16, cols=16)
    opts = dict(max_bus_fanout=4, mis_restarts=4, mis_iters=4000,
                max_ii=10, min_ii=5)
    outs = MappingService(max_workers=2).map_batch([
        MapRequest(dfg=make_loop_kernel(n_chains=4, chain_len=4, seed=s),
                   cgra=big, options=opts, tenant="t")
        for s in range(2)])
    assert all(o.ok and o.result.ii >= 5 for o in outs)


def test_lone_tenant_uses_the_cache():
    """A tenant alone in its batch has nothing to be co-resident with,
    so a cached solo placement is sound to reuse."""
    svc = MappingService(max_workers=2)
    base = make_cnkm(2, 6)
    assert not svc.map(base, CGRA).hit
    out = svc.map(permute_dfg(base, seed=8), CGRA, tenant="t")
    assert out.hit and out.source == "memory" and out.ok


def test_failed_co_map_falls_back_for_every_kernel(monkeypatch):
    """After a failed group run (arbitration / merged-validation), the
    region-locally-ok placements still clash on shared scopes — every
    kernel must fall back to a solo full-array map."""
    import dataclasses

    import repro.comap as comap_pkg
    real_co_map = comap_pkg.co_map

    def failing_co_map(dfgs, cgra, **kw):
        cm = real_co_map(dfgs, cgra, **kw)
        return dataclasses.replace(cm, ok=False)

    monkeypatch.setattr(comap_pkg, "co_map", failing_co_map)
    svc = MappingService(max_workers=2)
    outs = svc.map_batch([
        MapRequest(dfg=make_cnkm(2, 4), cgra=CGRA, tenant="t"),
        MapRequest(dfg=make_cnkm(2, 6), cgra=CGRA, tenant="t"),
    ])
    assert all(o.source == "computed" for o in outs)
    assert all(o.ok for o in outs)


def test_outcome_wall_includes_queueing():
    """ServeOutcome.wall_s is the serve-side completion latency, never
    less than the mapper's own wall time."""
    svc = MappingService(max_workers=1)
    outs = svc.map_batch([MapRequest(dfg=make_cnkm(n, m), cgra=CGRA)
                          for n, m in [(2, 4), (2, 6), (3, 6)]])
    assert all(o.wall_s >= o.result.wall_s for o in outs)


def test_isomorphic_co_tenants_are_not_deduped():
    """Two isomorphic kernels of one tenant are distinct co-resident
    instances — both must be placed (in disjoint regions of the shared
    fabric, in global coordinates), not collapsed onto one
    computation."""
    big = CGRAConfig(rows=16, cols=16)
    opts = dict(max_bus_fanout=4, mis_restarts=4, mis_iters=4000,
                max_ii=10)
    base = make_loop_kernel(n_chains=4, chain_len=4, seed=0)
    svc = MappingService(max_workers=2)
    outs = svc.map_batch([
        MapRequest(dfg=base, cgra=big, options=opts, tenant="t"),
        MapRequest(dfg=permute_dfg(base, seed=2), cgra=big,
                   options=opts, tenant="t"),
    ])
    assert all(o.source == "comap" and o.ok for o in outs)
    pes = [frozenset(v.pe for v in o.result.placement.values()
                     if v.kind == "quad") for o in outs]
    assert not (pes[0] & pes[1])                # disjoint regions


# -------------------------------------------------------------- service
def test_trace_end_to_end_hits_and_metrics():
    svc = MappingService(max_workers=2)
    trace = make_request_trace(14, scale="4x4", seed=3)
    outs = svc.map_batch([MapRequest(dfg=t.dfg, cgra=CGRA,
                                     deadline=t.deadline)
                          for t in trace])
    assert all(o.ok for o in outs)
    m = svc.metrics()
    assert m["requests"] == 14 and m["ok"] == 14
    assert m["hits"] >= 1                  # Zipf head repeats
    assert m["p95_ms"] >= m["p50_ms"] >= 0
    assert m["throughput_rps"] > 0
    assert set(m["sources"]) <= {"computed", "dedupe", "memory", "disk"}
    assert "serve:" in svc.summary()


def test_second_wave_hits_memory():
    svc = MappingService(max_workers=2)
    for wave_seed in (0, 1):
        trace = make_request_trace(8, scale="4x4", seed=0)
        # Re-permute each instance so only canonical hashing can hit.
        reqs = [MapRequest(dfg=permute_dfg(t.dfg, seed=wave_seed * 31 + i),
                           cgra=CGRA, deadline=t.deadline)
                for i, t in enumerate(trace)]
        outs = svc.map_batch(reqs)
    assert all(o.hit for o in outs)        # second wave: all hits
    assert all(o.source in ("memory", "dedupe") for o in outs)


def test_single_request_facade():
    svc = MappingService()
    out = svc.map(make_cnkm(2, 4), CGRA, req_id="one")
    assert out.ok and out.req_id == "one" and not out.hit
    out2 = svc.map(permute_dfg(make_cnkm(2, 4), seed=5), CGRA)
    assert out2.hit and out2.source == "memory"


# ------------------------------------------------------ trace generator
def test_request_trace_deterministic_and_zipf_skewed():
    t1 = make_request_trace(60, scale="4x4", seed=7)
    t2 = make_request_trace(60, scale="4x4", seed=7)
    assert [t.name for t in t1] == [t.name for t in t2]
    counts = collections.Counter(t.name for t in t1)
    specs = serve_catalog("4x4")
    assert counts[specs[0].name] > counts.get(specs[-1].name, 0)


def test_permute_dfg_preserves_structure():
    d = make_loop_kernel(n_chains=3, chain_len=4, n_carries=2, seed=9)
    p = permute_dfg(d, seed=4)
    assert len(p.ops) == len(d.ops) and len(p.edges) == len(d.edges)
    assert sorted(o.kind.value for o in p.ops.values()) == \
        sorted(o.kind.value for o in d.ops.values())
    assert mii(p, CGRA) == mii(d, CGRA)
    assert sorted(p.ops) == sorted(d.ops)   # same id set, reassigned


# ------------------------------------------------- transfer-round wiring
def test_serving_transfer_rounds_wiring():
    from repro.configs import get_smoke_config
    from repro.launch.serve import serving_transfer_rounds

    cfg = get_smoke_config("gemma3-4b")
    rounds, text = serving_transfer_rounds(cfg, batch=4, seq=64)
    assert rounds and all(isinstance(r, list) for r in rounds)
    moving = [name for rnd in rounds for name in rnd]
    assert "tp_partial_out" in moving
    assert "bandwidth round" in text


def test_roofline_transfer_round_depth():
    from benchmarks.roofline import transfer_round_depth

    depth = transfer_round_depth("gemma3-4b", "train_4k", "single")
    assert isinstance(depth, int) and depth >= 1
    assert transfer_round_depth("no-such-arch", "train_4k",
                                "single") is None
    assert transfer_round_depth("gemma3-4b", "train_4k",
                                "no-such-mesh") is None


def test_map_trace_driver():
    from repro.launch.serve import run_map_trace

    m = run_map_trace(6, scale="4x4", rows=4, cols=4, seed=0,
                      max_workers=2, quiet=True)
    assert m["requests"] == 6 and m["ok"] == 6


def test_metrics_p99_queue_depth_and_reset():
    """The `repro.obs.MetricsRegistry`-backed metrics: p99 latency,
    the queue-depth gauge, and `metrics(reset=True)` draining the
    window while leaving the cache intact."""
    svc = MappingService(max_workers=2)
    trace = make_request_trace(10, scale="4x4", seed=3)
    svc.map_batch([MapRequest(dfg=t.dfg, cgra=CGRA, deadline=t.deadline)
                   for t in trace])
    m = svc.metrics()
    assert m["p99_ms"] >= m["p95_ms"] >= m["p50_ms"] >= 0
    assert m["queue_depth"]["last"] == 10     # batch size at admission
    assert m["queue_depth"]["max"] >= m["queue_depth"]["last"]

    # A shared registry is injectable (the obs layer owns the store).
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    assert MappingService(registry=reg).registry is reg

    # reset=True drains the interval window...
    drained = svc.metrics(reset=True)
    assert drained["requests"] == 10
    # ...while the default (lifetime) view survives the drain — a
    # scraping consumer cannot zero `summary()`'s numbers.
    after = svc.metrics()
    assert after["requests"] == 10
    assert "10 requests" in svc.summary()
    # The mapping cache is untouched by a metrics drain: a repeat
    # batch still hits, and the next interval window reports it.
    outs = svc.map_batch([MapRequest(dfg=t.dfg, cgra=CGRA,
                                     deadline=t.deadline)
                          for t in trace])
    assert all(o.hit for o in outs)
    window = svc.metrics(reset=True)
    assert window["requests"] == 10 and window["hit_rate"] == 1.0
    assert svc.metrics()["requests"] == 20
