"""`MapOptions` — the consolidated engine API.

Three contracts: (1) the three call forms (structured `MapOptions`,
option dict, legacy keywords) are interchangeable and bit-identical
through `map_dfg`; (2) `MapOptions.fingerprint` is byte-compatible with
the serve tier's historical option-dict hash, so on-disk cache entries
written before the migration still hit; (3) the portfolio-init hotspot
fix holds — the traced phase breakdown shows constructive-init/engine
construction as a minority share of the mapping wall (it was the
dominant pre-search cost on 16x16-scale graphs before the shared row
cache).
"""

from __future__ import annotations

import hashlib

import pytest

from repro.core import (CertifyOptions, MapOptions, PortfolioOptions,
                        ScheduleOptions, make_cnkm, map_dfg,
                        scale_16x16_loop)
from repro.core.cgra import CGRAConfig
from repro.core.mis import GroupMoveConfig
from repro.core.options import LEGACY_KNOBS
from repro.obs import Tracer
from repro.serve.cache import MappingCache, options_fingerprint
from repro.serve.canon import canonical_form

CGRA = CGRAConfig()


# ------------------------------------------------------------- adapters
def test_three_call_forms_are_bit_identical():
    dfg = make_cnkm(2, 6)
    legacy = map_dfg(dfg, CGRA, seed=3, mis_iters=4000, mis_restarts=6)
    structured = map_dfg(dfg, CGRA, MapOptions(
        seed=3, portfolio=PortfolioOptions(iters=4000, restarts=6)))
    wire = map_dfg(dfg, CGRA, {"seed": 3, "mis_iters": 4000,
                               "mis_restarts": 6})
    assert legacy.ii == structured.ii == wire.ii
    assert legacy.placement == structured.placement == wire.placement
    assert legacy.attempts == structured.attempts == wire.attempts


def test_from_kwargs_routes_every_legacy_knob():
    opts = MapOptions.from_kwargs(
        mode="busmap", seed=9, backend="race", bus_pressure=False,
        max_ii=8, min_ii=2, use_grf=True, max_bus_fanout=3,
        certify=False, certify_budget=1000, n_exact_placements=2,
        static_prepass=False, hall=False, exact_node_budget=500,
        mis_restarts=3, mis_iters=100, engine="device", device_seeds=64,
        group_move=True, row_cache_limit=1 << 20)
    assert opts.mode == "busmap" and opts.seed == 9
    assert opts.backend == "race" and opts.bus_pressure is False
    assert opts.schedule == ScheduleOptions(max_ii=8, min_ii=2,
                                            use_grf=True,
                                            max_bus_fanout=3)
    assert opts.certify == CertifyOptions(
        enabled=False, budget=1000, n_exact_placements=2,
        static_prepass=False, hall=False, exact_node_budget=500)
    assert opts.portfolio.restarts == 3 and opts.portfolio.iters == 100
    assert opts.portfolio.engine == "device"
    assert opts.portfolio.device_seeds == 64
    # group_move=True normalizes to the default config (False -> None).
    assert opts.portfolio.group_move == GroupMoveConfig()
    assert opts.portfolio.row_cache_limit == 1 << 20


def test_round_trip_and_replace():
    opts = MapOptions.from_kwargs(mode="busmap", max_ii=8, seed=4,
                                  mis_iters=999)
    assert MapOptions.from_kwargs(**opts.to_kwargs(sparse=False)) == opts
    bumped = opts.replace(seed=5, certify_budget=10)
    assert bumped.seed == 5 and bumped.certify.budget == 10
    assert bumped.mode == "busmap"
    assert bumped.schedule.max_ii == 8
    assert bumped.portfolio.iters == 999


def test_unknown_keys_warn_and_drop():
    with pytest.warns(UserWarning, match="bogus"):
        opts = MapOptions.from_kwargs(seed=1, bogus=2)
    assert opts.seed == 1


def test_coerce_rejects_mixed_and_bad_types():
    with pytest.raises(TypeError, match="not both"):
        MapOptions.coerce(MapOptions(), {"seed": 1})
    with pytest.raises(TypeError, match="MapOptions"):
        MapOptions.coerce(42)
    with pytest.raises(ValueError, match="engine"):
        PortfolioOptions(engine="fpga")


# ---------------------------------------------------------- fingerprint
def _historical_fp(d: dict) -> str:
    """The serve tier's pre-migration formula, verbatim."""
    return hashlib.sha256(
        repr(sorted(d.items())).encode()).hexdigest()[:12]


# Option dicts the serving scheduler historically produced: request
# options (non-default knobs only — `serve_catalog` traces carry mode /
# budgets / backend) + a resolved seed.
SERVE_DICTS = [
    {"seed": 7},
    {"seed": 0},
    {"mode": "busmap", "seed": 123456},
    {"mode": "busmap", "max_ii": 8, "seed": 5},
    {"backend": "race", "seed": 1},
    {"mis_iters": 500, "mis_restarts": 4, "seed": 2},
    {"certify_budget": 50_000, "max_bus_fanout": 4, "seed": 9},
]


@pytest.mark.parametrize("d", SERVE_DICTS,
                         ids=[repr(sorted(d)) for d in SERVE_DICTS])
def test_fingerprint_matches_historical_bytes(d):
    """Cache keys survive the migration: the sparse legacy-kwarg
    rendering hashes to the exact pre-`MapOptions` fingerprint."""
    assert MapOptions.coerce(d).fingerprint() == _historical_fp(d)
    assert options_fingerprint(d) == _historical_fp(d)
    assert options_fingerprint(MapOptions.coerce(d)) == _historical_fp(d)


def test_on_disk_entries_hit_across_option_forms(tmp_path):
    """An entry stored under a legacy option dict is found by the
    equivalent `MapOptions` lookup (and vice versa) — same key bytes."""
    dfg = make_cnkm(2, 4)
    d = {"mode": "busmap", "seed": 5}
    res = map_dfg(dfg, CGRA, d)
    assert res.ok
    cache = MappingCache(art_dir=str(tmp_path))
    canon = canonical_form(dfg)
    key_dict = cache.store(canon, CGRA, d, res)
    assert key_dict is not None
    opts = MapOptions.coerce(d)
    assert cache.key(canon, CGRA, opts) == key_dict
    hit = MappingCache(art_dir=str(tmp_path)).lookup(canon, CGRA, opts)
    assert hit is not None and hit.result.ok


def test_fingerprint_ignores_explicit_defaults_not_seed():
    base = MapOptions()
    assert base.to_kwargs() == {"seed": 0}
    assert MapOptions.coerce({"seed": 3}).fingerprint() == \
        MapOptions(seed=3).fingerprint()
    assert MapOptions(seed=3).fingerprint() != \
        MapOptions(seed=4).fingerprint()


def test_legacy_knobs_cover_every_field():
    """Every dataclass field is reachable from exactly one legacy name
    (the adapter cannot silently orphan a knob)."""
    import dataclasses
    seen = set()
    for group, field in LEGACY_KNOBS.values():
        holder = {None: MapOptions, "schedule": ScheduleOptions,
                  "certify": CertifyOptions,
                  "portfolio": PortfolioOptions}[group]
        assert field in {f.name for f in dataclasses.fields(holder)}
        seen.add((group, field))
    assert len(seen) == len(LEGACY_KNOBS)
    n_fields = sum(
        1 for cls in (ScheduleOptions, CertifyOptions, PortfolioOptions)
        for _ in dataclasses.fields(cls)) + 4  # mode/seed/backend/bus_p
    assert len(seen) == n_fields


# ------------------------------------------------------ hotspot regression
def test_portfolio_init_no_longer_dominates():
    """PR-8 profiling put portfolio-init (constructive warm starts +
    per-round engine construction, each re-unpacking n^2 adjacency
    rows) at ~2/3 of the 16x16-scale mapping wall.  With the row cache
    memoized on the conflict graph and `greedy_mis` decrementing
    degrees from killed rows only, init must be a minority share."""
    big = CGRAConfig(rows=16, cols=16)
    dfg = scale_16x16_loop(n_chains=4, chain_len=4)
    tr = Tracer()
    res = map_dfg(dfg, big, max_bus_fanout=4, mis_restarts=4,
                  mis_iters=400, certify=False, static_prepass=False,
                  min_ii=5, tracer=tr)
    assert res.ok
    walls: dict[str, float] = {}
    for rec in tr.finished:
        walls[rec.name] = walls.get(rec.name, 0.0) + (rec.t1 - rec.t0)
    total = walls["map-dfg"]
    assert walls["portfolio-init"] < 0.5 * total, walls
