"""Multi-kernel co-mapping subsystem (repro/comap): region geometry,
claim arbitration, merged-binding replay, and end-to-end co-maps — fast
cases on 8x8 in tier-1, the 16x16 scale smoke under ``-m slow``."""

import numpy as np
import pytest

from repro.comap import (Region, arbitrate, co_map, merge_mappings,
                         partition)
from repro.core import (CGRAConfig, make_cnkm, make_loop_kernel,
                        make_reduction, make_stencil, map_dfg)
from repro.core.conflict import QUAD, TIN, TOUT, Vertex
from repro.core.tec import COL, ROW
from repro.core.validate import validate_mapping

BIG = CGRAConfig(rows=16, cols=16)


# ------------------------------------------------------------- geometry
@pytest.mark.parametrize("weights", [[1.0], [3, 5], [10, 7, 4], [1] * 6])
def test_partition_disjoint_cover(weights):
    regions = partition(BIG, weights)
    assert len(regions) == len(weights)
    cells = set()
    for r in regions:
        for rr in r.row_span:
            for cc in r.col_span:
                assert (rr, cc) not in cells
                cells.add((rr, cc))
        assert r.n_pes >= 1
    assert len(cells) == BIG.n_pes          # exact tiling
    for i, a in enumerate(regions):
        for b in regions[i + 1:]:
            assert not a.overlaps(b)


def test_partition_area_tracks_weight():
    r_small, r_big = partition(BIG, [1, 7])
    assert r_big.n_pes > r_small.n_pes


def test_region_config_and_translation():
    reg = Region(r0=4, c0=8, rows=4, cols=8)
    cfg = reg.config(BIG, grf=2)
    assert (cfg.rows, cfg.cols, cfg.grf) == (4, 8, 2)
    assert cfg.lrf == BIG.lrf and cfg.buses_per_scope == BIG.buses_per_scope
    tin = Vertex(0, 7, TIN, 1, 1, port=2, mode="bus")
    tout = Vertex(1, 8, TOUT, 2, 0, port=3)
    quad = Vertex(2, 9, QUAD, 2, 0, pe=(1, 5), drive=(COL, 5))
    assert reg.translate_vertex(tin).port == 6          # 4 + 2
    assert reg.translate_vertex(tout).port == 11        # 8 + 3
    gq = reg.translate_vertex(quad, op=42)
    assert gq.pe == (5, 13) and gq.drive == (COL, 13) and gq.op == 42
    rq = Vertex(3, 9, QUAD, 2, 0, pe=(0, 0), drive=(ROW, 0))
    assert reg.translate_vertex(rq).drive == (ROW, 4)


# -------------------------------------------------------------- arbiter
def _map_pair(cgra, regions, dfgs, ii):
    return [map_dfg(d, reg.config(cgra), min_ii=ii, max_ii=ii)
            for d, reg in zip(dfgs, regions)]


def test_arbiter_accepts_diagonal_regions():
    """Diagonal regions share no rows and no columns, so no port or bus
    scope is common — the arbiter must find nothing to flag."""
    cgra = CGRAConfig(rows=8, cols=8)
    regions = [Region(0, 0, 4, 4), Region(4, 4, 4, 4)]
    results = _map_pair(cgra, regions, [make_cnkm(2, 4), make_cnkm(2, 4)],
                        ii=1)
    assert all(r.ok for r in results)
    rep = arbitrate(regions, results, cgra)
    assert rep.ok, rep.conflicts


def test_arbiter_flags_forced_port_clash():
    """Side-by-side regions share their rows; mapping the same kernel at
    the same seed in both yields mirror-image placements whose fixed
    IPORT/IBUS claims collide."""
    cgra = CGRAConfig(rows=4, cols=8)
    regions = [Region(0, 0, 4, 4), Region(0, 4, 4, 4)]
    results = _map_pair(cgra, regions, [make_cnkm(2, 4), make_cnkm(2, 4)],
                        ii=1)
    assert all(r.ok for r in results)
    rep = arbitrate(regions, results, cgra)
    assert not rep.ok
    assert any("fixed claim clash" in c for c in rep.conflicts)
    assert rep.implicated == {0, 1}


def test_merge_replays_through_validator():
    cgra = CGRAConfig(rows=8, cols=8)
    regions = [Region(0, 0, 4, 4), Region(4, 4, 4, 4)]
    dfgs = [make_cnkm(2, 4), make_cnkm(1, 2)]
    results = _map_pair(cgra, regions, dfgs, ii=1)
    assert all(r.ok for r in results)
    sched, placement = merge_mappings(regions, results)
    assert len(sched.dfg.ops) == sum(len(r.sched.dfg.ops) for r in results)
    assert len(placement) == len(sched.dfg.ops)
    report = validate_mapping(sched, cgra, placement)
    assert report.ok, report.violations
    # PE occupancy stays region-disjoint after translation.
    for oid, v in placement.items():
        if v.kind == QUAD:
            reg = regions[0] if oid < len(results[0].sched.dfg.ops) \
                else regions[1]
            assert v.pe[0] in reg.row_span and v.pe[1] in reg.col_span


# ----------------------------------------------------------- end-to-end
def test_co_map_two_kernels_8x8():
    cgra = CGRAConfig(rows=8, cols=8)
    cm = co_map([make_cnkm(2, 4), make_stencil(points=4, taps=3)], cgra,
                max_ii=8)
    assert cm.ok, cm.summary()
    assert cm.report is not None and cm.report.ok
    assert len({r.ii for r in cm.results}) == 1     # common II
    # merged binding is complete: every op of every kernel is placed
    assert len(cm.placement) == len(cm.sched.dfg.ops)


def test_co_map_rejects_empty():
    with pytest.raises(ValueError):
        co_map([], BIG)


def test_co_map_failure_reports_state():
    """An impossible ask (kernel bigger than its region share at every
    II) fails cleanly with per-region results preserved."""
    tiny = CGRAConfig(rows=2, cols=2)
    cm = co_map([make_cnkm(2, 6), make_cnkm(2, 6)], tiny, max_ii=3)
    assert not cm.ok
    assert cm.report is None           # never reached a merged replay
    assert len(cm.regions) == 2


# ---------------------------------------------------------- 16x16 scale
@pytest.mark.slow
def test_co_map_16x16_generated_kernels():
    """The acceptance scenario: two and three generated kernels
    co-mapped on a 16x16 PEA, merged binding replayed through the
    validator."""
    from repro.core import COMAP_16X16_SPECS
    k1, k2, st = (spec.build() for spec in COMAP_16X16_SPECS)
    cm = co_map([k1, k2], BIG, max_ii=10, max_bus_fanout=4,
                mis_restarts=4, mis_iters=4000)
    assert cm.ok, cm.summary()
    assert cm.report.ok
    assert max(d.rec_mii() for d in (k1, k2)) > 1   # RecMII exercised
    cm3 = co_map([k1, k2, st], BIG, max_ii=10, max_bus_fanout=4,
                 mis_restarts=4, mis_iters=4000)
    assert cm3.ok, cm3.summary()
    assert cm3.report.ok
    assert len(cm3.regions) == 3


@pytest.mark.slow
def test_co_map_16x16_mixed_families():
    cm = co_map([make_loop_kernel(n_chains=4, chain_len=4, n_carries=1,
                                  seed=2),
                 make_reduction(width=8),
                 make_stencil(points=4, taps=3)],
                BIG, max_ii=10, max_bus_fanout=4,
                mis_restarts=4, mis_iters=4000)
    assert cm.ok, cm.summary()
    assert cm.report.ok
