"""The Hall-style joint bus-demand bound (`repro.exact.hall`).

Three layers, mirroring how `bus_pressure_edges` is pinned in
tests/test_validator_invariants.py:

1. **The SDR decision procedure itself** — property-tested against a
   brute-force matcher on random demand families, plus the
   monotonicity laws the conservative third-party union leans on
   (dropping a demand or enlarging a demand set never flips
   satisfiable -> unsatisfiable).
2. **No false conflicts end-to-end** — an accepted mapping found
   without the Hall bound never selects both endpoints of a Hall edge
   (the same subset-of-`_assign_buses`-rejections contract the
   pressure edges carry).
3. **Strictly stronger than pairwise** — the Hall bound subsumes the
   constructed two-router saturation scenario, and catches the
   three-demands-over-two-cells shape `bus_pressure_edges` is
   structurally blind to (each pair fits; the triple cannot).
"""

import itertools

import numpy as np
import pytest

from repro.core import make_cnkm, map_dfg
from repro.core.cgra import CGRAConfig
from repro.core.conflict import (QUAD, TIN, TOUT, Vertex,
                                 build_conflict_graph)
from repro.core.dfg import DFG, OpKind
from repro.core.schedule import ScheduledDFG
from repro.core.tec import COL, ROW
from repro.core.validate import validate_mapping
from repro.exact import hall_pressure_edges, sdr_exists

from _hypothesis_compat import given, settings, st

CGRA = CGRAConfig()


# ------------------------------------------------ the SDR procedure
def _sdr_brute(sets) -> bool:
    """Exhaustive system-of-distinct-representatives check."""
    sets = [list(s) for s in sets]
    if not sets:
        return True
    for choice in itertools.product(*sets):
        if len(set(choice)) == len(choice):
            return True
    return False


def _random_family(seed: int):
    rng = np.random.default_rng(seed)
    n_cells = int(rng.integers(1, 6))
    cells = [(int(k), int(s)) for k in range(2)
             for s in range((n_cells + 1) // 2)][:n_cells]
    n_sets = int(rng.integers(0, 6))
    return [frozenset(c for c in cells if rng.random() < 0.6)
            for _ in range(n_sets)], cells


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=4000))
def test_sdr_matches_brute_force(seed):
    family, _ = _random_family(seed)
    assert sdr_exists(family) == _sdr_brute(family)


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=4000))
def test_sdr_monotone_under_superset_and_removal(seed):
    """The soundness laws the conservative encoding uses: a satisfiable
    family stays satisfiable when any demand set grows (third-party
    union over candidates is a superset of the chosen candidate's set)
    or when demands are dropped (subset families)."""
    family, cells = _random_family(seed)
    if sdr_exists(family):
        for i in range(len(family)):
            grown = list(family)
            grown[i] = frozenset(cells)
            assert sdr_exists(grown)
    else:
        # Contrapositive of removal-monotonicity: an unsatisfiable
        # family has no satisfiable superset-family extension.
        assert not sdr_exists(list(family) + [frozenset(cells)])
    for i in range(len(family)):
        sub = family[:i] + family[i + 1:]
        if not sdr_exists(sub):
            assert not sdr_exists(family)


def test_sdr_empty_demand_is_degenerate_violation():
    assert not sdr_exists([frozenset()])
    assert sdr_exists([])


# --------------------------------------- no false conflicts end-to-end
@pytest.mark.parametrize("n,m,mode", [(2, 6, "busmap"), (3, 6, "busmap"),
                                      (2, 8, "bandmap"),
                                      (5, 5, "bandmap")])
def test_hall_edges_not_in_accepted_mappings(n, m, mode):
    """An accepted mapping found WITHOUT the Hall bound never contains
    both endpoints of a Hall edge: the bound only ever forbids pairs
    `validate_mapping` would reject anyway."""
    r = map_dfg(make_cnkm(n, m), CGRA, mode=mode)
    assert r.ok
    cg_base = build_conflict_graph(r.sched, CGRA, bus_pressure=True)
    cg_hall = build_conflict_graph(r.sched, CGRA, bus_pressure=True)
    n_added = hall_pressure_edges(cg_hall.bits, cg_hall.vertices,
                                  cg_hall.op_vertices, r.sched, CGRA)
    added = cg_hall.bits.to_dense() & ~cg_base.bits.to_dense()
    assert added.any() == (n_added > 0)
    sel = np.zeros(cg_hall.n, dtype=bool)
    idx = {(v.op, v.kind, v.port, v.mode, v.pe, v.drive): v.idx
           for v in cg_hall.vertices}
    for oid, v in r.placement.items():
        sel[idx[(v.op, v.kind, v.port, v.mode, v.pe, v.drive)]] = True
    assert not added[np.ix_(sel, sel)].any(), \
        "Hall edge inside a validator-accepted placement"


# ----------------------------------------- strictly stronger shapes
def test_hall_subsumes_two_router_saturation():
    """On the constructed pairwise scenario (two forced drives pinned
    to one surviving cell) the Hall bound finds the same edge
    `bus_pressure_edges` does — it generalises, not sidesteps, the
    pairwise cases."""
    from test_validator_invariants import (_two_router_scenario,
                                           _vertex_index)

    sched, placement, (r1, r2) = _two_router_scenario()
    cg = build_conflict_graph(sched, CGRA, bus_pressure=False)
    idx = _vertex_index(cg)
    i1 = idx[(r1, QUAD, -1, "", (0, 0), (COL, 0))]
    i2 = idx[(r2, QUAD, -1, "", (1, 0), (COL, 0))]
    assert not cg.bits.has_edge(i1, i2)
    n_added = hall_pressure_edges(cg.bits, cg.vertices, cg.op_vertices,
                                  sched, CGRA)
    assert n_added > 0
    assert cg.bits.has_edge(i1, i2)


def _three_router_scenario():
    """Tall fabric (8x4), II=2: three routing ops forced to drive in
    modulo slot 1, with a placement putting all three in column 0 —
    three demands over that column's two surviving (bus, cycle) cells
    {(0, 1), (1, 1)}.  Every *pair* fits (two buses), so
    `bus_pressure_edges` adds nothing; the triple cannot, which is
    exactly Hall's condition."""
    cgra = CGRAConfig(rows=8, cols=4)
    d = DFG()
    vins = [d.add_op(OpKind.VIN) for _ in range(3)]
    routes = [d.add_op(OpKind.ROUTE, latency=2) for _ in range(3)]
    cons = [d.add_op(OpKind.COMPUTE) for _ in range(3)]
    for vin, r, c in zip(vins, routes, cons):
        d.add_edge(vin, r)
        d.add_edge(r, c)
    time = {}
    for i in range(3):
        time[vins[i]] = 0
        time[routes[i]] = 1
        time[cons[i]] = 3
    sched = ScheduledDFG(d, 2, 2, time,
                         {v: "bus" for v in vins}, {})
    placement = {}
    for i in range(3):
        placement[vins[i]] = Vertex(-1, vins[i], TIN, 0, 0, port=i,
                                    mode="bus")
        placement[routes[i]] = Vertex(-1, routes[i], QUAD, 1, 1,
                                      pe=(i, 0), drive=(COL, 0))
        placement[cons[i]] = Vertex(-1, cons[i], QUAD, 3, 1,
                                    pe=(3 + i, 0))
    return cgra, sched, placement, routes


def test_hall_catches_three_demands_over_two_cells():
    cgra, sched, placement, routes = _three_router_scenario()
    cg = build_conflict_graph(sched, cgra, bus_pressure=True)
    idx = {(v.op, v.kind, v.port, v.mode, v.pe, v.drive): v.idx
           for v in cg.vertices}
    iv = [idx[(r, QUAD, -1, "", (i, 0), (COL, 0))]
          for i, r in enumerate(routes)]
    # Pairwise bound is blind: each route still has two feasible cells.
    for a, b in itertools.combinations(iv, 2):
        assert not cg.bits.has_edge(a, b)
    # ... and the full placement is conflict-free on the pairwise graph
    sel = np.zeros(cg.n, dtype=bool)
    for oid, v in placement.items():
        sel[idx[(v.op, v.kind, v.port, v.mode, v.pe, v.drive)]] = True
    assert sel.sum() == len(sched.dfg.ops)
    assert not cg.bits.to_dense()[np.ix_(sel, sel)].any()
    # ... but the validator rejects it on bus capacity,
    report = validate_mapping(sched, cgra, placement)
    assert not report.ok
    assert any("bus congestion" in v for v in report.violations)
    # ... and the Hall bound sees it up front IF the third route is
    # grid-implied.  Pin every third-route candidate that does not
    # drive (COL, 0) by doctoring adjacency (in a real instance the
    # rest of the graph does this), then the pair (r1@col0, r2@col0)
    # implies a third same-grid demand: 3 demands, 2 cells, no SDR.
    for r in routes:
        for vi in cg.op_vertices[r]:
            v = cg.vertices[vi]
            if v.drive != (COL, 0):
                for other in routes:
                    if other != r:
                        for ui in cg.op_vertices[other]:
                            if cg.vertices[ui].drive == (COL, 0):
                                cg.bits.add_edge(vi, ui)
    n_added = hall_pressure_edges(cg.bits, cg.vertices, cg.op_vertices,
                                  sched, cgra)
    assert n_added > 0
    assert any(cg.bits.has_edge(a, b)
               for a, b in itertools.combinations(iv, 2))
