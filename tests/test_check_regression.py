"""The bench-regression gate (`benchmarks/check_regression.py`) must
fail loudly when a whole baseline section vanishes from the fresh JSON
(a benchmark that silently stopped running), while retired individual
rows stay informational."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import SECTIONS, check  # noqa: E402


def _bench(wall=1.0, sections=("kernel_table",), kernels=("C2K6",)):
    return {s: [dict(kernel=k, mode="bandmap", wall_s=wall)
                for k in kernels] for s in sections}


def test_clean_pass():
    assert check(_bench(), _bench()) == []


def test_regression_fails():
    failures = check(_bench(wall=1.0), _bench(wall=9.0))
    assert failures and "exceeds" in failures[0]


def test_missing_row_is_note_not_failure():
    base = _bench(kernels=("C2K6", "C5K5"))
    fresh = _bench(kernels=("C2K6",))
    assert check(base, fresh) == []


def test_new_section_in_fresh_is_fine():
    base = _bench(sections=("kernel_table",))
    fresh = _bench(sections=("kernel_table", "group_move"))
    assert check(base, fresh) == []


def test_missing_section_fails_loudly():
    base = _bench(sections=("kernel_table", "group_move"))
    fresh = _bench(sections=("kernel_table",))
    failures = check(base, fresh)
    assert len(failures) == 1
    assert "group_move" in failures[0] and "missing" in failures[0]


def test_empty_section_counts_as_missing():
    base = _bench(sections=("comap",))
    fresh = dict(_bench(sections=("comap",)), comap=[])
    failures = check(base, fresh)
    assert len(failures) == 1 and "comap" in failures[0]


def test_machine_speed_scaling_loosens_budget():
    base = _bench(wall=1.0)
    base["engine_speedup"] = dict(seed_solve_s=1.0)
    fresh = _bench(wall=3.0)
    fresh["engine_speedup"] = dict(seed_solve_s=2.0)   # machine 2x slower
    assert check(base, fresh) == []                    # 3.0 < 2 * 2 * 1.0


def test_group_move_section_is_gated():
    assert "group_move" in SECTIONS
    base = _bench(sections=("group_move",))
    fresh = _bench(sections=("group_move",), wall=9.0)
    assert check(base, fresh)
