"""The bench-regression gate (`benchmarks/check_regression.py`) must
fail loudly when a whole baseline section vanishes from the fresh JSON
(a benchmark that silently stopped running), while retired individual
rows stay informational.  Rows carrying ``counters`` (the traced
kernel_table and device_engine rows) are additionally gated on each
deterministic counter — tighter factor, no machine-speed scaling,
missing counter = failure — and rows carrying ``phases`` on phase
*presence* (a vanished phase is lost instrumentation)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import SECTIONS, check  # noqa: E402


def _bench(wall=1.0, sections=("kernel_table",), kernels=("C2K6",),
           counters=None, phases=None):
    return {s: [dict(kernel=k, mode="bandmap", wall_s=wall,
                     **({"counters": dict(counters)} if counters
                        else {}),
                     **({"phases": {p: dict(count=1, total_s=0.1)
                                    for p in phases}} if phases
                        else {}))
                for k in kernels] for s in sections}


def test_clean_pass():
    assert check(_bench(), _bench()) == []


def test_regression_fails():
    failures = check(_bench(wall=1.0), _bench(wall=9.0))
    assert failures and "exceeds" in failures[0]


def test_missing_row_is_note_not_failure():
    base = _bench(kernels=("C2K6", "C5K5"))
    fresh = _bench(kernels=("C2K6",))
    assert check(base, fresh) == []


def test_new_section_in_fresh_is_fine():
    base = _bench(sections=("kernel_table",))
    fresh = _bench(sections=("kernel_table", "group_move"))
    assert check(base, fresh) == []


def test_missing_section_fails_loudly():
    base = _bench(sections=("kernel_table", "group_move"))
    fresh = _bench(sections=("kernel_table",))
    failures = check(base, fresh)
    assert len(failures) == 1
    assert "group_move" in failures[0] and "missing" in failures[0]


def test_empty_section_counts_as_missing():
    base = _bench(sections=("comap",))
    fresh = dict(_bench(sections=("comap",)), comap=[])
    failures = check(base, fresh)
    assert len(failures) == 1 and "comap" in failures[0]


def test_machine_speed_scaling_loosens_budget():
    base = _bench(wall=1.0)
    base["engine_speedup"] = dict(seed_solve_s=1.0)
    fresh = _bench(wall=3.0)
    fresh["engine_speedup"] = dict(seed_solve_s=2.0)   # machine 2x slower
    assert check(base, fresh) == []                    # 3.0 < 2 * 2 * 1.0


def test_group_move_section_is_gated():
    assert "group_move" in SECTIONS
    base = _bench(sections=("group_move",))
    fresh = _bench(sections=("group_move",), wall=9.0)
    assert check(base, fresh)


# -------------------------------------------------------- counter gate

def test_counter_within_budget_passes():
    base = _bench(counters={"certify_csp_nodes": 1000})
    fresh = _bench(counters={"certify_csp_nodes": 1200})   # < 1.25x
    assert check(base, fresh) == []


def test_counter_regression_fails():
    base = _bench(counters={"certify_csp_nodes": 1000,
                            "portfolio_iters": 800})
    fresh = _bench(counters={"certify_csp_nodes": 2000,
                             "portfolio_iters": 800})
    failures = check(base, fresh)
    assert len(failures) == 1
    assert "certify_csp_nodes" in failures[0]
    assert "counter budget" in failures[0]


def test_missing_counter_fails():
    base = _bench(counters={"certify_csp_nodes": 1000,
                            "portfolio_iters": 800})
    fresh = _bench(counters={"certify_csp_nodes": 1000})
    failures = check(base, fresh)
    assert len(failures) == 1
    assert "portfolio_iters" in failures[0]
    assert "instrumentation" in failures[0]


def test_sub_floor_counter_jump_passes():
    # 10 -> 40 CSP nodes is noise-free but meaningless; the absolute
    # floor (default 500) absorbs it.
    base = _bench(counters={"certify_csp_nodes": 10})
    fresh = _bench(counters={"certify_csp_nodes": 40})
    assert check(base, fresh) == []
    # ...but past the floor the tighter factor applies, unscaled by
    # machine speed.
    base["engine_speedup"] = dict(seed_solve_s=1.0)
    slow = _bench(counters={"certify_csp_nodes": 700})
    slow["engine_speedup"] = dict(seed_solve_s=4.0)
    assert check(base, slow)  # 700 > 1.25 * max(10, 500) despite scale


def test_counterless_rows_skip_the_gate():
    base = _bench(counters={"certify_csp_nodes": 1000})
    fresh = _bench()   # fresh row dropped its counters dict entirely
    failures = check(base, fresh)
    assert failures and "instrumentation" in failures[0]


def test_device_engine_counters_are_gated():
    base = _bench(sections=("device_engine",),
                  counters={"portfolio_iters": 1000})
    fresh = _bench(sections=("device_engine",),
                   counters={"portfolio_iters": 2000})
    failures = check(base, fresh)
    assert failures and "device_engine" in failures[0]
    # Missing the counter entirely fails the instrumentation-loss way.
    bare = _bench(sections=("device_engine",))
    failures = check(base, bare)
    assert failures and "instrumentation" in failures[0]


# -------------------------------------------------- phase-presence gate

def test_matching_phases_pass():
    base = _bench(phases=("certify", "portfolio"))
    fresh = _bench(phases=("portfolio", "certify"))
    assert check(base, fresh) == []


def test_vanished_phase_fails():
    base = _bench(phases=("certify", "portfolio", "validate"))
    fresh = _bench(phases=("certify", "portfolio"))
    failures = check(base, fresh)
    assert len(failures) == 1
    assert "'validate'" in failures[0]
    assert "instrumentation" in failures[0]


def test_new_phase_in_fresh_is_fine():
    base = _bench(phases=("certify",))
    fresh = _bench(phases=("certify", "static-prepass"))
    assert check(base, fresh) == []


def test_phases_of_retired_row_are_not_gated():
    base = _bench(kernels=("C2K6", "C5K5"), phases=("certify",))
    fresh = _bench(kernels=("C2K6",), phases=("certify",))
    assert check(base, fresh) == []      # retired row, not lost phases
