"""Validator invariants and the bus-pressure conflict edges.

Three pillars:

1. **Replay** — for every accepted mapping on the quick paper kernels,
   re-play the returned ``bus_assignment`` against the fixed VIO/VOO
   drives and assert at most one driver per (bus, cycle), with every
   drive inside its edge's schedule window.
2. **No false conflicts** — bus-pressure edges are a *subset* of what
   `_assign_buses` rejects: an accepted mapping found without pressure
   edges never contains both endpoints of a pressure edge, and with the
   flag off the adjacency is byte-identical to the dense oracle rules.
3. **Capacity is config** — `CGRAConfig.buses_per_scope` is the single
   source of truth: a constructed two-router scenario saturating the
   OBUS cells is rejected at capacity 2 (and pairwise-forbidden by the
   pressure edges), and accepted — with the pressure edges dissolving —
   at capacity 3.

Plus the GRF-residency regression: a distance>=1 consumer of a
GRF-parked VIO extends the park window by distance * II cycles.
"""

import numpy as np
import pytest

from repro.core import make_cnkm, map_dfg, schedule_dfg
from repro.core.cgra import CGRAConfig
from repro.core.conflict import (QUAD, TIN, TOUT, Vertex, _dep_ok,
                                 build_conflict_graph,
                                 dense_conflicts_python)
from repro.core.dfg import DFG, OpKind
from repro.core.schedule import ScheduledDFG
from repro.core.tec import COL, ROW, TEC
from repro.core.validate import validate_mapping

CGRA = CGRAConfig()
QUICK = [(1, 2), (2, 4), (2, 6), (3, 6), (4, 4)]


def _fixed_drives(placement):
    used = {}
    for oid, v in placement.items():
        if v.kind == TIN and v.mode == "bus":
            used[(ROW, v.port, 0, v.m)] = ("vio", oid)
        elif v.kind == TOUT:
            used[(COL, v.port, 0, v.m)] = ("voo", oid)
    return used


def _replay_bus_assignment(r, cgra):
    """Assert <=1 driver per (bus, cycle) incl. fixed drives, and every
    flexible drive inside its edge's schedule window and scope."""
    sched, placement = r.sched, r.placement
    ii = sched.ii
    used = _fixed_drives(placement)
    assert len(used) == sum(
        1 for v in placement.values()
        if (v.kind == TIN and v.mode == "bus") or v.kind == TOUT), \
        "fixed VIO/VOO drives collide"
    driver_of = {}
    for (src, dst), key in r.report.bus_assignment.items():
        scope, idx, k, slot = key
        assert 0 <= k < cgra.buses_per_scope
        assert key not in used, f"flexible drive collides with fixed {key}"
        # one driver per (bus, cycle): a key may be shared only as the
        # broadcast of a single producer
        assert driver_of.setdefault(key, src) == src, \
            f"two producers drive {key}"
        pv, cv = placement[src], placement[dst]
        t_ready = sched.time[src] + sched.dfg.ops[src].latency
        t_use = sched.time[dst] + next(
            e.distance for e in sched.dfg.edges
            if e.src == src and e.dst == dst) * ii
        window = range(t_ready, min(t_use, t_ready + ii - 1) + 1)
        assert slot in {t % ii for t in window}
        if pv.drive is not None:
            assert (scope, idx) == pv.drive
        else:
            assert (scope, idx) in {(ROW, pv.pe[0]), (COL, pv.pe[1])}
            assert (idx == cv.pe[0] if scope == ROW else idx == cv.pe[1])


@pytest.mark.parametrize("mode", ["bandmap", "busmap"])
@pytest.mark.parametrize("n,m", QUICK)
def test_accepted_mappings_replay(n, m, mode):
    r = map_dfg(make_cnkm(n, m), CGRA, mode=mode)
    assert r.ok
    _replay_bus_assignment(r, CGRA)


@pytest.mark.parametrize("n,m,mode", [(2, 6, "busmap"), (3, 6, "busmap"),
                                      (2, 8, "bandmap")])
def test_pressure_edges_not_in_accepted_mappings(n, m, mode):
    """An accepted mapping found WITHOUT pressure edges never selects
    both endpoints of a pressure edge (no false conflicts)."""
    r = map_dfg(make_cnkm(n, m), CGRA, mode=mode, bus_pressure=False)
    assert r.ok
    sched = r.sched
    cg_off = build_conflict_graph(sched, CGRA, bus_pressure=False)
    cg_on = build_conflict_graph(sched, CGRA, bus_pressure=True)
    added = cg_on.bits.to_dense() & ~cg_off.bits.to_dense()
    sel = np.zeros(cg_on.n, dtype=bool)
    vert_idx = {(v.op, v.kind, v.port, v.mode, v.pe, v.drive): v.idx
                for v in cg_on.vertices}
    for oid, v in r.placement.items():
        sel[vert_idx[(v.op, v.kind, v.port, v.mode, v.pe, v.drive)]] = True
    assert not added[np.ix_(sel, sel)].any()


@pytest.mark.parametrize("n,m,mode", [(2, 6, "busmap"), (5, 5, "busmap"),
                                      (2, 8, "bandmap")])
def test_adjacency_byte_identical_with_pressure_disabled(n, m, mode):
    """Flag off => byte-equal to the dense oracle rules (group cliques +
    dependency realizability), the seed formulation."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode)
    cg = build_conflict_graph(sched, CGRA, bus_pressure=False)
    ref = dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
    for src, dst in {(e.src, e.dst) for e in sched.dfg.edges}:
        for i in cg.op_vertices[src]:
            for j in cg.op_vertices[dst]:
                if not _dep_ok(cg.vertices[i], cg.vertices[j]):
                    ref[i, j] = ref[j, i] = True
    np.testing.assert_array_equal(cg.bits.to_dense(), ref)


# ------------------------------------------------- constructed scenario
def _two_router_scenario():
    """4x4 CGRA, II=2.  Two routing ops (latency 2, slot 1) each with a
    same-slot consumer whose drive window collapses to slot 1, while
    eight VOOs saturate every OBUS bus-0 cell: any placement where both
    routers drive the same column demands two drives from the single
    surviving (bus, cycle) cell of that column."""
    d = DFG()
    vin0, vin1 = d.add_op(OpKind.VIN), d.add_op(OpKind.VIN)
    r1 = d.add_op(OpKind.ROUTE, latency=2)
    r2 = d.add_op(OpKind.ROUTE, latency=2)
    c1, c2 = d.add_op(OpKind.COMPUTE), d.add_op(OpKind.COMPUTE)
    vouts = [d.add_op(OpKind.VOUT) for _ in range(8)]
    d.add_edge(vin0, r1)
    d.add_edge(r1, c1)
    d.add_edge(vin1, r2)
    d.add_edge(r2, c2)
    time = {vin0: 0, vin1: 0, r1: 1, r2: 1, c1: 3, c2: 3}
    for i, v in enumerate(vouts):
        time[v] = 2 if i < 4 else 3
    sched = ScheduledDFG(d, 2, 2, time,
                         {vin0: "bus", vin1: "bus"}, {})
    placement = {
        vin0: Vertex(-1, vin0, TIN, 0, 0, port=0, mode="bus"),
        vin1: Vertex(-1, vin1, TIN, 0, 0, port=1, mode="bus"),
        r1: Vertex(-1, r1, QUAD, 1, 1, pe=(0, 0), drive=(COL, 0)),
        r2: Vertex(-1, r2, QUAD, 1, 1, pe=(1, 0), drive=(COL, 0)),
        c1: Vertex(-1, c1, QUAD, 3, 1, pe=(2, 0)),
        c2: Vertex(-1, c2, QUAD, 3, 1, pe=(3, 0)),
    }
    for i, v in enumerate(vouts):
        placement[v] = Vertex(-1, v, TOUT, time[v], time[v] % 2,
                              port=i % 4)
    return sched, placement, (r1, r2)


def _vertex_index(cg):
    return {(v.op, v.kind, v.port, v.mode, v.pe, v.drive): v.idx
            for v in cg.vertices}


def test_pressure_edge_is_subset_of_assign_buses_rejections():
    """The constructed scenario: conflict-free without pressure edges,
    rejected by `_assign_buses` — and exactly that pair becomes a
    pressure edge."""
    sched, placement, (r1, r2) = _two_router_scenario()
    cg_off = build_conflict_graph(sched, CGRA, bus_pressure=False)
    idx = _vertex_index(cg_off)
    sel = np.zeros(cg_off.n, dtype=bool)
    for oid, v in placement.items():
        sel[idx[(v.op, v.kind, v.port, v.mode, v.pe, v.drive)]] = True
    assert sel.sum() == len(sched.dfg.ops)
    adj_off = cg_off.bits.to_dense()
    assert not adj_off[np.ix_(sel, sel)].any(), \
        "scenario must be a complete MIS without pressure edges"
    # ... which the validator rejects on bus capacity:
    report = validate_mapping(sched, CGRA, placement)
    assert not report.ok
    assert any("bus congestion" in v for v in report.violations)
    # ... and the pressure edges forbid exactly that pair up front:
    cg_on = build_conflict_graph(sched, CGRA, bus_pressure=True)
    i1 = idx[(r1, QUAD, -1, "", (0, 0), (COL, 0))]
    i2 = idx[(r2, QUAD, -1, "", (1, 0), (COL, 0))]
    assert cg_on.bits.has_edge(i1, i2)
    assert not cg_off.bits.has_edge(i1, i2)


def test_buses_per_scope_threads_through_capacity():
    """One extra routing bus per scope makes the same placement valid,
    and the pressure edges dissolve — capacity comes from CGRAConfig."""
    sched, placement, (r1, r2) = _two_router_scenario()
    wide = CGRAConfig(buses_per_scope=3)
    assert len(TEC(wide, 2).buses(COL, 0)) == 3
    assert len(TEC(CGRA, 2).buses(COL, 0)) == 2
    report = validate_mapping(sched, wide, placement)
    assert report.ok, report.violations
    cg_wide = build_conflict_graph(sched, wide, bus_pressure=True)
    cg_off = build_conflict_graph(sched, wide, bus_pressure=False)
    np.testing.assert_array_equal(cg_wide.bits.to_dense(),
                                  cg_off.bits.to_dense())


# ------------------------------------------------------ GRF regression
def test_grf_residency_counts_inter_iteration_distance():
    """A distance>=1 consumer of a GRF-parked VIO parks the datum for
    distance * II extra cycles; the old successor-slot-only window
    underestimated exactly this (GRF peak 1 instead of 4 here)."""
    d = DFG()
    vin = d.add_op(OpKind.VIN)
    c = d.add_op(OpKind.COMPUTE)
    d.add_edge(vin, c, distance=3)
    sched = ScheduledDFG(d, 2, 1, {vin: 0, c: 1}, {vin: "grf"}, {})
    cgra = CGRAConfig(grf=2)
    placement = {
        vin: Vertex(-1, vin, TIN, 0, 0, port=0, mode="grf"),
        c: Vertex(-1, c, QUAD, 1, 1, pe=(0, 0)),
    }
    report = validate_mapping(sched, cgra, placement)
    # park window [0, 1 + 3*2] = 8 cycles over II=2 -> 4 live per slot
    assert report.grf_peak == 4
    assert not report.ok
    assert any("GRF overflow" in v for v in report.violations)
    # enough capacity -> accepted, same peak
    report_ok = validate_mapping(sched, CGRAConfig(grf=4), placement)
    assert report_ok.grf_peak == 4
    assert report_ok.ok, report_ok.violations
