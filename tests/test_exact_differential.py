"""Differential-testing oracle: the exact backend vs the portfolio.

The exact backend (`repro.exact.backend`) and the stochastic portfolio
(`bandmap.map_dfg`) search the *same* deterministic (II, jitter)
schedule family when given the same seed, so two oracle relations must
hold on every instance where the prover terminates in budget:

1. **The portfolio never beats the exact II.**  A portfolio success at
   a lower II than a proven-optimal exact II would be a soundness bug
   in one of the two engines (a phantom certificate, a validator
   disagreement, or a conflict edge excluding a validatable placement
   — including the Hall bound, which runs on the exact side only).
2. **Exact accepts are real mappings.**  Every exact success replays
   through `validate_mapping` and carries a full-coverage placement.

Both directions run over all `PAPER_KERNELS` and one small instance of
every `workloads.FAMILIES` generator, in both modes — the kernel set
the rest of the suite leans on, now with proven-optimal IIs.

The UNSAT side of the oracle is exercised through the one relation the
certificates make checkable: on an instance the exact backend proves
infeasible up to some ``max_ii``, the portfolio must also fail there
(a portfolio success would contradict the proof).

Finally, the validator-equivariance property the exact backend's
symmetry-pruned UNSAT claim rests on (see `certify._search_complete`):
`validate_mapping`'s verdict is invariant under the fabric's row and
column relabelings, so rejecting a symmetry-orbit representative
rejects the whole orbit.
"""

import dataclasses

import pytest

from repro.core import (CGRAConfig, make_cnkm, map_dfg, mii,
                        all_paper_kernels, workloads)
from repro.core.certify import _axis_swap_perm
from repro.core.conflict import build_conflict_graph
from repro.core.validate import validate_mapping

CGRA = CGRAConfig()
MODES = ["bandmap", "busmap"]

# One small instance per workload family — big enough to route through
# buses, small enough that the prover decides every combination fast.
FAMILY_CASES = [
    ("loop", dict(n_chains=2, chain_len=3, n_inputs=2, n_outputs=1,
                  seed=1)),
    ("stencil", dict(points=3, taps=3, seed=1)),
    ("reduction", dict(width=6, arity=2, seed=1)),
    ("cnkm", dict(n=3, m=5)),
    ("tight", dict(n_vios=4, fanout=3, cross_links=1, n_outputs=1,
                   link_run=2, seed=1)),
]

PAPER_CASES = sorted(all_paper_kernels().items())


def _instances():
    for name, dfg in PAPER_CASES:
        yield pytest.param(dfg, id=name)
    for fam, kw in FAMILY_CASES:
        yield pytest.param(workloads.FAMILIES[fam](**kw), id=fam)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dfg", list(_instances()))
def test_portfolio_never_beats_exact(dfg, mode):
    """Oracle relations 1 and 2 on every instance, same seed both
    sides.  The exact side must terminate with a claim (these
    instances are sized for it); the portfolio may fail, but a success
    below a proven-optimal exact II is a bug somewhere in the engine."""
    ex = map_dfg(dfg, CGRA, mode=mode, backend="exact")
    assert ex.backend == "exact"
    assert ex.ok, f"exact backend failed: {ex.summary()}"
    assert ex.optimal, "prover must decide these instances in budget"
    assert ex.ii >= ex.mii
    assert ex.report is not None and ex.report.ok
    assert len(ex.placement) == ex.n_ops
    po = map_dfg(dfg, CGRA, mode=mode)
    if po.ok:
        assert po.ii >= ex.ii, (
            f"portfolio II {po.ii} beats proven-optimal {ex.ii}")


@pytest.mark.parametrize("mode", MODES)
def test_exact_unsat_implies_portfolio_failure(mode):
    """C5K5 capped below its proven-optimal II: the prover certifies
    the whole range and the portfolio, searching the same schedule
    family, must agree by failing."""
    dfg = make_cnkm(5, 5)
    cap = 2  # proven optimum is 3 in both modes (golden table)
    ex = map_dfg(dfg, CGRA, mode=mode, max_ii=cap, backend="exact")
    assert not ex.ok and ex.proved_infeasible
    # busmap schedules at II=2 and needs real certificates; bandmap
    # can't even schedule there (vacuously UNSAT, nothing to certify).
    assert ex.certificates or ex.sched is None
    po = map_dfg(dfg, CGRA, mode=mode, max_ii=cap)
    assert not po.ok, "portfolio success would contradict the proof"


def test_exact_optimal_at_mii_is_absolute():
    """An exact success at II == MII needs no lower-II certificates:
    MII is a sound lower bound for any modulo schedule."""
    ex = map_dfg(make_cnkm(2, 4), CGRA, backend="exact")
    assert ex.ok and ex.optimal
    assert ex.ii == ex.mii == mii(make_cnkm(2, 4), CGRA)


# ------------------------------------------ validator equivariance
def _permute_placement(res, perm, cg):
    by_idx = {v.idx: v for v in cg.vertices}
    idx_of = {(v.op, v.kind, v.port, v.mode, v.pe, v.drive): v.idx
              for v in cg.vertices}
    out = {}
    for oid, v in res.placement.items():
        i = idx_of[(v.op, v.kind, v.port, v.mode, v.pe, v.drive)]
        out[oid] = by_idx[int(perm[i])]
    return out


@pytest.mark.parametrize("axis,a,b", [("row", 0, 1), ("row", 0, 3),
                                      ("col", 0, 1), ("col", 1, 2)])
def test_validator_equivariant_under_fabric_relabeling(axis, a, b):
    """Swap two fabric rows (or columns) of an accepted mapping via the
    same vertex permutation the symmetry-pruned CSP uses: the validator
    must still accept.  This is the property that makes an orbit
    representative's rejection stand for its whole orbit — the exact
    backend's UNSAT-by-exhaustion claim depends on it."""
    res = map_dfg(make_cnkm(3, 6), CGRA, mode="busmap", backend="exact")
    assert res.ok
    cg = build_conflict_graph(res.sched, CGRA, bus_pressure=True)
    perm = _axis_swap_perm(cg.vertices, axis, a, b)
    assert perm is not None, "candidate sets must be axis-uniform here"
    placement = _permute_placement(res, perm, cg)
    assert placement != res.placement
    report = validate_mapping(res.sched, CGRA, placement)
    assert report.ok, report.violations


def test_validator_equivariant_on_rejections():
    """The other half of equivariance: a *rejected* placement stays
    rejected (with the same violation class) under a fabric
    relabeling.  Reuses the constructed two-router congestion scenario
    from tests/test_validator_invariants.py."""
    from test_validator_invariants import _two_router_scenario

    sched, placement, _ = _two_router_scenario()
    base = validate_mapping(sched, CGRA, placement)
    assert not base.ok
    assert any("bus congestion" in v for v in base.violations)
    # Swap fabric rows 0 and 3: pe rows, TIN delivery ports and ROW
    # drives move together (the scenario's drives are COL, its TIN
    # ports are rows 0/1 — swap 0<->3 moves one of them).
    sw = {0: 3, 3: 0}

    def relab(v):
        port, pe, drive = v.port, v.pe, v.drive
        if v.kind == "tin":
            port = sw.get(port, port)
        elif v.kind == "quad":
            pe = (sw.get(pe[0], pe[0]), pe[1])
            if drive is not None and drive[0] == "row":
                drive = (drive[0], sw.get(drive[1], drive[1]))
        return dataclasses.replace(v, port=port, pe=pe, drive=drive)

    moved = {oid: relab(v) for oid, v in placement.items()}
    assert moved != placement
    rep = validate_mapping(sched, CGRA, moved)
    assert not rep.ok
    assert any("bus congestion" in v for v in rep.violations)
