"""Serve-tier observability: Prometheus exposition with labels, the
per-request access log, the service flight recorder (admit / reject /
crash stream), head-sampled tracing, and the worker crash path."""

import pytest

from repro.core import (CGRAConfig, make_cnkm, make_request_trace,
                        permute_dfg)
from repro.core.dfg import DFG, OpKind
from repro.obs import ACCESS_LOG_FIELDS, parse_prometheus
from repro.serve import MappingService, MapRequest

CGRA = CGRAConfig()


def _dense_vio(n: int = 8) -> DFG:
    """Statically unmappable at max_ii=2 (demand floor > range)."""
    d = DFG()
    vins = [d.add_op(OpKind.VIN, f"v{i}") for i in range(n)]
    for i in range(n - 1):
        x = d.add_op(OpKind.COMPUTE, f"x{i}")
        d.add_edge(vins[i], x)
        d.add_edge(vins[i + 1], x)
        o = d.add_op(OpKind.VOUT, f"o{i}")
        d.add_edge(x, o)
    return d


# ------------------------------------------------------------ prometheus
def test_prometheus_exposition_with_shard_label():
    svc = MappingService(max_workers=2, shard="7")
    trace = make_request_trace(10, scale="4x4", seed=3)
    svc.map_batch([MapRequest(dfg=t.dfg, cgra=CGRA, deadline=t.deadline)
                   for t in trace])
    parsed = parse_prometheus(svc.prometheus())
    labels = {"shard": "7"}
    assert parsed["bandmap_requests"] == [(labels, 10.0)]
    assert parsed["bandmap_queue_depth"] == [(labels, 10.0)]
    hit_rate = parsed["bandmap_hit_rate"][0]
    assert hit_rate[0] == labels and 0.0 <= hit_rate[1] <= 1.0
    lat_qs = {lab["quantile"] for lab, _ in parsed["bandmap_latency_s"]}
    assert lat_qs == {"0.5", "0.95", "0.99"}
    # An explicit label set overrides the shard default.
    parsed2 = parse_prometheus(svc.prometheus(labels={"worker": "a"}))
    assert parsed2["bandmap_requests"] == [({"worker": "a"}, 10.0)]


def test_prometheus_never_drains_the_registry():
    svc = MappingService(max_workers=1)
    svc.map(make_cnkm(2, 4), CGRA)
    before = svc.metrics()["requests"]
    svc.prometheus()
    svc.prometheus()
    assert svc.metrics()["requests"] == before == 1
    # ...and a metrics scrape draining the window doesn't zero the
    # exposition either (it renders the cumulative view).
    svc.metrics(reset=True)
    parsed = parse_prometheus(svc.prometheus())
    assert parsed["bandmap_requests"][0][1] == 1.0


@pytest.mark.slow
def test_prometheus_over_200_request_serve_trace():
    """Acceptance: a 200-request Zipf trace exposes hit-rate, p99
    latency and the queue-depth gauge, labeled by shard."""
    svc = MappingService(max_workers=4, shard="0")
    trace = make_request_trace(200, scale="4x4", seed=11)
    outs = svc.map_batch([
        MapRequest(dfg=t.dfg, cgra=CGRA, deadline=t.deadline,
                   options=dict(mis_restarts=4, mis_iters=4000),
                   req_id=f"r{i}")
        for i, t in enumerate(trace)])
    assert len(outs) == 200
    parsed = parse_prometheus(svc.prometheus())
    labels = {"shard": "0"}
    assert parsed["bandmap_requests"] == [(labels, 200.0)]
    assert parsed["bandmap_hit_rate"][0][1] > 0.0     # Zipf head repeats
    p99 = {lab["quantile"]: v
           for lab, v in parsed["bandmap_latency_s"]}["0.99"]
    assert p99 > 0.0
    assert parsed["bandmap_latency_s_count"] == [(labels, 200.0)]
    assert parsed["bandmap_queue_depth"] == [(labels, 200.0)]
    assert len(svc.access_log) == 200


# ------------------------------------------------------------ access log
def test_every_request_gets_an_access_log_line():
    svc = MappingService(max_workers=2)
    base = make_cnkm(3, 6)
    svc.map_batch([
        MapRequest(dfg=base, cgra=CGRA, req_id="lead"),
        MapRequest(dfg=permute_dfg(base, seed=1), cgra=CGRA,
                   req_id="follow"),
        MapRequest(dfg=_dense_vio(), cgra=CGRA,
                   options=dict(max_ii=2), req_id="doomed"),
    ])
    entries = {e["req_id"]: e for e in svc.access_log.tail()}
    assert set(entries) == {"lead", "follow", "doomed"}
    assert all(tuple(e) == ACCESS_LOG_FIELDS
               for e in entries.values())
    assert entries["lead"]["source"] == "computed"
    assert entries["lead"]["ok"] and not entries["lead"]["hit"]
    assert entries["follow"]["source"] == "dedupe"
    assert entries["doomed"]["source"] == "static_reject"
    assert entries["doomed"]["backend"] == "static"
    assert not entries["doomed"]["ok"]
    assert all(e["wall_s"] >= 0 and len(e["digest"]) == 64
               for e in entries.values())


# -------------------------------------------------- flight / serve events
def test_service_flight_records_admit_and_reject():
    svc = MappingService(max_workers=1)
    svc.map(make_cnkm(2, 4), CGRA, req_id="solo")
    svc.map(_dense_vio(), CGRA, max_ii=2, req_id="doomed")
    svc.map(permute_dfg(_dense_vio(), seed=7), CGRA, max_ii=2)
    kinds = [e["kind"] for e in svc.flight.dump()]
    assert "serve-admit" in kinds
    assert kinds.count("serve-reject") == 2       # static + negative hit
    reasons = {e["reason"] for e in svc.flight.dump()
               if e["kind"] == "serve-reject"}
    assert reasons == {"static", "negative-cache"}


def test_worker_crash_yields_synthetic_failure(monkeypatch):
    import repro.serve.scheduler as sched_mod

    def boom(*a, **kw):
        raise RuntimeError("injected crash")

    monkeypatch.setattr(sched_mod, "map_dfg", boom)
    svc = MappingService(max_workers=2)
    base = make_cnkm(2, 6)
    outs = svc.map_batch([
        MapRequest(dfg=base, cgra=CGRA, req_id="lead"),
        MapRequest(dfg=permute_dfg(base, seed=1), cgra=CGRA,
                   req_id="follow"),
    ])
    assert all(o.source == "crash" and not o.ok for o in outs)
    res = outs[0].result
    # The synthetic result fails the cache's sound-negative admission
    # rule by construction: a crash is never stored as a proof.
    assert res.attempts == 1 and not res.proved_infeasible
    assert not res.certificates
    assert svc.cache.stats.puts == 0
    # The per-request postmortem ends in the crash event...
    assert res.flight[-1]["kind"] == "serve-crash"
    assert res.flight[-1]["error"] == "RuntimeError"
    # ...and the service-level stream saw it too.
    assert any(e["kind"] == "serve-crash" for e in svc.flight.dump())
    # Access log labels both requests as crash outcomes.
    assert {e["source"] for e in svc.access_log.tail()} == {"crash"}
    # A retry after the bug is fixed gets a fresh (uncached) run.
    monkeypatch.undo()
    out = svc.map(base, CGRA)
    assert not out.hit and out.ok


# -------------------------------------------------------------- sampling
def test_head_sampling_bit_identity_and_capture():
    trace = make_request_trace(8, scale="4x4", seed=5)
    reqs = lambda: [MapRequest(dfg=t.dfg, cgra=CGRA, req_id=f"r{i}")  # noqa: E731
                    for i, t in enumerate(trace)]
    plain = MappingService(max_workers=2).map_batch(reqs())
    sampled_svc = MappingService(max_workers=2, trace_sample=1.0)
    sampled = sampled_svc.map_batch(reqs())
    for a, b in zip(plain, sampled):
        assert (a.ok, a.result.ii, a.result.n_routing_pes,
                a.result.attempts, a.result.mis_size) == \
            (b.ok, b.result.ii, b.result.n_routing_pes,
             b.result.attempts, b.result.mis_size)
    # rate=1.0 traces every *dispatched* request (hits never dispatch).
    n_computed = sum(1 for o in sampled if o.source == "computed")
    assert len(sampled_svc.traces) == n_computed > 0
    digest, tracer = sampled_svc.traces[0]
    assert len(digest) == 64
    assert any(r.name == "map-dfg" for r in tracer.finished)
    # rate=0.0 (default) samples nothing.
    zero_svc = MappingService(max_workers=2)
    zero_svc.map_batch(reqs())
    assert len(zero_svc.traces) == 0
