"""Synthetic workload generator (core/workloads.py) + the loop-carried
mapping path it exercises for the first time: RecMII computation, end-to-
end maps of distance >= 1 kernels, the scheduler's recurrence post-check,
the validator's recurrence violation, and the GRF park window for
inter-iteration consumers on a *generated cyclic* graph (the PR 2 fix
regressed only on hand-built DFGs before)."""

import numpy as np
import pytest

from repro.core import (CGRAConfig, generate, make_loop_kernel,
                        make_reduction, make_stencil, map_dfg, mii,
                        schedule_dfg, sweep_specs)
from repro.core.dfg import OpKind
from repro.core.validate import validate_mapping

CGRA = CGRAConfig()


# ------------------------------------------------------------- families
def test_registry_and_determinism():
    for spec in sweep_specs("4x4"):
        d1, d2 = spec.build(), spec.build()
        assert len(d1.ops) == len(d2.ops)
        assert [(e.src, e.dst, e.distance) for e in d1.edges] == \
            [(e.src, e.dst, e.distance) for e in d2.edges]
        d1.topo_order()          # no intra-iteration cycles
    with pytest.raises(KeyError):
        generate("nope")


def test_loop_kernel_exercises_rec_mii():
    d = make_loop_kernel(n_chains=4, chain_len=4, n_carries=2,
                         max_distance=1, seed=0)
    # distance-1 back edge over a 4-op chain: RecMII = 4.
    assert d.rec_mii() == 4
    assert any(e.distance >= 1 for e in d.edges)
    d2 = make_loop_kernel(n_carries=0, seed=0)
    assert d2.rec_mii() == 1


def test_loop_kernel_single_vio_pred_invariant():
    """At most one VIO predecessor per compute op — the fabric can only
    deliver one bus datum per consumer row pinning (see workloads.py)."""
    d = make_loop_kernel(n_chains=5, chain_len=6, n_inputs=4, seed=3)
    vins = set(d.v_i)
    for c in d.v_r:
        assert sum(1 for p in d.predecessors(c) if p in vins) <= 1


def test_vout_producers_distinct():
    d = make_loop_kernel(n_chains=3, chain_len=3, n_outputs=3, seed=1)
    prods = [d.predecessors(v)[0] for v in d.v_o]
    assert len(prods) == len(set(prods))


def test_stencil_reuse_profile():
    d = make_stencil(points=4, taps=3)
    rds = sorted(d.rd(v) for v in d.v_i)
    assert rds[0] == 1 and rds[-1] == 3    # sliding-window RD profile
    assert len(d.v_o) == 4


def test_reduction_shape():
    d = make_reduction(width=8, arity=2)
    assert len(d.v_i) == 8 and len(d.v_o) == 1
    assert len(d.v_r) == 8 + 7             # leaves + tree


def test_tightly_coupled_shape_and_invariants():
    d = generate("tight", n_vios=8, fanout=8, cross_links=2,
                 link_run=6, seed=0)
    assert len(d.v_i) == 8 and len(d.v_r) == 64 and len(d.v_o) == 2
    vins = set(d.v_i)
    for c in d.v_r:                        # <= 1 VIO pred per op
        assert sum(1 for p in d.predecessors(c) if p in vins) <= 1
    for v in d.v_i:                        # high fan-out groups
        assert d.rd(v) == 8
    prods = [d.predecessors(v)[0] for v in d.v_o]
    assert len(prods) == len(set(prods))   # distinct VOO producers
    # cross-lane runs: exactly cross_links * (link_run - 1) chain edges
    chain = [e for e in d.edges
             if e.src in set(d.v_r) and e.dst in set(d.v_r)]
    assert len(chain) == 2 * 5
    d.topo_order()                         # acyclic
    # deterministic in seed
    d2 = generate("tight", n_vios=8, fanout=8, cross_links=2,
                  link_run=6, seed=0)
    assert [(e.src, e.dst) for e in d.edges] == \
        [(e.src, e.dst) for e in d2.edges]


# ----------------------------------------------- loop-carried end-to-end
@pytest.mark.parametrize("seed", range(3))
def test_map_loop_kernel_end_to_end(seed):
    d = make_loop_kernel(seed=seed)
    r = map_dfg(d, CGRA, max_ii=10)
    assert r.ok, r.summary()
    assert r.mii >= d.rec_mii()
    assert r.report.ok
    # the mapped schedule respects every loop-carried edge
    sched = r.sched
    for e in sched.dfg.edges:
        if e.distance:
            assert (sched.time[e.dst] + e.distance * sched.ii
                    >= sched.time[e.src] + sched.dfg.ops[e.src].latency)


def test_scheduler_rejects_recurrence_violations():
    """A one-op cycle of latency 3 at distance 1 cannot schedule below
    II=3; schedule_dfg must escalate instead of emitting an invalid
    schedule (the pre-PR behaviour silently violated the recurrence)."""
    from repro.core.dfg import DFG
    d = DFG()
    a = d.add_op(OpKind.COMPUTE, latency=3)
    b = d.add_op(OpKind.COMPUTE)
    d.add_edge(a, b)
    d.add_edge(b, a, distance=1)
    sched = schedule_dfg(d, CGRA)
    assert sched.ii >= 4            # lat(a)+lat(b) = 4 over distance 1
    assert mii(d, CGRA) == 4


def test_validator_flags_recurrence_violation():
    """Same-PE (LRF) consumers of a violated back edge used to pass
    silently — the park interval was empty, not negative."""
    from repro.core.conflict import QUAD, Vertex
    from repro.core.dfg import DFG
    from repro.core.schedule import ScheduledDFG
    d = DFG()
    a = d.add_op(OpKind.COMPUTE, latency=3)
    b = d.add_op(OpKind.COMPUTE)
    d.add_edge(a, b)
    d.add_edge(b, a, distance=1)
    # Hand-built II=2 schedule violating the recurrence b->a.
    sched = ScheduledDFG(d, 2, 2, {a: 0, b: 3}, {}, {})
    placement = {a: Vertex(-1, a, QUAD, 0, 0, pe=(0, 0)),
                 b: Vertex(-1, b, QUAD, 3, 1, pe=(0, 0))}
    report = validate_mapping(sched, CGRA, placement)
    assert any("recurrence violated" in v for v in report.violations)


def test_grf_park_window_on_generated_cyclic_kernel():
    """End-to-end GRF regression on a *generated* cyclic graph: an
    inter-iteration VIO consumer at distance d parks the datum d*II
    extra cycles (PR 2 counted the successor slot only)."""
    d = make_loop_kernel(n_chains=5, chain_len=3, n_inputs=3,
                         n_carries=1, max_distance=1,
                         vin_carry_distance=2, seed=0)
    dist_edges = [e for e in d.edges if e.distance == 2
                  and d.ops[e.src].kind == OpKind.VIN]
    assert dist_edges, "generator must emit the inter-iteration VIO edge"
    cgra = CGRAConfig(grf=8)
    r = map_dfg(d, cgra, max_ii=10)
    assert r.ok, r.summary()
    vin = dist_edges[0].src
    # RD = 5 > M = 4 parks the VIOs in the GRF; the distance-2 consumer
    # then holds the datum 2*II extra cycles, so the park window spans
    # several modulo slots (PR 2 counted the successor slot only).
    assert r.sched.delivery.get(vin) == "grf"
    assert r.report.grf_peak >= 2
    assert r.report.ok


# ------------------------------------------------------------ 8x8 sweep
def test_sweep_specs_map_on_8x8():
    cgra = CGRAConfig(rows=8, cols=8)
    for spec in sweep_specs("8x8"):
        r = map_dfg(spec.build(), cgra, max_ii=10, mis_restarts=4,
                    mis_iters=4000, max_bus_fanout=4)
        assert r.ok, f"{spec.name}: {r.summary()}"


@pytest.mark.parametrize("mode", ["bandmap", "busmap"])
def test_clone_and_route_rewiring_preserves_distance(mode):
    """Multi-port VIO clone splits (bandmap) and routing-PE insertion
    (busmap) rewire consumer edges; the iteration distance must ride
    along or inter-iteration consumers silently become intra-iteration
    (validator and park windows would never see the real distance)."""
    d = make_loop_kernel(n_chains=5, chain_len=3, n_inputs=3,
                         n_carries=1, max_distance=1,
                         vin_carry_distance=2, seed=0)
    assert sum(1 for e in d.edges if e.distance == 2) == 1
    # grf=0: RD = 5 > M = 4 forces the split/route path.
    sched = schedule_dfg(d, CGRA, mode=mode)
    kept = [e for e in sched.dfg.edges if e.distance == 2]
    assert kept, f"{mode}: rewiring dropped the inter-iteration edge"
    src = sched.dfg.ops[kept[0].src]
    assert src.kind in (OpKind.VIN, OpKind.ROUTE)
