"""Optional-hypothesis shim.

`hypothesis` is a dev nicety, not a hard dependency: when it is missing
(the tier-1 CPU image does not bake it in), the property tests degrade to
a small deterministic example sweep instead of failing at collection.
Test modules import ``given``/``settings``/``st`` from here; with
hypothesis installed they get the real thing.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A fixed, deduplicated example list standing in for a
        hypothesis search strategy."""

        def __init__(self, values):
            self.values = list(dict.fromkeys(values))

    class _StrategiesShim:
        @staticmethod
        def integers(min_value=0, max_value=100):
            lo, hi = int(min_value), int(max_value)
            span = hi - lo
            return _Strategy([lo, hi, lo + span // 2, lo + span // 3,
                              lo + (2 * span) // 3, lo + 1 if span else lo])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            lo, hi = float(min_value), float(max_value)
            return _Strategy([lo, hi, (lo + hi) / 2,
                              lo + (hi - lo) * 0.25,
                              lo + (hi - lo) * 0.75])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            vals = elements.values
            sizes = sorted({min_size, max_size,
                            (min_size + max_size) // 2})
            out = [[vals[(k + i) % len(vals)] for i in range(s)]
                   for k, s in enumerate(sizes)]
            return _Strategy([tuple(x) for x in out])

    st = _StrategiesShim()

    def settings(**_kwargs):
        return lambda f: f

    def given(*strategies):
        """Run the test once per example row: the i-th example of every
        strategy, cycling shorter example lists."""

        def deco(f):
            # Zero-arg wrapper (deliberately no functools.wraps: pytest
            # must not see the wrapped signature as fixture requests).
            def wrapper():
                rows = max(len(s.values) for s in strategies)
                for i in range(rows):
                    drawn = [s.values[i % len(s.values)]
                             for s in strategies]
                    drawn = [list(d) if isinstance(d, tuple) else d
                             for d in drawn]
                    f(*drawn)

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco
