"""Observability layer: span mechanics, the NullTracer bit-identity
contract, export round-trips, and the metrics registry.

The load-bearing test here is the bit-identity sweep: `map_dfg` with a
recording `Tracer` must return exactly the same (ok, II, routing-PE,
attempts) as with ``tracer=None`` on every paper kernel — tracing is
observation only, never a perturbation of the search.  The slow BusMap
stragglers run under ``-m slow``, matching test_golden_results.
"""

import json
import threading

import numpy as np
import pytest

from repro.core import PAPER_KERNELS, cnkm_name, make_cnkm, map_dfg
from repro.core.cgra import CGRAConfig
from repro.obs import (NULL_TRACER, PHASES, MetricsRegistry, NullTracer,
                       SpanRecord, Tracer, from_json, live,
                       to_chrome_trace, to_json)
from repro.obs.trace import NULL_SPAN


# ---------------------------------------------------------------- spans

def test_span_nesting_parent_and_depth():
    tr = Tracer()
    with tr.span("outer", ii=2) as outer:
        with tr.span("inner", jitter=1) as inner:
            inner.set(nodes=7)
        with tr.span("inner2"):
            pass
    recs = {r.name: r for r in tr.finished}
    assert set(recs) == {"outer", "inner", "inner2"}
    assert recs["outer"].parent == -1 and recs["outer"].depth == 0
    assert recs["inner"].parent == recs["outer"].sid
    assert recs["inner"].depth == 1
    assert recs["inner2"].parent == recs["outer"].sid
    assert recs["inner"].attrs == {"jitter": 1, "nodes": 7}
    assert recs["outer"].attrs == {"ii": 2}
    # Children finish before the parent; times are monotone and nested.
    assert recs["inner"].t1 <= recs["outer"].t1
    assert recs["outer"].t0 <= recs["inner"].t0
    assert all(r.dur_s >= 0 for r in tr.finished)
    assert outer.sid != inner.sid


def test_span_records_error_attr_on_exception():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("nope")
    (rec,) = tr.finished
    assert rec.attrs["error"] == "ValueError"


def test_span_out_of_order_exit_tolerated():
    tr = Tracer()
    outer = tr.span("outer")
    tr.span("inner")  # never explicitly closed
    outer.__exit__(None, None, None)  # closes through the stack
    names = [r.name for r in tr.finished]
    assert names == ["outer"]
    # A fresh span after the unwind starts at the top level again.
    with tr.span("next"):
        pass
    assert tr.finished[-1].parent == -1


def test_spans_from_two_threads_keep_separate_stacks():
    tr = Tracer()

    def work(tag):
        with tr.span("side", side=tag):
            with tr.span("leaf", side=tag):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    leaves = [r for r in tr.finished if r.name == "leaf"]
    sides = {r.attrs["side"]: r for r in tr.finished if r.name == "side"}
    assert len(leaves) == 2 and len(sides) == 2
    for leaf in leaves:
        # Each leaf's parent is its own thread's "side" span.
        assert leaf.parent == sides[leaf.attrs["side"]].sid
        assert leaf.tid == sides[leaf.attrs["side"]].tid


def test_phase_breakdown_aggregates_and_sorts():
    tr = Tracer()
    for _ in range(3):
        with tr.span("a"):
            pass
    with tr.span("b"):
        pass
    bd = tr.phase_breakdown()
    assert bd["a"]["count"] == 3 and bd["b"]["count"] == 1
    totals = [agg["total_s"] for agg in bd.values()]
    assert totals == sorted(totals, reverse=True)


# --------------------------------------------------- NullTracer contract

def test_null_tracer_is_allocation_free_singletons():
    nt = live(None)
    assert nt is NULL_TRACER
    assert live(nt) is nt
    tr = Tracer()
    assert live(tr) is tr
    assert nt.span("x", ii=1) is NULL_SPAN
    assert nt.span("y") is nt.span("z")
    c = nt.counter("portfolio.iters")
    c.inc()
    c.inc(5)
    assert nt.counter_value("portfolio.iters") == 0
    nt.count("certify.csp_nodes", 41)
    nt.gauge("portfolio.best", 3)
    assert nt.phase_breakdown() == {}
    assert NullTracer().finished == ()
    with nt.span("ctx") as sp:
        assert sp.set(anything=1) is sp


SLOW = {(2, 8, "busmap"), (5, 5, "busmap")}
BIT_CASES = [
    pytest.param(n, m, mode, marks=pytest.mark.slow)
    if (n, m, mode) in SLOW else (n, m, mode)
    for n, m in PAPER_KERNELS for mode in ("bandmap", "busmap")
]


@pytest.mark.parametrize("n,m,mode", BIT_CASES)
def test_tracer_bit_identity_on_paper_kernels(n, m, mode):
    """tracer=None and a recording Tracer must produce the identical
    mapping — tracing never touches the RNG stream or search state."""
    kw = dict(mode=mode, seed=0)
    base = map_dfg(make_cnkm(n, m), CGRAConfig(), **kw)
    tr = Tracer()
    traced = map_dfg(make_cnkm(n, m), CGRAConfig(), tracer=tr, **kw)
    label = f"{cnkm_name(n, m)}:{mode}"
    assert (base.ok, base.ii, base.n_routing_pes, base.attempts) == \
        (traced.ok, traced.ii, traced.n_routing_pes,
         traced.attempts), label
    assert base.mis_size == traced.mis_size, label
    # And the traced run actually recorded the pipeline.
    names = {r.name for r in tr.finished}
    assert "map-dfg" in names and "conflict-build" in names, label
    assert names <= set(PHASES), names - set(PHASES)


def test_traced_run_exports_valid_chrome_trace():
    tr = Tracer()
    r = map_dfg(make_cnkm(5, 5), CGRAConfig(), tracer=tr)
    assert r.ok
    doc = to_chrome_trace(tr, process_name="c5k5")
    # Must survive strict JSON serialization (Perfetto requirement).
    blob = json.loads(json.dumps(doc))
    events = blob["traceEvents"]
    x_names = {e["name"] for e in events if e["ph"] == "X"}
    for phase in ("map-dfg", "conflict-build", "certify", "portfolio",
                  "validate"):
        assert phase in x_names, phase
    for e in events:
        assert e["ph"] in ("X", "C", "M")
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
            assert isinstance(e["tid"], int) and e["tid"] < 64
    (cev,) = [e for e in events if e["ph"] == "C"]
    assert cev["args"]["certify.csp_nodes"] > 0
    assert tr.counter_value("certify.csp_nodes") == \
        cev["args"]["certify.csp_nodes"]


# -------------------------------------------------------- export round-trip

def test_to_json_from_json_round_trip():
    tr = Tracer()
    with tr.span("outer", ii=3):
        with tr.span("inner", stage="exhausted", nodes=12):
            pass
    tr.count("certify.csp_nodes", 12)
    payload = json.loads(json.dumps(to_json(tr)))
    spans = from_json(payload)
    assert spans == tr.finished
    assert all(isinstance(s, SpanRecord) for s in spans)
    assert payload["metrics"]["counters"]["certify.csp_nodes"] == 12


def test_chrome_trace_numpy_attrs_coerced():
    tr = Tracer()
    with tr.span("s", n=np.int64(5), cov=np.float32(0.5),
                 shape=(np.int32(2), 3)):
        pass
    doc = json.loads(json.dumps(to_chrome_trace(tr)))
    args = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]["args"]
    assert args == {"n": 5, "cov": 0.5, "shape": [2, 3]}


# ------------------------------------------------------------- registry

def test_histogram_percentiles_match_numpy():
    reg = MetricsRegistry()
    rng = np.random.default_rng(7)
    samples = rng.exponential(scale=0.01, size=500)
    for s in samples:
        reg.observe("latency_s", float(s))
    p50, p95, p99 = reg.percentiles("latency_s")
    assert p50 == pytest.approx(np.percentile(samples, 50))
    assert p95 == pytest.approx(np.percentile(samples, 95))
    assert p99 == pytest.approx(np.percentile(samples, 99))
    snap = reg.snapshot()
    h = snap["histograms"]["latency_s"]
    assert h["count"] == 500
    assert h["p99"] == pytest.approx(p99)
    assert h["mean"] == pytest.approx(samples.mean())
    assert h["max"] == pytest.approx(samples.max())


def test_gauge_tracks_last_min_max_mean():
    reg = MetricsRegistry()
    for v in (3, 1, 4, 1, 5):
        reg.gauge("portfolio.best", v)
    g = reg.snapshot()["gauges"]["portfolio.best"]
    assert g == dict(last=5, min=1, max=5, count=5, mean=2.8)


def test_snapshot_reset_drains_window_keeps_lifetime():
    reg = MetricsRegistry()
    reg.inc("portfolio.iters", 10)
    reg.observe("latency_s", 0.5)
    reg.gauge("queue_depth", 3)
    snap = reg.snapshot(reset=True)
    assert snap["counters"]["portfolio.iters"] == 10
    # A second drain sees an empty *window*...
    again = reg.snapshot(reset=True)
    assert again == dict(counters={}, gauges={}, histograms={})
    # ...but the cumulative default view keeps the lifetime totals: a
    # scraping consumer can never zero another reader's view (the
    # double-drain hazard).
    life = reg.snapshot()
    assert life["counters"]["portfolio.iters"] == 10
    assert life["histograms"]["latency_s"]["count"] == 1
    assert life["gauges"]["queue_depth"]["last"] == 3
    # Counters keep accumulating across the drain boundary, and the
    # lifetime reads fold both sides.
    reg.inc("portfolio.iters", 2)
    assert reg.counter_value("portfolio.iters") == 12
    assert reg.snapshot(reset=True)["counters"]["portfolio.iters"] == 2
    assert reg.snapshot()["counters"]["portfolio.iters"] == 12


def test_drained_gauge_envelope_and_percentiles_fold():
    reg = MetricsRegistry()
    reg.gauge("queue_depth", 9)
    for v in (0.1, 0.2):
        reg.observe("latency_s", v)
    reg.snapshot(reset=True)
    reg.gauge("queue_depth", 2)
    reg.observe("latency_s", 0.4)
    g = reg.snapshot()["gauges"]["queue_depth"]
    # Live window's last wins; envelope spans both windows.
    assert (g["last"], g["min"], g["max"], g["count"]) == (2, 2, 9, 2)
    p50, _, _ = reg.percentiles("latency_s")
    assert p50 == pytest.approx(0.2)


def test_concurrent_counter_increments_lossless():
    reg = MetricsRegistry()
    tr = Tracer(registry=reg)
    n_threads, per_thread = 8, 2000

    def work():
        handle = tr.counter("portfolio.iters")
        for _ in range(per_thread):
            handle.inc()
            reg.inc("certify.csp_nodes", 2)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.counter_value("portfolio.iters") == n_threads * per_thread
    assert reg.counter_value("certify.csp_nodes") == \
        n_threads * per_thread * 2
