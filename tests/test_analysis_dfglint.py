"""Structural/shape lint rules: each fires on a constructed DFG and
stays silent on everything the generators ship.

`generator_invariant_findings` is also the checked form of the
invariants `core.workloads` used to state only in docstrings — the
generators now assert it on every build, so the sweep below doubles as
a test that the promotion did not reject any shipped workload.
"""

from __future__ import annotations

import pytest

from repro.analysis.dfglint import (LintFinding, fatal_findings,
                                    generator_invariant_findings,
                                    lint_dfg)
from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG, OpKind
from repro.core.workloads import generate, permute_dfg, sweep_specs

CGRA = CGRAConfig()


def _base() -> tuple[DFG, int, int, int]:
    d = DFG()
    v = d.add_op(OpKind.VIN, "v")
    x = d.add_op(OpKind.COMPUTE, "x")
    o = d.add_op(OpKind.VOUT, "o")
    d.add_edge(v, x)
    d.add_edge(x, o)
    return d, v, x, o


def _rules(findings: list[LintFinding]) -> set[str]:
    return {f.rule for f in findings}


# ------------------------------------------------------- error rules
def test_dangling_edge():
    d, v, x, o = _base()
    d.edges.append(type(d.edges[0])(src=x, dst=99, distance=0))
    f = lint_dfg(d, CGRA)
    assert "dangling-edge" in _rules(f)
    assert fatal_findings(f)


def test_zero_distance_cycle():
    d, v, x, o = _base()
    y = d.add_op(OpKind.COMPUTE, "y")
    d.add_edge(x, y)
    d.add_edge(y, x)                      # distance 0 back-edge
    f = lint_dfg(d, CGRA)
    assert "zero-distance-cycle" in _rules(f)
    assert fatal_findings(f)


def test_nonzero_distance_cycle_is_legal():
    d, v, x, o = _base()
    y = d.add_op(OpKind.COMPUTE, "y")
    d.add_edge(x, y)
    d.add_edge(y, x, distance=1)          # loop-carried: fine
    assert "zero-distance-cycle" not in _rules(lint_dfg(d, CGRA))


def test_vin_has_pred():
    d, v, x, o = _base()
    b = d.add_op(OpKind.VIN, "b")
    d.add_edge(x, b)
    f = lint_dfg(d, CGRA)
    assert "vin-has-pred" in _rules(f)
    assert fatal_findings(f)


def test_vout_has_succ():
    d, v, x, o = _base()
    y = d.add_op(OpKind.COMPUTE, "y")
    d.add_edge(o, y)
    f = lint_dfg(d, CGRA)
    assert "vout-has-succ" in _rules(f)
    assert fatal_findings(f)


# -------------------------------------------------------- warn rules
def test_vio_unconsumed():
    d, v, x, o = _base()
    d.add_op(OpKind.VIN, "lonely")
    f = lint_dfg(d, CGRA)
    assert "vio-unconsumed" in _rules(f)
    assert not fatal_findings(f)          # warn, not error


def test_vio_overfanout_needs_cgra():
    d, v, x, o = _base()
    for i in range(CGRA.pes_per_ibus):    # rd = m_eff + 1 total
        y = d.add_op(OpKind.COMPUTE, f"y{i}")
        d.add_edge(v, y)
    assert "vio-overfanout" in _rules(lint_dfg(d, CGRA))
    assert "vio-overfanout" not in _rules(lint_dfg(d))   # no fabric
    # tightening max_bus_fanout flags earlier
    d2, v2, x2, o2 = _base()
    y = d2.add_op(OpKind.COMPUTE, "y")
    d2.add_edge(v2, y)
    assert "vio-overfanout" in _rules(
        lint_dfg(d2, CGRA, max_bus_fanout=1))


def test_multi_vio_pred():
    d, v, x, o = _base()
    v2 = d.add_op(OpKind.VIN, "v2")
    d.add_edge(v2, x)                     # x now reads two VINs
    y = d.add_op(OpKind.COMPUTE, "y")     # keep v2 otherwise consumed
    d.add_edge(v2, y)
    f = generator_invariant_findings(d)
    assert "multi-vio-pred" in _rules(f)
    assert "multi-vio-pred" in _rules(lint_dfg(d, CGRA))


def test_shared_voo_producer():
    d, v, x, o = _base()
    o2 = d.add_op(OpKind.VOUT, "o2")
    d.add_edge(x, o2)                     # x drives two VOUTs
    f = generator_invariant_findings(d)
    assert "shared-voo-producer" in _rules(f)


# ------------------------------------------------ ordering + silence
def test_errors_sort_before_warns():
    d, v, x, o = _base()
    d.add_op(OpKind.VIN, "lonely")        # warn
    b = d.add_op(OpKind.VIN, "b")
    d.add_edge(x, b)                      # error
    sev = [fd.severity for fd in lint_dfg(d, CGRA)]
    assert "error" in sev and "warn" in sev
    assert sev == sorted(sev, key=lambda s: s != "error")


def test_summary_names_rule_and_ops():
    d, v, x, o = _base()
    b = d.add_op(OpKind.VIN, "b")
    d.add_edge(x, b)
    s = [f.summary() for f in lint_dfg(d, CGRA)
         if f.rule == "vin-has-pred"][0]
    assert "vin-has-pred" in s and "error" in s


@pytest.mark.parametrize("spec", sweep_specs("4x4") + sweep_specs("8x8"),
                         ids=lambda s: s.name)
def test_generators_clean(spec):
    """No errors and no invariant violations on any shipped spec.
    `vio-overfanout` is informational here — high-fanout VINs are
    exactly what the scheduler's port-splitting handles."""
    d = spec.build()                      # asserts invariants itself
    for g in (d, permute_dfg(d, seed=3)):
        f = lint_dfg(g, CGRA)
        assert not fatal_findings(f), (spec.name, f)
        assert _rules(f) <= {"vio-overfanout"}, (spec.name, f)


def test_generator_assertion_rejects_violation(monkeypatch):
    """The promoted invariant actually guards the generators: feed the
    shared checker a violating DFG through `_assert_invariants`."""
    from repro.core import workloads
    d, v, x, o = _base()
    v2 = d.add_op(OpKind.VIN, "v2")
    d.add_edge(v2, x)
    y = d.add_op(OpKind.COMPUTE, "y")
    d.add_edge(v2, y)
    with pytest.raises(AssertionError, match="multi-vio-pred"):
        workloads._assert_invariants(d)
    assert workloads._assert_invariants(generate("cnkm", n=2, m=4))
