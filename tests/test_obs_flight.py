"""Flight recorder: ring mechanics, thread safety, engine wiring, and
the ``record=None`` bit-identity contract (the NullTracer twin).

The load-bearing sweep mirrors ``test_obs_trace``'s tracer bit-identity
test: `map_dfg` under a live `FlightRecorder` must return exactly the
same (ok, II, routing-PE, attempts, MIS size) as with ``record=None``
on every paper kernel — recording is observation only.
"""

import threading

import pytest

from repro.core import PAPER_KERNELS, cnkm_name, make_cnkm, map_dfg
from repro.core.cgra import CGRAConfig
from repro.obs import (EVENTS, NULL_RECORDER, FlightEvent, FlightRecorder,
                       NullFlightRecorder, recording)


# ----------------------------------------------------------------- ring

def test_ring_keeps_newest_capacity_events():
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.emit("attempt", ii=i)
    dump = rec.dump()
    assert len(dump) == 4
    assert [e["ii"] for e in dump] == [6, 7, 8, 9]      # oldest-first
    assert [e["seq"] for e in dump] == [6, 7, 8, 9]     # global seq kept
    assert rec.total == 10 and len(rec) == 4


def test_event_as_dict_shape_and_monotone_clock():
    rec = FlightRecorder()
    rec.emit("phase-begin", phase="map-dfg")
    rec.emit("certificate", ii=2, stage="exhausted")
    a, b = rec.dump()
    assert a["kind"] == "phase-begin" and a["phase"] == "map-dfg"
    assert b["kind"] == "certificate" and b["stage"] == "exhausted"
    assert set(a) == {"seq", "t", "kind", "phase"}
    assert 0 <= a["t"] <= b["t"]
    ev = FlightEvent(seq=3, t=1.25, kind="attempt", attrs={"ii": 2})
    assert ev.as_dict() == dict(seq=3, t=1.25, kind="attempt", ii=2)


def test_null_recorder_contract():
    assert recording(None) is NULL_RECORDER
    rec = FlightRecorder()
    assert recording(rec) is rec
    NULL_RECORDER.emit("attempt", ii=2)
    assert NULL_RECORDER.dump() == ()
    assert NULL_RECORDER.total == 0 and len(NULL_RECORDER) == 0
    assert NullFlightRecorder().dump() == ()


def test_concurrent_emits_lossless_and_unique_seq():
    rec = FlightRecorder(capacity=100_000)
    n_threads, per_thread = 8, 2000

    def work(tag):
        for _ in range(per_thread):
            rec.emit("attempt", tag=tag)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dump = rec.dump()
    assert rec.total == n_threads * per_thread == len(dump)
    seqs = [e["seq"] for e in dump]
    assert len(set(seqs)) == len(seqs)
    assert seqs == sorted(seqs)


# -------------------------------------------------------- engine wiring

def test_failed_run_carries_flight_dump():
    rec = FlightRecorder()
    res = map_dfg(make_cnkm(2, 8), CGRAConfig(rows=4, cols=4),
                  mode="busmap", max_ii=2, record=rec)
    assert not res.ok
    assert len(res.flight) > 0
    kinds = {e["kind"] for e in res.flight}
    assert kinds <= set(EVENTS), kinds - set(EVENTS)
    assert "certificate" in kinds or "attempt" in kinds
    # Events carry the escalation structure an explain report needs.
    assert any(e["kind"] == "phase-begin" and e["phase"] == "map-dfg"
               for e in res.flight)


def test_successful_run_stays_lean():
    rec = FlightRecorder()
    res = map_dfg(make_cnkm(5, 5), CGRAConfig(), record=rec)
    assert res.ok
    assert res.flight == ()        # successes don't carry a postmortem
    assert rec.total > 0           # but the ring did record the run


def test_unrecorded_run_has_no_flight():
    res = map_dfg(make_cnkm(2, 8), CGRAConfig(rows=4, cols=4),
                  mode="busmap", max_ii=2)
    assert not res.ok and res.flight == ()


def test_race_failure_carries_race_events():
    from repro.exact.race import race_map_dfg
    rec = FlightRecorder()
    res = race_map_dfg(make_cnkm(2, 8), CGRAConfig(rows=4, cols=4),
                       mode="busmap", max_ii=2, record=rec)
    assert not res.ok and res.proved_infeasible
    kinds = [e["kind"] for e in res.flight]
    assert "race-cancel" in kinds and "race-winner" in kinds
    winner = [e for e in res.flight if e["kind"] == "race-winner"][-1]
    assert winner["winner"] in ("exact", "portfolio")


# --------------------------------------------------------- bit identity

SLOW = {(2, 8, "busmap"), (5, 5, "busmap")}
BIT_CASES = [
    pytest.param(n, m, mode, marks=pytest.mark.slow)
    if (n, m, mode) in SLOW else (n, m, mode)
    for n, m in PAPER_KERNELS for mode in ("bandmap", "busmap")
]


@pytest.mark.parametrize("n,m,mode", BIT_CASES)
def test_recorder_bit_identity_on_paper_kernels(n, m, mode):
    """record=None and a live FlightRecorder must produce the identical
    mapping — recording never touches the RNG stream or search state."""
    kw = dict(mode=mode, seed=0)
    base = map_dfg(make_cnkm(n, m), CGRAConfig(), **kw)
    rec = FlightRecorder()
    recorded = map_dfg(make_cnkm(n, m), CGRAConfig(), record=rec, **kw)
    label = f"{cnkm_name(n, m)}:{mode}"
    assert (base.ok, base.ii, base.n_routing_pes, base.attempts) == \
        (recorded.ok, recorded.ii, recorded.n_routing_pes,
         recorded.attempts), label
    assert base.mis_size == recorded.mis_size, label
    # And the recorded run actually saw the pipeline.
    kinds = {e["kind"] for e in rec.dump()}
    assert "phase-begin" in kinds and "attempt" in kinds, label
    assert kinds <= set(EVENTS), kinds - set(EVENTS)
