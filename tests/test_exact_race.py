"""Race semantics (`repro.exact.race`) and cooperative cancellation.

What the race driver promises, pinned:

- **Bounded loser shutdown** — a cancelled `PortfolioSBTS` stops
  within one iteration of the token being set (the prover's CSP polls
  every 64 nodes; the portfolio polls per super-iteration), and a
  pre-cancelled `map_dfg` / `exact_map_dfg` returns without claiming
  anything (no partial-range certificates masquerading as full UNSAT
  proofs).
- **Reproducible winners** — the winner is decided by *soundness*,
  not thread timing, whenever only one side can produce a sound
  answer: an UNSAT instance with portfolio certification off can only
  be won by the prover; a feasible instance with a starved prover
  budget can only be won by the portfolio.  Pinned seeds reproduce
  the same winner across repeats.
- **Degradation** — a crashed prover degrades the race to
  portfolio-only (and vice versa); the race only raises when both
  sides crash.
"""

import pytest

from repro.core import CancelToken, make_cnkm, map_dfg
from repro.core.cgra import CGRAConfig
from repro.core.conflict import build_conflict_graph
from repro.core.mis import PortfolioSBTS
from repro.core.schedule import schedule_dfg

CGRA = CGRAConfig()


class _CountingToken(CancelToken):
    """Cancels itself after ``after`` is_set() polls."""

    def __init__(self, after: int):
        super().__init__()
        self.after = after
        self.polls = 0

    def is_set(self) -> bool:
        self.polls += 1
        if self.polls >= self.after:
            self.cancel()
        return super().is_set()


# -------------------------------------------------- bounded shutdown
def _sbts():
    sched = schedule_dfg(make_cnkm(3, 6), CGRA, mode="busmap")
    cg = build_conflict_graph(sched, CGRA, bus_pressure=True)
    return PortfolioSBTS(cg.bits, [None] * 4, seed=0), cg


def test_portfolio_stops_immediately_on_preset_cancel():
    sbts, _ = _sbts()
    tok = CancelToken()
    tok.cancel()
    sbts.run(5000, cancel=tok)
    assert sbts.it == 0


def test_portfolio_stops_within_one_iteration_of_cancel():
    sbts, _ = _sbts()
    tok = _CountingToken(after=10)
    sbts.run(5000, cancel=tok)
    # Polled once per super-iteration: by poll 10 the token is set, so
    # at most 10 iterations ever ran (and no target was hit earlier).
    assert sbts.it <= 10


def test_portfolio_run_identical_with_inert_token():
    """An attached-but-never-set token must not perturb trajectories:
    cancel=None and an inert token produce identical best sets."""
    a, _ = _sbts()
    b, _ = _sbts()
    ra = a.run(300)
    rb = b.run(300, cancel=CancelToken())
    assert a.it == b.it
    assert (ra == rb).all()


@pytest.mark.parametrize("backend", ["portfolio", "exact"])
def test_map_dfg_preset_cancel_claims_nothing(backend):
    tok = CancelToken()
    tok.cancel()
    r = map_dfg(make_cnkm(5, 5), CGRA, mode="busmap", max_ii=2,
                backend=backend, cancel=tok)
    assert not r.ok
    # The crucial soundness property: a cancelled run covers only a
    # prefix of the (II, jitter) range, so it must not carry the
    # full-range UNSAT claim (which this instance would otherwise earn).
    assert not r.proved_infeasible


def test_cancelled_portfolio_never_fakes_certificate_fast_fail():
    """Cancel after the first few polls, mid-II-range: whatever prefix
    was certified must not surface as a sound attempts==0 fast-fail."""
    tok = _CountingToken(after=3)
    r = map_dfg(make_cnkm(5, 5), CGRA, mode="busmap", max_ii=2,
                cancel=tok)
    assert not r.ok and not r.proved_infeasible


def test_token_chaining_reaches_children():
    parent = CancelToken()
    child = CancelToken(parent=parent)
    assert not child.is_set()
    parent.cancel()
    assert child.is_set()
    solo = CancelToken(parent=None)
    solo.cancel()
    assert solo.is_set()


# ------------------------------------------------ reproducible winners
def test_exact_always_wins_unsat_race_without_portfolio_certificates():
    """Portfolio certification off => only the prover can be sound on
    an infeasible instance; the winner is forced, not timed."""
    dfg = make_cnkm(5, 5)
    for _ in range(3):
        r = map_dfg(dfg, CGRA, mode="busmap", max_ii=2, backend="race",
                    certify=False, seed=7)
        assert r.backend == "race:exact"
        assert not r.ok and r.proved_infeasible


def test_portfolio_always_wins_with_starved_prover():
    """A one-node prover budget can neither accept nor certify, so the
    portfolio's validated mapping is the only sound answer."""
    dfg = make_cnkm(3, 6)
    for _ in range(3):
        r = map_dfg(dfg, CGRA, mode="busmap", backend="race",
                    certify=False, certify_budget=1, seed=7)
        assert r.backend == "race:portfolio"
        assert r.ok


def test_race_winner_matches_solo_portfolio_result():
    """Same seed => the racing portfolio walks the same trajectories
    as a solo run; when it wins, it returns the same mapping."""
    dfg = make_cnkm(3, 6)
    solo = map_dfg(dfg, CGRA, mode="busmap", certify=False, seed=3)
    raced = map_dfg(dfg, CGRA, mode="busmap", backend="race",
                    certify=False, certify_budget=1, seed=3)
    assert raced.backend == "race:portfolio"
    assert (raced.ii, raced.placement) == (solo.ii, solo.placement)


def test_race_preset_cancel_returns_unsound_best_effort():
    tok = CancelToken()
    tok.cancel()
    r = map_dfg(make_cnkm(5, 5), CGRA, mode="busmap", max_ii=2,
                backend="race", cancel=tok)
    assert not r.ok and not r.proved_infeasible
    assert r.backend.startswith("race:")


# ------------------------------------------------------- degradation
def test_crashed_prover_degrades_to_portfolio(monkeypatch):
    import repro.exact.race as race_mod

    def boom(*a, **kw):
        raise RuntimeError("prover died")

    monkeypatch.setattr(race_mod, "exact_map_dfg", boom)
    r = map_dfg(make_cnkm(2, 6), CGRA, mode="busmap", backend="race")
    assert r.ok
    assert r.backend == "race:portfolio"


def test_crashed_portfolio_degrades_to_prover(monkeypatch):
    import repro.core.bandmap as bandmap_mod

    real = bandmap_mod.map_dfg

    def boom(*a, **kw):
        if kw.get("cancel") is not None and kw.get("backend",
                                                   "portfolio") \
                == "portfolio":
            raise RuntimeError("portfolio died")
        return real(*a, **kw)

    monkeypatch.setattr(bandmap_mod, "map_dfg", boom)
    r = bandmap_mod.map_dfg(make_cnkm(2, 6), CGRA, mode="busmap",
                            backend="race")
    assert r.ok
    assert r.backend == "race:exact"
    assert r.optimal


def test_both_sides_crashed_raises(monkeypatch):
    import repro.core.bandmap as bandmap_mod
    import repro.exact.race as race_mod

    def boom(*a, **kw):
        raise RuntimeError("dead")

    monkeypatch.setattr(race_mod, "exact_map_dfg", boom)
    monkeypatch.setattr(bandmap_mod, "map_dfg", boom)
    from repro.exact import race_map_dfg
    with pytest.raises(RuntimeError):
        race_map_dfg(make_cnkm(2, 6), CGRA, mode="busmap")


def test_traced_race_bounds_loser_iterations_after_cancel():
    """The traced race records the cancel-request -> loser-exit latency
    and the loser's iterations after the cancel; the poll-at-top
    contract bounds the latter at <= 1 on the real engine.  Forced
    winner: certification off on an infeasible instance means only the
    prover can be sound, so the portfolio is always the loser."""
    from repro.obs import Tracer

    tr = Tracer()
    r = map_dfg(make_cnkm(5, 5), CGRA, mode="busmap", max_ii=2,
                backend="race", certify=False, seed=7, tracer=tr)
    assert r.backend == "race:exact" and not r.ok
    (race_rec,) = [s for s in tr.finished if s.name == "race"]
    assert race_rec.attrs["winner"] == "exact"
    assert race_rec.attrs["loser"] == "portfolio"
    assert race_rec.attrs["cancel_latency_s"] >= 0.0
    assert race_rec.attrs["loser_iters_after_cancel"] <= 1
    sides = {s.attrs["side"]: s for s in tr.finished
             if s.name == "race-side"}
    assert set(sides) == {"exact", "portfolio"}
    assert sides["exact"].attrs["ok"] is False
    # Both sides ran nested engine pipelines on the shared tracer.
    names = {s.name for s in tr.finished}
    assert "exact-csp" in names and "conflict-build" in names
