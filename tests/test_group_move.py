"""Group-move ("kick") neighbourhood of the SBTS portfolio: flag-off
bit-identity, cluster-move validity invariants, and the end-to-end
tightly-coupled regression the neighbourhood exists for (a VIO whose
bus-fed consumers span rows is unreachable for (1,1) swaps)."""

import numpy as np
import pytest

from repro.core import (GroupMoveConfig, make_cnkm, make_tightly_coupled,
                        map_dfg, schedule_dfg)
from repro.core.bitset import BitsetGraph, pack_bool
from repro.core.cgra import CGRAConfig
from repro.core.conflict import build_conflict_graph
from repro.core.mis import PortfolioSBTS

CGRA = CGRAConfig()
BIG = CGRAConfig(rows=8, cols=8)


def _tight_cg(ii=2):
    d = make_tightly_coupled(8, 8, 2, link_run=6, seed=0)
    sched = schedule_dfg(d, BIG, ii=ii, max_ii=ii)
    cg = build_conflict_graph(sched, BIG, bus_pressure=True)
    return cg, sched, cg.op_of


# ----------------------------------------------------- bitset primitives
def _random_adj(n, density, seed):
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < density
    adj = np.triu(adj, 1)
    return adj | adj.T


@pytest.mark.parametrize("n,seed", [(67, 0), (130, 1), (300, 2)])
def test_union_rows_and_cluster_members_vs_bruteforce(n, seed):
    adj = _random_adj(n, 0.1, seed)
    g = BitsetGraph.from_dense(adj)
    rng = np.random.default_rng(seed + 10)
    vs = rng.choice(n, size=7, replace=False)
    union_ref = adj[vs].any(axis=0)
    from repro.core.bitset import unpack
    np.testing.assert_array_equal(
        unpack(g.union_rows(vs), n).astype(bool), union_ref)
    s = rng.random(n) < 0.3
    ref = np.flatnonzero(union_ref & s)
    np.testing.assert_array_equal(g.cluster_members(vs, pack_bool(s)), ref)


def test_union_rows_empty():
    g = BitsetGraph(65)
    assert g.union_rows(np.asarray([], dtype=np.int64)).sum() == 0
    assert g.cluster_members([], pack_bool(np.ones(65, bool))).size == 0


# ------------------------------------------------- flag-off bit-identity
@pytest.mark.parametrize("n,m,mode", [(2, 6, "bandmap"), (3, 6, "busmap"),
                                      (5, 5, "busmap")])
def test_flag_off_bit_identical_on_paper_kernels(n, m, mode):
    """Constructing the portfolio with op_of (as map_dfg now always
    does) and group_move disabled must leave every trajectory
    bit-identical to the plain portfolio."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode)
    cg = build_conflict_graph(sched, CGRA)
    op_of = cg.op_of
    n_ops = len(sched.dfg.ops)
    runs = []
    for kw in ({}, dict(op_of=op_of),
               dict(op_of=op_of, group_move=GroupMoveConfig(enabled=False))):
        sbts = PortfolioSBTS(cg.bits, [None] * 4, seed=11, **kw)
        runs.append(sbts.run(500, target=n_ops).copy())
        assert sbts._gm is None
    assert (runs[0] == runs[1]).all()
    assert (runs[0] == runs[2]).all()


def test_flag_off_rearm_bit_identical():
    adj = _random_adj(80, 0.15, 3)
    g = BitsetGraph.from_dense(adj)
    op_of = np.arange(80) // 4
    states = []
    for kw in ({}, dict(op_of=op_of)):
        sbts = PortfolioSBTS(g, [None, None], seed=5, **kw)
        sbts.run(200)
        sbts.rearm(0)
        sbts.reset_seed(1)
        sbts.run(100)
        states.append((sbts.best.copy(), sbts.in_s.copy(),
                       sbts.tabu.copy()))
    for a, b in zip(states[0], states[1]):
        assert (a == b).all()


def test_group_move_requires_op_of():
    g = BitsetGraph.from_dense(_random_adj(10, 0.3, 0))
    with pytest.raises(ValueError):
        PortfolioSBTS(g, [None], group_move=GroupMoveConfig())


# ------------------------------------------------- kick-phase invariants
def test_kick_preserves_independence_and_conf():
    """After kick phases: membership stays an independent set, conf is
    the exact conflict count, and size bookkeeping matches."""
    cg, sched, op_of = _tight_cg()
    n_ops = len(sched.dfg.ops)
    gm = GroupMoveConfig(cadence=20)
    sbts = PortfolioSBTS(cg.bits, [None] * 3, seed=2, op_of=op_of,
                         group_move=gm)
    for _ in range(6):
        sbts.run(60, target=n_ops)
        for k in range(3):
            row = sbts.in_s[k]
            assert not cg.bits.any_conflict(pack_bool(row))
            np.testing.assert_array_equal(
                cg.bits.conflict_counts(pack_bool(row)), sbts.conf[k])
            assert int(row.sum()) == int(sbts.size[k])
        if (sbts.best_size >= n_ops).any():
            break


def test_kick_respects_tabu():
    """A vertex ejected by the kick is tabu for the kick's tenure: the
    kick itself never re-inserts it while tabu (the re-insertion filter
    is `tabu <= it`)."""
    cg, sched, op_of = _tight_cg()
    n_ops = len(sched.dfg.ops)
    gm = GroupMoveConfig(cadence=10, tenure=50)
    sbts = PortfolioSBTS(cg.bits, [None] * 2, seed=0, op_of=op_of,
                         group_move=gm)
    sbts.run(400, target=n_ops)   # reach the stall, several kicks fire
    before = sbts.in_s.copy()
    sbts.it += 1
    sbts.stall[:] = gm.cadence    # open the stall gate
    sbts._group_kick(n_ops)
    for k in range(2):
        ejected = np.flatnonzero(before[k] & ~sbts.in_s[k])
        if ejected.size:
            assert (sbts.tabu[k, ejected] > sbts.it).all()
        inserted = np.flatnonzero(~before[k] & sbts.in_s[k])
        # nothing inserted was tabu at kick time
        assert (sbts.tabu[k, inserted] <= sbts.it).all() or \
            not inserted.size


def test_rearm_cluster_eviction_invariants():
    """With the flag on, rearm evicts a coherent cluster, keeps the
    state independent, and re-arms best tracking."""
    cg, sched, op_of = _tight_cg()
    n_ops = len(sched.dfg.ops)
    sbts = PortfolioSBTS(cg.bits, [None] * 2, seed=1, op_of=op_of,
                         group_move=GroupMoveConfig())
    sbts.run(300, target=n_ops)
    for k in range(2):
        sbts.rearm(k)
        row = sbts.in_s[k]
        assert not cg.bits.any_conflict(pack_bool(row))
        np.testing.assert_array_equal(
            cg.bits.conflict_counts(pack_bool(row)), sbts.conf[k])
        assert sbts.best_size[k] == int(row.sum())


# ------------------------------------------------ the stall, end to end
def test_tight_workload_engine_stalls_without_kick():
    """Cold-started portfolios on the tightly-coupled family: the
    (1,1)-swap engine stalls below full coverage at the iteration
    budget; the kick-enabled engine reaches it — same graph, same
    budget, same seeds."""
    cg, sched, op_of = _tight_cg()
    n_ops = len(sched.dfg.ops)
    for seed in (0, 1):
        off = PortfolioSBTS(cg.bits, [None] * 8, seed=seed, op_of=op_of)
        best_off = int(off.run(3000, target=n_ops).sum(axis=1).max())
        on = PortfolioSBTS(cg.bits, [None] * 8, seed=seed, op_of=op_of,
                           group_move=GroupMoveConfig())
        best_on = int(on.run(3000, target=n_ops).sum(axis=1).max())
        assert best_off < n_ops, "swap engine no longer stalls"
        assert best_on == n_ops, "kick engine failed to cover"
        assert on.it < 3000, "kick engine should early-exit"


def test_tight_workload_map_dfg_flag_on_vs_off():
    """End to end: at equal iteration budget and pinned II, `map_dfg`
    with group_move enabled produces a *valid* full-coverage binding
    the flag-off engine does not reach (the acceptance scenario)."""
    d = make_tightly_coupled(8, 8, 2, link_run=6, seed=0)
    kw = dict(certify=False, mis_restarts=4, mis_iters=2500,
              min_ii=2, max_ii=2, seed=0)
    r_off = map_dfg(d, BIG, **kw)
    r_on = map_dfg(d, BIG, group_move=True, **kw)
    assert not r_off.ok and r_off.mis_size < r_off.n_ops
    assert r_on.ok and r_on.mis_size == r_on.n_ops == 74
    assert r_on.report is not None and r_on.report.ok
