"""Deterministic equivalence + invariant tests for the packed-bitset
conflict-graph engine and the multi-seed SBTS portfolio (no hypothesis
dependency: every case is seeded and enumerated)."""

import numpy as np
import pytest

from repro.core import (BitsetGraph, make_cnkm, map_dfg, schedule_dfg,
                        solve_mis, solve_mis_portfolio)
from repro.core.bitset import (as_bitset_graph, indices, pack_bool,
                               pack_indices, popcount, unpack)
from repro.core.cgra import CGRAConfig
from repro.core.conflict import (_dep_ok, bitset_group_conflicts,
                                 build_conflict_graph, constructive_init,
                                 dense_conflicts_python)
from repro.core.mis import PortfolioSBTS, greedy_mis

CGRA = CGRAConfig()


def _random_adj(n, density, seed):
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < density
    adj = np.triu(adj, 1)
    return adj | adj.T


# ------------------------------------------------------------ primitives
@pytest.mark.parametrize("n", [1, 5, 63, 64, 65, 200, 513])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    mask = rng.random(n) < 0.3
    words = pack_bool(mask)
    assert words.size == (n + 63) // 64
    np.testing.assert_array_equal(unpack(words, n).astype(bool), mask)
    assert popcount(words) == int(mask.sum())
    np.testing.assert_array_equal(indices(words, n), np.flatnonzero(mask))
    idx = np.flatnonzero(mask)
    np.testing.assert_array_equal(pack_indices(idx, n), words)


@pytest.mark.parametrize("n,density,seed",
                         [(7, 0.5, 0), (64, 0.2, 1), (130, 0.1, 2),
                          (301, 0.35, 3)])
def test_bitset_graph_dense_roundtrip(n, density, seed):
    adj = _random_adj(n, density, seed)
    g = BitsetGraph.from_dense(adj)
    np.testing.assert_array_equal(g.to_dense(), adj)
    assert g.n_edges == int(adj.sum()) // 2
    np.testing.assert_array_equal(g.degrees(), adj.sum(axis=1))
    s = np.zeros(n, dtype=bool)
    s[::3] = True
    np.testing.assert_array_equal(g.conflict_counts(pack_bool(s)),
                                  adj[:, s].sum(axis=1))


def test_bitset_graph_add_edges_matches_dense():
    n = 97
    rng = np.random.default_rng(4)
    i = rng.integers(0, n, 300)
    j = rng.integers(0, n, 300)
    g = BitsetGraph(n)
    g.add_edges(i, j)
    dense = np.zeros((n, n), dtype=bool)
    for a, b in zip(i, j):
        if a != b:
            dense[a, b] = dense[b, a] = True
    np.testing.assert_array_equal(g.to_dense(), dense)


# -------------------------------------------------- conflict-graph build
@pytest.mark.parametrize("n,m,mode", [(1, 2, "bandmap"), (2, 6, "bandmap"),
                                      (3, 6, "busmap"), (4, 4, "bandmap"),
                                      (2, 8, "busmap"), (5, 5, "busmap")])
def test_group_conflicts_byte_identical_to_oracle(n, m, mode):
    """bitset group rules == dense_conflicts_python, bit for bit."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode)
    cg = build_conflict_graph(sched, CGRA)
    bits = bitset_group_conflicts(cg.vertices, cg.op_vertices, sched.ii)
    oracle = dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
    np.testing.assert_array_equal(bits.to_dense(), oracle)


@pytest.mark.parametrize("n,m,mode", [(2, 6, "bandmap"), (3, 6, "busmap"),
                                      (5, 5, "busmap")])
def test_full_adjacency_equals_seed_reference(n, m, mode):
    """Full build (groups + vectorised dep realizability) == the seed
    engine's formulation (oracle groups + python _dep_ok loop)."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode=mode)
    cg = build_conflict_graph(sched, CGRA)
    ref = dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
    for src, dst in {(e.src, e.dst) for e in sched.dfg.edges}:
        for i in cg.op_vertices[src]:
            for j in cg.op_vertices[dst]:
                if not _dep_ok(cg.vertices[i], cg.vertices[j]):
                    ref[i, j] = ref[j, i] = True
    np.testing.assert_array_equal(cg.bits.to_dense(), ref)
    assert cg.n_edges == int(ref.sum()) // 2


def test_adjacency_identical_on_8x8_cgra():
    big = CGRAConfig(rows=8, cols=8)
    sched = schedule_dfg(make_cnkm(3, 6), big)
    cg = build_conflict_graph(sched, big)
    assert cg.n > 1000          # the scenario the dense path can't reach
    ref = dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
    for src, dst in {(e.src, e.dst) for e in sched.dfg.edges}:
        for i in cg.op_vertices[src]:
            for j in cg.op_vertices[dst]:
                if not _dep_ok(cg.vertices[i], cg.vertices[j]):
                    ref[i, j] = ref[j, i] = True
    np.testing.assert_array_equal(cg.bits.to_dense(), ref)


# ------------------------------------------------------------- portfolio
@pytest.mark.parametrize("seed", range(6))
def test_portfolio_independence_random_graphs(seed):
    """Every per-seed best of the portfolio is an independent set."""
    n = 40 + 17 * seed
    adj = _random_adj(n, 0.08 + 0.06 * seed, seed)
    inits = [None, None, greedy_mis(adj, np.random.default_rng(seed)),
             None]
    bests = solve_mis_portfolio(adj, inits=inits, max_iters=400, seed=seed)
    assert bests.shape == (4, n)
    for row in bests:
        idx = np.flatnonzero(row)
        assert not adj[np.ix_(idx, idx)].any()


@pytest.mark.parametrize("seed", range(4))
def test_portfolio_dominates_single_seed(seed):
    """The portfolio's best is never worse than its own member
    trajectories run alone with the same seed stream."""
    adj = _random_adj(80, 0.15, seed + 100)
    single = solve_mis(adj, max_iters=300, seed=seed)
    bests = solve_mis_portfolio(adj, inits=[None] * 4 + [single],
                                max_iters=300, seed=seed)
    assert int(bests.sum(axis=1).max()) >= int(single.sum())


@pytest.mark.parametrize("n,m", [(1, 2), (2, 4), (4, 4)])
def test_portfolio_reaches_target_on_cnkm(n, m):
    """Size parity with the seed solver: on the easy bandmap instances
    both the single-seed solver and the portfolio cover every op."""
    sched = schedule_dfg(make_cnkm(n, m), CGRA, mode="bandmap")
    cg = build_conflict_graph(sched, CGRA)
    n_ops = len(sched.dfg.ops)
    init = constructive_init(cg, sched, CGRA, seed=0)
    single = solve_mis(cg.bits, target=n_ops, max_iters=4000, seed=0,
                       init=init)
    bests = solve_mis_portfolio(cg.bits, inits=[init, None, None],
                                target=n_ops, max_iters=4000, seed=0)
    assert int(single.sum()) == n_ops
    assert int(bests.sum(axis=1).max()) == n_ops


def test_rearm_and_reset_preserve_invariants():
    adj = _random_adj(60, 0.2, 7)
    g = as_bitset_graph(adj)
    sbts = PortfolioSBTS(g, [None, None], seed=3)
    sbts.run(200)
    for k in range(2):
        sbts.rearm(k)
        np.testing.assert_array_equal(
            sbts.conf[k], g.conflict_counts(pack_bool(sbts.in_s[k])))
        idx = np.flatnonzero(sbts.in_s[k])
        assert not adj[np.ix_(idx, idx)].any()
    sbts.reset_seed(0)
    np.testing.assert_array_equal(
        sbts.conf[0], g.conflict_counts(pack_bool(sbts.in_s[0])))
    sbts.run(100)
    for row in sbts.best:
        idx = np.flatnonzero(row)
        assert not adj[np.ix_(idx, idx)].any()


# ------------------------------------------------------------ end-to-end
def test_map_completes_on_8x8_cgra():
    """The new scenario: an 8x8 PEA maps end-to-end, fast."""
    big = CGRAConfig(rows=8, cols=8)
    r = map_dfg(make_cnkm(3, 6), big, mode="bandmap")
    assert r.ok and r.ii == r.mii == 1
    assert r.cg_size[0] > 1000
    r2 = map_dfg(make_cnkm(4, 8), big, mode="busmap")
    assert r2.ok and r2.ii == 1
    assert r2.cg_size[0] > 2000


# ----------------------------------------------- row-cache configurability
def test_row_cache_limit_fallback_equivalence():
    """PortfolioSBTS trajectories are bit-identical whether rows come
    from the unpacked u8 cache or the per-move-unpack fallback — the
    cap (now configurable) only trades memory for gather speed."""
    sched = schedule_dfg(make_cnkm(3, 6), CGRAConfig())
    cg = build_conflict_graph(sched, CGRAConfig())
    n_ops = len(sched.dfg.ops)
    runs = []
    for limit in (None, 0):          # default cache vs forced fallback
        sbts = PortfolioSBTS(cg.bits, [None] * 4, seed=7,
                             row_cache_limit=limit)
        assert (sbts._u8 is None) == (limit == 0)
        runs.append(sbts.run(300, target=n_ops).copy())
    assert (runs[0] == runs[1]).all()


def test_row_cache_limit_threads_through_map_dfg():
    r_cached = map_dfg(make_cnkm(2, 6), CGRAConfig(), mode="busmap")
    r_fallback = map_dfg(make_cnkm(2, 6), CGRAConfig(), mode="busmap",
                         row_cache_limit=0)
    assert (r_cached.ok, r_cached.ii, r_cached.n_routing_pes) == \
        (r_fallback.ok, r_fallback.ii, r_fallback.n_routing_pes)


@pytest.mark.slow
def test_row_cache_fallback_hit_at_16x16_scale():
    """|V_C| ~ 10^4 (a 40-op generated kernel on a 16x16 PEA) exceeds
    the default 32 MiB bound: the constructor must skip the cache, the
    per-move fallback must still solve, and `row_cache()` must
    materialise the full unpacked adjacency lazily for one-shot
    consumers."""
    from repro.core import scale_16x16_loop
    from repro.core.mis import ROW_CACHE_LIMIT
    big = CGRAConfig(rows=16, cols=16)
    sched = schedule_dfg(scale_16x16_loop(), big, max_bus_fanout=4)
    cg = build_conflict_graph(sched, big)
    assert cg.n > 10_000
    assert cg.n * cg.n > ROW_CACHE_LIMIT
    sbts = PortfolioSBTS(cg.bits, [None] * 2, seed=0)
    assert sbts._u8 is None                      # fallback hit
    bests = sbts.run(150, target=len(sched.dfg.ops))
    for row in bests:                            # independence held
        assert not cg.bits.any_conflict(pack_bool(row))
    rc = sbts.row_cache()
    assert rc.shape == (cg.n, cg.n)
    v = int(np.flatnonzero(bests[0])[0])
    assert (rc[v] == cg.bits.row_u8(v)).all()
