"""Serve-side static admission: provably-doomed requests are rejected
at admission with a certificate-backed negative — no worker-pool time —
and the negative is cache-admissible, so isomorphic resubmissions hit
negative memory."""

import collections

from repro.core import CGRAConfig, make_cnkm, permute_dfg
from repro.core.dfg import DFG, OpKind
from repro.serve import MappingService, MapRequest

CGRA = CGRAConfig()


def _dense_vio() -> DFG:
    """Row component of 3 VIOs -> static floor II >= 3."""
    d = DFG()
    vins = [d.add_op(OpKind.VIN, f"v{i}") for i in range(3)]
    for i in range(2):
        x = d.add_op(OpKind.COMPUTE, f"x{i}")
        d.add_edge(vins[i], x)
        d.add_edge(vins[i + 1], x)
        o = d.add_op(OpKind.VOUT, f"o{i}")
        d.add_edge(x, o)
    return d


def test_static_reject_short_circuits_admission():
    svc = MappingService(max_workers=1)
    out = svc.map(_dense_vio(), CGRA, max_ii=2, req_id="doomed")
    assert out.source == "static_reject" and not out.hit
    assert not out.ok
    r = out.result
    assert r.backend == "static"
    assert r.proved_infeasible and r.attempts == 0
    assert r.certificates and all(c.stage == "static-demand"
                                  for c in r.certificates)
    # stored as a negative entry -> the cache took a put
    assert svc.cache.stats.puts == 1
    assert svc.metrics()["static_rejects"] == 1


def test_isomorphic_resubmission_hits_negative_memory():
    svc = MappingService(max_workers=1)
    base = _dense_vio()
    out1 = svc.map(base, CGRA, max_ii=2)
    assert out1.source == "static_reject"
    out2 = svc.map(permute_dfg(base, seed=7), CGRA, max_ii=2)
    assert out2.hit and out2.source == "negative-memory"
    assert out2.result.proved_infeasible
    # only the first request paid for the analysis
    assert svc.cache.stats.puts == 1


def test_static_reject_does_not_touch_mappable_requests():
    svc = MappingService(max_workers=2)
    outs = svc.map_batch([
        MapRequest(dfg=_dense_vio(), cgra=CGRA,
                   options=dict(max_ii=2), deadline=0.0, req_id="bad"),
        MapRequest(dfg=make_cnkm(2, 4), cgra=CGRA, deadline=1.0,
                   req_id="good"),
    ])
    by_id = {o.req_id: o for o in outs}
    assert by_id["bad"].source == "static_reject"
    assert by_id["good"].source == "computed" and by_id["good"].ok
    src = collections.Counter(o.source for o in outs)
    assert src == {"static_reject": 1, "computed": 1}


def test_malformed_dfg_rejected_with_lint_detail():
    """A distance-0 cycle would make `map_dfg` raise inside a worker;
    the static pre-pass turns it into a clean negative instead."""
    d = DFG()
    a = d.add_op(OpKind.COMPUTE, "a")
    b = d.add_op(OpKind.COMPUTE, "b")
    v = d.add_op(OpKind.VIN, "v")
    o = d.add_op(OpKind.VOUT, "o")
    d.add_edge(v, a)
    d.add_edge(a, b)
    d.add_edge(b, a)
    d.add_edge(b, o)
    svc = MappingService(max_workers=1)
    out = svc.map(d, CGRA, max_ii=8)
    assert out.source == "static_reject" and not out.ok
    assert "zero-distance-cycle" in out.result.certificates[0].detail


def test_solo_tenant_path_also_statically_rejected():
    svc = MappingService(max_workers=1)
    out = svc.map(_dense_vio(), CGRA, max_ii=2, tenant="t0")
    assert out.source == "static_reject"
    assert out.result.proved_infeasible


def test_metrics_count_static_rejects():
    svc = MappingService(max_workers=1)
    svc.map(_dense_vio(), CGRA, max_ii=2)
    svc.map(make_cnkm(2, 4), CGRA)
    m = svc.metrics()
    assert m["requests"] == 2
    assert m["static_rejects"] == 1
    assert m["sources"]["static_reject"] == 1
