"""Repo-invariant AST linter: every rule fires on a seeded violation,
stays quiet on the compliant twin, and the shipped tree is clean.

Each fixture is a minimal source string linted under a synthetic
repo-relative path (the rules are path-scoped: e.g. only canonical-path
modules may not read wall clocks, only the engine modules may build
``MappingResult(ok=True)``).
"""

from __future__ import annotations

import pytest

from repro.analysis.astlint import (RULE_NAMES, lint_paths, lint_source,
                                    main)

# (name, expected rule, source, synthetic rel path) — one violation each.
VIOLATIONS = [
    ("ok-constructor", "mapping-result-ok", """
def f(sched):
    return MappingResult(ok=True, mode="bandmap")
""", "src/repro/serve/rogue.py"),
    ("ok-replace", "mapping-result-ok", """
import dataclasses
def f(res):
    return dataclasses.replace(res, ok=True)
""", "src/repro/comap/rogue.py"),
    ("cancel-param-unread", "cancel-poll", """
def run(self, max_iters, cancel=None):
    for _ in range(max_iters):
        pass
""", "src/repro/core/mis.py"),
    ("while-true-no-poll", "cancel-poll", """
def spin(cancel):
    if cancel.is_set():
        return
    while True:
        step()
""", "src/repro/exact/backend.py"),
    ("stale-fingerprint", "serial-version-pin", """
class MappingResult:
    ok: bool
    extra_field: int
    SERIAL_VERSION = 2
""", "src/repro/core/bandmap.py"),
    ("unlocked-mutation", "lock-guarded-state", """
class S:
    _lock_guarded = ("_hits",)
    def __init__(self):
        self._hits = 0
    def bump(self):
        self._hits += 1
    def good(self):
        with self._lock:
            self._hits += 1
""", "src/repro/serve/service.py"),
    ("wallclock-aliased", "no-wallclock-canonical", """
import time as _time
def canon(d):
    return _time.perf_counter()
""", "src/repro/serve/canon.py"),
    ("global-rng", "no-wallclock-canonical", """
import numpy as np
def sig(d):
    return np.random.permutation(3)
""", "src/repro/core/schedule.py"),
    ("tracer-non-none-default", "tracer-default-none", """
def map_it(dfg, tracer=NULL_TRACER):
    return run(dfg, tracer)
""", "src/repro/core/bandmap.py"),
    ("tracer-no-default", "tracer-default-none", """
def run(self, max_iters, *, tracer):
    return tracer
""", "src/repro/core/mis.py"),
    ("tracer-content-branch", "tracer-default-none", """
def f(tracer=None):
    if tracer is not None and tracer.counter_value("x") > 10:
        return early()
""", "src/repro/exact/race.py"),
    ("tracer-truthiness-branch", "tracer-default-none", """
def f(tracer=None):
    if tracer:
        tracer.count("x")
""", "src/repro/comap/comap.py"),
    ("recorder-non-none-default", "recorder-default-none", """
def map_it(dfg, record=NULL_RECORDER):
    return run(dfg, record)
""", "src/repro/core/bandmap.py"),
    ("recorder-boolop-branch", "recorder-default-none", """
def f(record=None):
    if record is not None and not res.ok:
        return record.dump()
""", "src/repro/exact/race.py"),
    ("knob-subscript", "options-single-source", """
def dispatch(req):
    return run(iters=req.options["mis_iters"])
""", "src/repro/serve/scheduler.py"),
    ("knob-dict-get", "options-single-source", """
def dispatch(opts):
    return run(mode=opts.get("mode", "bandmap"))
""", "src/repro/comap/comap.py"),
    ("knob-dict-pop", "options-single-source", """
def dispatch(opts):
    seed = opts.pop("seed", 0)
    return run(seed=seed)
""", "src/repro/exact/race.py"),
]

# Compliant twin under the SAME path scope: must produce no findings.
CLEAN = [
    ("ok-in-engine", """
def f(sched):
    return MappingResult(ok=True, mode="bandmap")
""", "src/repro/core/bandmap.py"),
    ("cancel-polled", """
def run(self, max_iters, cancel=None):
    for _ in range(max_iters):
        if cancel is not None and cancel.is_set():
            return
""", "src/repro/core/mis.py"),
    ("while-true-polls", """
def spin(cancel):
    while True:
        if cancel.is_set():
            return
        step()
""", "src/repro/exact/backend.py"),
    ("lock-held", """
class S:
    _lock_guarded = ("_hits",)
    def __init__(self):
        self._hits = 0
    def bump(self):
        with self._lock:
            self._hits += 1
""", "src/repro/serve/service.py"),
    ("wallclock-elsewhere", """
import time
def bench():
    return time.perf_counter()
""", "src/repro/benchmarks/run.py"),
    ("seeded-rng-ok", """
import numpy as np
def sig(d):
    return np.random.default_rng(0).permutation(3)
""", "src/repro/core/schedule.py"),
    ("tracer-identity-check-ok", """
def f(dfg, *, tracer=None):
    trc = live(tracer)
    if tracer is not None:
        trc.span("conflict-build")
    if tracer is None:
        return fast(dfg)
    return slow(dfg, trc)
""", "src/repro/core/conflict.py"),
    ("tracer-rule-scoped-to-engine", """
def plot(tracer):
    if tracer:
        draw(tracer.finished)
""", "src/repro/analysis/plots.py"),
    ("recorder-identity-check-ok", """
def f(dfg, *, record=None):
    rec = recording(record)
    rec.emit("attempt", ii=2)
    if record is not None:
        if not res.ok:
            return record.dump()
    return res
""", "src/repro/core/bandmap.py"),
    ("recorder-rule-scoped-to-engine", """
def replay(record):
    if record:
        draw(record.dump())
""", "src/repro/analysis/plots.py"),
    ("knob-membership-test-ok", """
def solo(req):
    eff = MapOptions.coerce(req.options)
    if "seed" not in req.options:
        eff = eff.replace(seed=7)
    return eff
""", "src/repro/serve/scheduler.py"),
    ("knob-attribute-read-ok", """
def dispatch(opts):
    return run(iters=opts.portfolio.iters, mode=opts.mode)
""", "src/repro/core/bandmap.py"),
    ("knob-nonknob-key-ok", """
def co(opts):
    raw = dict(opts)
    rounds = raw.pop("rounds", 4)
    return rounds
""", "src/repro/serve/scheduler.py"),
    ("knob-rule-scoped-to-engine", """
def plot(opts):
    return opts["mis_iters"]
""", "src/repro/analysis/plots.py"),
]


@pytest.mark.parametrize("name,rule,src,rel", VIOLATIONS,
                         ids=[v[0] for v in VIOLATIONS])
def test_seeded_violation_fires_once(name, rule, src, rel):
    findings = lint_source(src, rel)
    assert [f.rule for f in findings] == [rule], findings
    assert findings[0].path == rel
    assert findings[0].line > 0


@pytest.mark.parametrize("name,src,rel", CLEAN,
                         ids=[c[0] for c in CLEAN])
def test_compliant_twin_is_clean(name, src, rel):
    assert lint_source(src, rel) == []


def test_all_rules_covered():
    """The seeded-violation fixtures exercise every named rule."""
    assert len(RULE_NAMES) >= 8
    assert {v[1] for v in VIOLATIONS} == set(RULE_NAMES)


def test_knob_names_mirror_legacy_knobs():
    """astlint never imports the linted package, so it carries its own
    copy of the legacy knob names; the two sets must not drift."""
    from repro.analysis.astlint import _KNOB_NAMES
    from repro.core.options import LEGACY_KNOBS
    assert _KNOB_NAMES == frozenset(LEGACY_KNOBS)


def test_syntax_error_is_a_finding():
    findings = lint_source("def broken(:\n", "src/repro/core/x.py")
    assert [f.rule for f in findings] == ["syntax-error"]


def test_repo_tree_is_clean():
    """The gate CI enforces: the shipped source linted end-to-end."""
    findings, n_files = lint_paths(["src"])
    assert n_files > 50
    assert findings == [], [f"{f.path}:{f.line} {f.rule}" for f in findings]


def test_main_exit_codes(tmp_path, capsys):
    assert main(["src"]) == 0
    assert "clean" in capsys.readouterr().out

    rogue = tmp_path / "repro" / "serve" / "rogue.py"
    rogue.parent.mkdir(parents=True)
    rogue.write_text("def f():\n    return MappingResult(ok=True)\n")
    assert main([str(tmp_path)]) == 1
    assert "mapping-result-ok" in capsys.readouterr().out
