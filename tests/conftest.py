import os
import sys

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device (the dry-run sets its
# own flag; see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
