"""Canonical DFG form (`repro.serve.canon`): hash invariance under
vertex relabeling, discrimination across families, and cached-placement
replay validity after relabeling."""

import dataclasses

import pytest

from repro.core import CGRAConfig, map_dfg, make_cnkm, permute_dfg
from repro.core.bandmap import MappingResult
from repro.core.workloads import (make_loop_kernel, make_reduction,
                                  make_stencil, make_tightly_coupled)
from repro.core.validate import validate_mapping
from repro.serve import canonical_form, canonical_hash, relabel_result

# One representative per workload family (generator-name keyed so a
# failure names the family).
FAMILY_DFGS = {
    "cnkm": lambda: make_cnkm(3, 6),
    "loop": lambda: make_loop_kernel(n_chains=3, chain_len=4,
                                     n_carries=1, seed=5),
    "stencil": lambda: make_stencil(points=5, taps=3),
    "reduction": lambda: make_reduction(width=8, arity=2),
    "tight": lambda: make_tightly_coupled(4, 4, 1, seed=2),
}


@pytest.mark.parametrize("family", sorted(FAMILY_DFGS))
def test_hash_invariant_under_permutation(family):
    d = FAMILY_DFGS[family]()
    ref = canonical_form(d)
    for seed in range(10):
        c = canonical_form(permute_dfg(d, seed=seed))
        assert c.digest == ref.digest, (family, seed)
        assert c.blob == ref.blob, (family, seed)


def test_hash_differs_across_families():
    digests = {f: canonical_hash(fn()) for f, fn in FAMILY_DFGS.items()}
    assert len(set(digests.values())) == len(digests), digests


def test_hash_differs_within_family_across_params():
    assert canonical_hash(make_cnkm(2, 4)) != canonical_hash(
        make_cnkm(2, 6))
    assert canonical_hash(make_reduction(width=8, arity=2)) != \
        canonical_hash(make_reduction(width=8, arity=4))


def test_canonical_indices_are_a_bijection():
    d = make_loop_kernel(seed=1)
    c = canonical_form(d)
    assert sorted(c.canon_of.values()) == list(range(len(d.ops)))
    assert set(c.canon_of) == set(d.ops)
    assert all(c.canon_of[c.op_of[i]] == i for i in range(c.n))


def test_blob_equality_implies_isomorphism_map():
    """Composing the two canonical maps must send edges to edges with
    matching distances — the property that makes negative cache hits
    sound."""
    d1 = make_loop_kernel(n_chains=3, chain_len=3, n_carries=1, seed=7)
    d2 = permute_dfg(d1, seed=11)
    c1, c2 = canonical_form(d1), canonical_form(d2)
    assert c1.blob == c2.blob
    iso = {oid: c2.op_of[ci] for oid, ci in c1.canon_of.items()}
    e1 = sorted((iso[e.src], iso[e.dst], e.distance) for e in d1.edges)
    e2 = sorted((e.src, e.dst, e.distance) for e in d2.edges)
    assert e1 == e2
    for oid, op in d1.ops.items():
        assert d2.ops[iso[oid]].kind == op.kind


@pytest.mark.parametrize("family", sorted(FAMILY_DFGS))
def test_cached_placement_replays_after_relabel(family):
    """Map the family's kernel once, relabel the result onto a randomly
    permuted instance through the canonical maps, and replay it through
    the validator — the serving cache's hit path."""
    d = FAMILY_DFGS[family]()
    cgra = CGRAConfig(rows=8, cols=8)
    res = map_dfg(d, cgra, seed=0)
    assert res.ok, family

    c = canonical_form(d)
    canonical = relabel_result(res, c.canon_of)

    perm = permute_dfg(d, seed=3)
    cp = canonical_form(perm)
    assert cp.blob == c.blob
    inv = {ci: oid for oid, ci in cp.canon_of.items()}
    replayed = relabel_result(canonical, inv)

    # The replayed schedule covers exactly the permuted request's ops
    # (plus scheduler-added clones/routing ops on fresh ids).
    assert set(perm.ops) <= set(replayed.sched.dfg.ops)
    extras = set(replayed.sched.dfg.ops) - set(perm.ops)
    assert all(e > max(perm.ops) for e in extras)
    for oid in perm.ops:
        assert replayed.sched.dfg.ops[oid].kind == perm.ops[oid].kind

    report = validate_mapping(replayed.sched, cgra, replayed.placement)
    assert report.ok, (family, report.violations[:3])


def test_relabel_keeps_vertex_op_fields_consistent():
    d = make_cnkm(2, 4)
    res = map_dfg(d, CGRAConfig(), seed=0)
    c = canonical_form(d)
    rel = relabel_result(res, c.canon_of)
    assert all(v.op == oid for oid, v in rel.placement.items())
    assert rel.report is None          # caller must revalidate


def test_relabel_handles_failed_result_without_schedule():
    failed = dataclasses.replace(
        map_dfg(make_cnkm(2, 4), CGRAConfig(), seed=0),
        ok=False, sched=None, placement={}, report=None)
    rel = relabel_result(failed, {0: 5, 1: 6})
    assert rel.sched is None and rel.placement == {}


def test_mapping_result_serialization_roundtrip():
    res = map_dfg(make_cnkm(2, 6), CGRAConfig(), seed=0)
    back = MappingResult.from_bytes(res.to_bytes())
    assert back.ok == res.ok and back.ii == res.ii
    assert back.placement.keys() == res.placement.keys()
    assert back.sched.time == res.sched.time
    with pytest.raises(ValueError):
        import pickle
        MappingResult.from_bytes(pickle.dumps((999, res)))
