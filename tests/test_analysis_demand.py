"""Implied-bandwidth-demand analysis: soundness against the engines.

Three layers of evidence that `repro.analysis.demand` keeps its
contract ("a bound of II >= k means the deterministic schedule family
has no binding below k"):

1. constructed dense-VIO / dense-VOO scenarios where the tuple bound
   fires and `exact_map_dfg` — exhaustive over the same schedule
   family — independently proves UNSAT below the static floor;
2. the scenario the ISSUE names: a dense-VIO component that
   `exact.hall.hall_pressure_edges` alone contributes *zero* edges
   for at the infeasible II (no routing ops, no forced drives), so
   only the tuple demand bound prunes it pre-mapping;
3. a no-false-positive sweep: on every shipped paper kernel and
   workload family the analyzer is a provable no-op (no error
   findings, no bound above MII), and mapped representatives always
   achieve an II >= the static floor.
"""

from __future__ import annotations

import pytest

from _hypothesis_compat import given, settings, st

from repro.analysis import analyze, static_infeasibility
from repro.analysis.demand import (DemandBound, demand_mii,
                                   effective_fanout,
                                   implied_demand_bounds)
from repro.analysis.dfglint import fatal_findings, lint_dfg
from repro.core import map_dfg
from repro.core.cgra import CGRAConfig
from repro.core.conflict import build_conflict_graph
from repro.core.dfg import DFG, OpKind
from repro.core.kernels_cnkm import all_paper_kernels, make_cnkm
from repro.core.schedule import mii, schedule_dfg
from repro.core.workloads import serve_catalog, sweep_specs
from repro.exact import exact_map_dfg
from repro.exact.hall import hall_pressure_edges

CGRA = CGRAConfig()


# ------------------------------------------------------- constructors
def dense_vio(k: int) -> DFG:
    """k VINs chained into one row component: compute x_i reads
    {v_i, v_{i+1}}, so consecutive VINs share a consumer and the
    union-find ties all k into one component -> static floor k."""
    d = DFG()
    vins = [d.add_op(OpKind.VIN, f"v{i}") for i in range(k)]
    for i in range(k - 1):
        x = d.add_op(OpKind.COMPUTE, f"x{i}")
        d.add_edge(vins[i], x)
        d.add_edge(vins[i + 1], x)
        o = d.add_op(OpKind.VOUT, f"o{i}")
        d.add_edge(x, o)
    return d


def dense_voo(k: int) -> DFG:
    """One producer feeding k VOUTs: the column-side dual, floor k."""
    d = DFG()
    v = d.add_op(OpKind.VIN, "v")
    p = d.add_op(OpKind.COMPUTE, "p")
    d.add_edge(v, p)
    for i in range(k):
        q = d.add_op(OpKind.VOUT, f"q{i}")
        d.add_edge(p, q)
    return d


# ------------------------------------------------- the bound itself
def test_dense_vio_bound_fires():
    bounds = implied_demand_bounds(dense_vio(3), CGRA)
    assert len(bounds) == 1
    b = bounds[0]
    assert isinstance(b, DemandBound)
    assert b.scope == "row"
    assert b.min_ii == 3
    assert len(b.tuple_ops) == 3
    assert "II >= 3" in b.summary()
    assert demand_mii(dense_vio(3), CGRA) == 3


def test_dense_voo_bound_fires():
    bounds = implied_demand_bounds(dense_voo(2), CGRA)
    assert [b.scope for b in bounds] == ["col"]
    assert bounds[0].min_ii == 2


def test_high_fanout_vio_exempt():
    """A VIN with rd > m_eff is GRF/multi-port material — the
    single-port row-pinning argument does not apply, so it must never
    enter a component."""
    m_eff = effective_fanout(CGRA)
    d = DFG()
    v = d.add_op(OpKind.VIN, "v")
    outs = []
    for i in range(m_eff + 1):
        x = d.add_op(OpKind.COMPUTE, f"x{i}")
        d.add_edge(v, x)
        outs.append(x)
    o = d.add_op(OpKind.VOUT, "o")
    d.add_edge(outs[0], o)
    assert implied_demand_bounds(d, CGRA) == []
    # ... but a max_bus_fanout override can pull it back in scope.
    assert effective_fanout(CGRA, max_bus_fanout=1) == 1


def test_effective_fanout_matches_scheduler():
    assert effective_fanout(CGRA) == CGRA.pes_per_ibus
    assert effective_fanout(CGRA, max_bus_fanout=2) == 2
    assert effective_fanout(CGRA, max_bus_fanout=99) == CGRA.pes_per_ibus
    assert effective_fanout(CGRA, max_bus_fanout=0) == 1


# ------------------------------------- differential: exact backend
@given(st.integers(min_value=2, max_value=4))
@settings(max_examples=3, deadline=None)
def test_exact_confirms_dense_vio_floor(k):
    """Every flagged (DFG, II < floor) is UNSAT-proved by the
    exhaustive backend over the same schedule family."""
    d = dense_vio(k)
    assert demand_mii(d, CGRA) == k
    r = exact_map_dfg(d, CGRA, max_ii=k - 1)
    assert not r.ok
    assert r.proved_infeasible


def test_exact_confirms_dense_voo_floor():
    d = dense_voo(2)
    r = exact_map_dfg(d, CGRA, max_ii=1)
    assert not r.ok and r.proved_infeasible


def test_exact_confirms_structural_errors():
    """The two absolute error rules (VIN with a predecessor, VOUT with
    a successor) describe ops `conflict._dep_ok` can never bind — the
    exhaustive backend agrees at every II it tries."""
    d = DFG()
    a = d.add_op(OpKind.VIN, "a")
    x = d.add_op(OpKind.COMPUTE, "x")
    b = d.add_op(OpKind.VIN, "b")
    d.add_edge(a, x)
    d.add_edge(x, b)
    assert any(f.rule == "vin-has-pred" for f in lint_dfg(d, CGRA))
    r = exact_map_dfg(d, CGRA, max_ii=3)
    assert not r.ok and r.proved_infeasible

    d2 = DFG()
    a = d2.add_op(OpKind.VIN, "a")
    x = d2.add_op(OpKind.COMPUTE, "x")
    o = d2.add_op(OpKind.VOUT, "o")
    y = d2.add_op(OpKind.COMPUTE, "y")
    d2.add_edge(a, x)
    d2.add_edge(x, o)
    d2.add_edge(o, y)
    assert any(f.rule == "vout-has-succ" for f in lint_dfg(d2, CGRA))
    r2 = exact_map_dfg(d2, CGRA, max_ii=3)
    assert not r2.ok and r2.proved_infeasible


# --------------------------------------- the shape hall.py misses
def test_hall_alone_misses_dense_vio():
    """At II=2 the dense-VIO scenario has no routing ops and no forced
    drive pairs, so `hall_pressure_edges` adds zero edges — the tuple
    demand bound is the only pre-mapping analysis that prunes it."""
    d = dense_vio(3)
    sched = schedule_dfg(d, CGRA, ii=2, max_ii=2)
    cg = build_conflict_graph(sched, CGRA, bus_pressure=True)
    n = hall_pressure_edges(cg.bits, cg.vertices, cg.op_vertices,
                            sched, CGRA)
    assert n == 0
    assert demand_mii(d, CGRA) == 3       # ...but the bound sees it


# ----------------------------------------- map_dfg static pre-pass
def test_map_dfg_skips_below_static_floor():
    r = map_dfg(dense_vio(3), CGRA, max_ii=2)
    assert not r.ok
    assert r.attempts == 0                # never built a schedule
    assert r.proved_infeasible
    assert [(c.ii, c.jitter, c.stage) for c in r.certificates] == \
        [(1, -1, "static-demand"), (2, -1, "static-demand")]


def test_map_dfg_prepass_identical_on_mappable_kernel():
    """On kernels the analyzer is a no-op for, the pre-pass must not
    change the result in any way."""
    d = make_cnkm(2, 4)
    a = map_dfg(d, CGRA, seed=0)
    b = map_dfg(d, CGRA, seed=0, static_prepass=False)
    assert (a.ok, a.ii, a.n_routing_pes, a.attempts, a.placement) == \
        (b.ok, b.ii, b.n_routing_pes, b.attempts, b.placement)


def test_map_dfg_prepass_partial_skip():
    """With max_ii above the floor the engine still runs, but the
    doomed IIs below the floor are certificate-skipped."""
    r = map_dfg(dense_vio(2), CGRA, max_ii=4)
    skipped = [c for c in r.certificates if c.stage == "static-demand"]
    assert [c.ii for c in skipped] == [1]
    assert all(c.jitter == -1 for c in skipped)


# --------------------------------------------- verdict constructor
def test_static_infeasibility_verdict_shape():
    res = static_infeasibility(dense_vio(3), CGRA, max_ii=2)
    assert res is not None
    assert not res.ok and res.proved_infeasible
    assert res.backend == "static"
    assert res.attempts == 0 and res.certificates   # cache-admissible
    assert res.sched is None and res.placement == {}

    # floor within budget -> no verdict, engine must run.
    assert static_infeasibility(dense_vio(3), CGRA, max_ii=8) is None
    assert static_infeasibility(make_cnkm(2, 4), CGRA) is None


def test_static_infeasibility_on_fatal_lint():
    d = DFG()
    a = d.add_op(OpKind.COMPUTE, "a")
    b = d.add_op(OpKind.COMPUTE, "b")
    v = d.add_op(OpKind.VIN, "v")
    o = d.add_op(OpKind.VOUT, "o")
    d.add_edge(v, a)
    d.add_edge(a, b)
    d.add_edge(b, a)                      # distance-0 cycle
    d.add_edge(b, o)
    assert fatal_findings(lint_dfg(d))
    res = static_infeasibility(d, CGRA, max_ii=8)
    assert res is not None and res.proved_infeasible
    assert "zero-distance-cycle" in res.certificates[0].detail


# --------------------------------------- no-false-positive sweep
def _suite():
    specs = {s.name: s for s in sweep_specs("4x4")}
    specs.update({s.name: s for s in sweep_specs("8x8")})
    specs.update({s.name: s for s in serve_catalog("8x8")})
    return [(name, spec.build()) for name, spec in sorted(specs.items())] \
        + sorted(all_paper_kernels().items())


@pytest.mark.parametrize("name,dfg", _suite(), ids=lambda v: v
                         if isinstance(v, str) else "")
def test_analyzer_noop_on_shipped_workloads(name, dfg):
    """Soundness floor: on every kernel/family the repo ships (all of
    which the portfolio maps elsewhere in the suite), the analyzer
    reports no errors and no demand bound above MII."""
    findings, bounds = analyze(dfg, CGRA)
    assert not fatal_findings(findings), (name, findings)
    assert bounds == [], (name, bounds)
    assert demand_mii(dfg, CGRA) == mii(dfg, CGRA)


@pytest.mark.parametrize("n,m", [(2, 4), (3, 6)])
def test_floor_never_exceeds_achieved_ii(n, m):
    """End-to-end tie: a successful map's II is >= the static floor,
    i.e. the analyzer never flags a combo the engine then achieves."""
    d = make_cnkm(n, m)
    floor = demand_mii(d, CGRA)
    r = map_dfg(d, CGRA, seed=0)
    assert r.ok
    assert r.ii >= floor
