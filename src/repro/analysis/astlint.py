"""Repo-invariant AST linter over ``src/repro/``.

The engine's soundness regimes — validator-as-single-authority,
certificate-backed negative caching, cooperative cancellation, pinned
serialization, deterministic canonical paths — were written down in
docstrings and enforced only by tests.  This pass turns each into a
named, CI-gated rule over the parsed source (no imports, no execution),
so a violation fails the ``lint`` job the moment it is committed.

Rules (stable identifiers; each has a seeded-violation fixture in
tests/test_analysis_astlint.py):

``mapping-result-ok``
    `MappingResult(ok=True, ...)` (or ``dataclasses.replace(...,
    ok=True)``) may only be constructed in the validator-replayed
    engine paths: ``core/bandmap.py`` and ``exact/backend.py``, where
    every ``ok=True`` sits behind a ``report.ok`` check.  Anywhere
    else it would mint an unvalidated positive.

``cancel-poll``
    In the engine modules (``core/mis.py``, ``core/certify.py``,
    ``core/bandmap.py``, ``exact/backend.py``, ``exact/race.py``):
    a function taking a ``cancel`` parameter must reference it in its
    body (a dropped token makes the race's loser unkillable), and any
    ``while True:`` loop must reference ``cancel``/``is_set`` inside
    its body (unbounded loops must poll their CancelToken).

``serial-version-pin``
    `MappingResult`'s dataclass field list is fingerprinted; the
    (SERIAL_VERSION, fingerprint) pair must match the pinned table
    below.  Changing the field set without bumping the version would
    let the serve cache unpickle stale on-disk blobs into the new
    layout (`MappingResult.to_bytes` guards the version only).

``lock-guarded-state``
    A class declaring ``_lock_guarded = ("attr", ...)`` promises those
    ``self`` attributes are shared mutable state: outside ``__init__``
    they may only be assigned/augmented/mutated-in-place inside a
    ``with self.<...lock...>`` block.

``no-wallclock-canonical``
    Canonical-path modules (``serve/canon.py``, ``core/schedule.py``)
    must stay deterministic functions of their inputs: no
    ``time.time``/``perf_counter``-style wall-clock reads and no
    global-RNG calls (``random.*``, ``np.random.<fn>`` other than the
    seeded ``default_rng``).

``tracer-default-none``
    In the engine modules threaded with tracing (``core/mis.py``,
    ``core/certify.py``, ``core/bandmap.py``, ``core/conflict.py``,
    ``exact/backend.py``, ``exact/race.py``, ``comap/comap.py``):
    every function accepting a ``tracer`` parameter must default it to
    ``None`` (the NullTracer contract — untraced runs stay
    bit-identical and allocation-free), and no condition (``if`` /
    ``while`` / ternary / ``assert``) may reference ``tracer`` except
    the exact identity checks ``tracer is None`` / ``tracer is not
    None`` — the engine must never branch on trace *content*.

``recorder-default-none``
    The flight-recorder twin of ``tracer-default-none``, over the same
    engine modules: every function accepting a ``record`` parameter
    (`repro.obs.FlightRecorder`) must default it to ``None`` and only
    reference it in conditions through the identity None-checks — a
    ``record=None`` run stays bit-identical (NullFlightRecorder
    contract), and the engine never branches on recorded events.

``options-single-source``
    In the engine modules behind the `MapOptions` facade
    (``core/bandmap.py``, ``exact/backend.py``, ``exact/race.py``,
    ``serve/scheduler.py``, ``comap/comap.py``): a mapping knob may
    only be read from a `MapOptions` instance, never pulled out of a
    loose dict — no ``d["mis_iters"]`` subscripts and no
    ``d.get/.pop/.setdefault("seed")`` calls whose key is a
    `core.options.LEGACY_KNOBS` name.  Membership tests (``"seed" in
    d``) stay legal (that is how the seed-pinning precedence is
    detected), and `MapOptions.from_kwargs`/`coerce` are the one
    adapter allowed to consume such dicts — they live in
    ``core/options.py``, outside the rule's scope.

Run ``python -m repro.analysis.astlint [paths...]`` (default ``src``);
exit code 1 iff any finding.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import os
import sys

# --------------------------------------------------------------- config
_OK_ALLOWED = ("repro/core/bandmap.py", "repro/exact/backend.py")
_CANCEL_MODULES = ("repro/core/mis.py", "repro/core/certify.py",
                   "repro/core/bandmap.py", "repro/exact/backend.py",
                   "repro/exact/race.py")
_CANONICAL_MODULES = ("repro/serve/canon.py", "repro/core/schedule.py")
_TRACER_MODULES = ("repro/core/mis.py", "repro/core/certify.py",
                   "repro/core/bandmap.py", "repro/core/conflict.py",
                   "repro/exact/backend.py", "repro/exact/race.py",
                   "repro/comap/comap.py")
_RESULT_MODULE = "repro/core/bandmap.py"
_OPTIONS_MODULES = ("repro/core/bandmap.py", "repro/exact/backend.py",
                    "repro/exact/race.py", "repro/serve/scheduler.py",
                    "repro/comap/comap.py")
# Mirror of core.options.LEGACY_KNOBS keys (astlint parses source, it
# never imports the linted package); tests/test_analysis_astlint.py
# asserts the two sets stay equal.
_KNOB_NAMES = frozenset({
    "mode", "seed", "backend", "bus_pressure", "max_ii", "min_ii",
    "use_grf", "max_bus_fanout", "certify", "certify_budget",
    "n_exact_placements", "static_prepass", "hall",
    "exact_node_budget", "mis_restarts", "mis_iters", "engine",
    "device_seeds", "group_move", "row_cache_limit",
})
# SERIAL_VERSION -> sha256(",".join(field names))[:16].  Adding,
# removing or reordering MappingResult fields requires bumping the
# version in bandmap.py AND adding the new pair here — that is the
# point: the diff becomes impossible to make silently.
_SERIAL_PINS = {2: "be396c8aa0fcae06", 3: "9b6f3df493a0e85e"}

_WALLCLOCK_CALLS = {("time", "time"), ("time", "perf_counter"),
                    ("time", "monotonic"), ("time", "time_ns"),
                    ("time", "process_time"), ("datetime", "now"),
                    ("datetime", "utcnow")}
_GLOBAL_RNG_FUNCS = {"random", "randint", "randrange", "shuffle",
                     "choice", "sample", "uniform", "seed", "gauss",
                     "random_sample", "rand", "randn", "permutation",
                     "integers"}
_MUTATING_METHODS = {"append", "extend", "add", "update", "pop",
                     "popitem", "clear", "remove", "insert",
                     "setdefault", "discard", "__setitem__"}


@dataclasses.dataclass(frozen=True)
class AstFinding:
    path: str
    line: int
    rule: str
    message: str

    def summary(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> list[str]:
    """`a.b.c` -> ["a", "b", "c"]; empty when not a plain name chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _callee_name(call: ast.Call) -> str:
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return ""


def _has_kw_true(call: ast.Call, name: str) -> bool:
    return any(kw.arg == name and isinstance(kw.value, ast.Constant)
               and kw.value.value is True for kw in call.keywords)


# ----------------------------------------------------------------- rules
def _rule_mapping_result_ok(tree, rel, out):
    if rel.endswith(_OK_ALLOWED):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _callee_name(node)
        flagged = (name == "MappingResult"
                   and (_has_kw_true(node, "ok")
                        or (node.args
                            and isinstance(node.args[0], ast.Constant)
                            and node.args[0].value is True)))
        flagged = flagged or (name == "replace"
                              and _has_kw_true(node, "ok"))
        if flagged:
            out.append(AstFinding(
                rel, node.lineno, "mapping-result-ok",
                "MappingResult(ok=True) constructed outside the "
                "validator-replayed engine paths "
                f"({', '.join(_OK_ALLOWED)})"))


def _rule_cancel_poll(tree, rel, out):
    if not rel.endswith(_CANCEL_MODULES):
        return

    def references_cancel(body) -> bool:
        for stmt in body:
            for n in ast.walk(stmt):
                if isinstance(n, ast.Name) and n.id == "cancel":
                    return True
                if isinstance(n, ast.Attribute) and \
                        n.attr in ("is_set", "cancel", "_cancel"):
                    return True
        return False

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            names = [a.arg for a in args.args + args.kwonlyargs]
            if "cancel" in names and not references_cancel(node.body):
                out.append(AstFinding(
                    rel, node.lineno, "cancel-poll",
                    f"function {node.name!r} takes a cancel token but "
                    f"never references it — the race's loser becomes "
                    f"unkillable through this path"))
        if isinstance(node, ast.While) and \
                isinstance(node.test, ast.Constant) and \
                node.test.value is True and \
                not references_cancel(node.body):
            out.append(AstFinding(
                rel, node.lineno, "cancel-poll",
                "unbounded `while True` loop in an engine module does "
                "not poll its CancelToken"))


def _rule_serial_version_pin(tree, rel, out):
    if not rel.endswith(_RESULT_MODULE):
        return
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef)
                and node.name == "MappingResult"):
            continue
        fields = [s.target.id for s in node.body
                  if isinstance(s, ast.AnnAssign)
                  and isinstance(s.target, ast.Name)]
        version = None
        for s in node.body:
            if isinstance(s, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "SERIAL_VERSION"
                    for t in s.targets):
                version = s.value.value \
                    if isinstance(s.value, ast.Constant) else None
        fp = hashlib.sha256(",".join(fields).encode()).hexdigest()[:16]
        if version not in _SERIAL_PINS:
            out.append(AstFinding(
                rel, node.lineno, "serial-version-pin",
                f"MappingResult.SERIAL_VERSION {version!r} has no "
                f"pinned field fingerprint in analysis/astlint.py"))
        elif _SERIAL_PINS[version] != fp:
            out.append(AstFinding(
                rel, node.lineno, "serial-version-pin",
                f"MappingResult field set changed (fingerprint {fp}, "
                f"pinned {_SERIAL_PINS[version]} for version "
                f"{version}): bump SERIAL_VERSION and re-pin"))


def _rule_lock_guarded_state(tree, rel, out):
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        guarded: set[str] = set()
        for s in cls.body:
            if isinstance(s, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "_lock_guarded"
                    for t in s.targets) and \
                    isinstance(s.value, (ast.Tuple, ast.List)):
                guarded = {e.value for e in s.value.elts
                           if isinstance(e, ast.Constant)}
        if not guarded:
            continue

        def self_attr(node) -> str | None:
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == "self":
                return node.attr
            return None

        def guarded_target(node) -> str | None:
            # self.attr, self.attr[...] — peel subscripts.
            while isinstance(node, ast.Subscript):
                node = node.value
            a = self_attr(node)
            return a if a in guarded else None

        class Visitor(ast.NodeVisitor):
            """Tracks `with self.*lock*` nesting; flags guarded-attr
            mutations at depth 0.  Nested function defs are skipped
            (their call sites are checked where they run)."""

            def __init__(self, fn_name: str) -> None:
                self.depth = 0
                self.fn_name = fn_name

            def visit_With(self, node: ast.With) -> None:
                locked = any(
                    "lock" in (self_attr(item.context_expr) or "")
                    for item in node.items)
                self.depth += locked
                self.generic_visit(node)
                self.depth -= locked

            def visit_FunctionDef(self, node) -> None:
                pass

            visit_AsyncFunctionDef = visit_FunctionDef
            visit_Lambda = visit_FunctionDef

            def _flag(self, line: int, attr: str) -> None:
                if self.depth == 0:
                    out.append(AstFinding(
                        rel, line, "lock-guarded-state",
                        f"self.{attr} (declared in _lock_guarded) "
                        f"mutated outside `with self.*lock*` in "
                        f"{self.fn_name!r}"))

            def visit_Assign(self, node: ast.Assign) -> None:
                for t in node.targets:
                    a = guarded_target(t)
                    if a:
                        self._flag(node.lineno, a)
                self.generic_visit(node)

            def visit_AugAssign(self, node: ast.AugAssign) -> None:
                a = guarded_target(node.target)
                if a:
                    self._flag(node.lineno, a)
                self.generic_visit(node)

            def visit_Call(self, node: ast.Call) -> None:
                f = node.func
                if isinstance(f, ast.Attribute) and \
                        f.attr in _MUTATING_METHODS:
                    a = guarded_target(f.value)
                    if a:
                        self._flag(node.lineno, a)
                self.generic_visit(node)

        for fn in cls.body:
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and fn.name != "__init__":
                v = Visitor(fn.name)
                for stmt in fn.body:
                    v.visit(stmt)


def _rule_no_wallclock_canonical(tree, rel, out):
    if not rel.endswith(_CANONICAL_MODULES):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        parts = _dotted(node.func)
        if len(parts) < 2:
            continue
        head, fn = parts[0], parts[-1]
        if head == "_time":          # the repo's habitual alias
            head = "time"
        if (head, fn) in _WALLCLOCK_CALLS:
            out.append(AstFinding(
                rel, node.lineno, "no-wallclock-canonical",
                f"wall-clock call {'.'.join(parts)} in a "
                f"canonical-path module"))
            continue
        if head == "random" and fn in _GLOBAL_RNG_FUNCS:
            out.append(AstFinding(
                rel, node.lineno, "no-wallclock-canonical",
                f"global-RNG call {'.'.join(parts)} in a "
                f"canonical-path module"))
            continue
        if len(parts) >= 3 and parts[-2] == "random" and \
                fn != "default_rng":
            out.append(AstFinding(
                rel, node.lineno, "no-wallclock-canonical",
                f"global numpy RNG call {'.'.join(parts)} in a "
                f"canonical-path module (seed a default_rng instead)"))


def _check_handle_default_none(tree, rel, out, *, param: str,
                               rule: str, null_name: str,
                               noun: str) -> None:
    """Shared body of the ``tracer-default-none`` /
    ``recorder-default-none`` twins: the ``param`` parameter must
    default to None, and conditions may only reference it through the
    exact identity checks ``param is None`` / ``param is not None``."""

    def is_identity_none_check(test: ast.AST) -> bool:
        return (isinstance(test, ast.Compare)
                and isinstance(test.left, ast.Name)
                and test.left.id == param
                and len(test.ops) == 1
                and isinstance(test.ops[0], (ast.Is, ast.IsNot))
                and len(test.comparators) == 1
                and isinstance(test.comparators[0], ast.Constant)
                and test.comparators[0].value is None)

    def mentions_param(node: ast.AST) -> bool:
        return any(isinstance(n, ast.Name) and n.id == param
                   for n in ast.walk(node))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            pos = args.args + args.posonlyargs
            n_required = len(pos) - len(args.defaults)
            pairs = list(zip(pos[n_required:], args.defaults)) + [
                (a, d) for a, d in zip(args.kwonlyargs,
                                       args.kw_defaults)]
            for a in pos[:n_required]:
                if a.arg == param:
                    out.append(AstFinding(
                        rel, node.lineno, rule,
                        f"function {node.name!r} takes `{param}` "
                        f"without a default — engine entry points "
                        f"must default it to None ({null_name} "
                        f"contract)"))
            for a, d in pairs:
                if a.arg == param and not (
                        isinstance(d, ast.Constant)
                        and d.value is None):
                    out.append(AstFinding(
                        rel, node.lineno, rule,
                        f"function {node.name!r} defaults `{param}` to "
                        f"something other than None — un{noun}d runs "
                        f"must stay bit-identical"))
        tests: list[ast.AST] = []
        if isinstance(node, (ast.If, ast.While, ast.IfExp,
                             ast.Assert)):
            tests.append(node.test)
        for test in tests:
            if mentions_param(test) and \
                    not is_identity_none_check(test):
                out.append(AstFinding(
                    rel, node.lineno, rule,
                    f"condition references `{param}` beyond the "
                    f"identity None-check — the engine must not "
                    f"branch on {noun} content"))


def _rule_tracer_default_none(tree, rel, out):
    if not rel.endswith(_TRACER_MODULES):
        return
    _check_handle_default_none(tree, rel, out, param="tracer",
                               rule="tracer-default-none",
                               null_name="NullTracer", noun="trace")


def _rule_recorder_default_none(tree, rel, out):
    if not rel.endswith(_TRACER_MODULES):
        return
    _check_handle_default_none(tree, rel, out, param="record",
                               rule="recorder-default-none",
                               null_name="NullFlightRecorder",
                               noun="record")


def _rule_options_single_source(tree, rel, out):
    if not rel.endswith(_OPTIONS_MODULES):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, ast.Load) and \
                isinstance(node.slice, ast.Constant) and \
                node.slice.value in _KNOB_NAMES:
            out.append(AstFinding(
                rel, node.lineno, "options-single-source",
                f"mapping knob {node.slice.value!r} read from a dict "
                f"subscript — engine modules read knobs from a "
                f"MapOptions instance only"))
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("get", "pop", "setdefault") and \
                node.args and \
                isinstance(node.args[0], ast.Constant) and \
                node.args[0].value in _KNOB_NAMES:
            out.append(AstFinding(
                rel, node.lineno, "options-single-source",
                f"mapping knob {node.args[0].value!r} read via "
                f".{node.func.attr}() — engine modules read knobs "
                f"from a MapOptions instance only"))


_RULES = (_rule_mapping_result_ok, _rule_cancel_poll,
          _rule_serial_version_pin, _rule_lock_guarded_state,
          _rule_no_wallclock_canonical, _rule_tracer_default_none,
          _rule_recorder_default_none, _rule_options_single_source)

RULE_NAMES = ("mapping-result-ok", "cancel-poll", "serial-version-pin",
              "lock-guarded-state", "no-wallclock-canonical",
              "tracer-default-none", "recorder-default-none",
              "options-single-source")


# ------------------------------------------------------------------ api
def lint_source(src: str, rel_path: str) -> list[AstFinding]:
    """Lint one module's source.  ``rel_path`` must be a posix-style
    path whose suffix identifies the module (".../repro/core/mis.py");
    fixture tests feed synthetic paths to aim rules at snippets."""
    try:
        tree = ast.parse(src)
    except SyntaxError as exc:
        return [AstFinding(rel_path, exc.lineno or 0, "syntax-error",
                           str(exc.msg))]
    out: list[AstFinding] = []
    for rule in _RULES:
        rule(tree, rel_path, out)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def lint_paths(paths: list[str]) -> tuple[list[AstFinding], int]:
    """Lint every ``*.py`` under ``paths``; returns (findings, n_files)."""
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = [d for d in dirs
                       if not d.startswith(".")
                       and d != "__pycache__"]
            files.extend(os.path.join(root, n) for n in names
                         if n.endswith(".py"))
    findings: list[AstFinding] = []
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(),
                                        f.replace(os.sep, "/")))
    return findings, len(files)


def main(argv: list[str] | None = None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or ["src"]
    findings, n_files = lint_paths(paths)
    for f in findings:
        print(f.summary())
    status = "clean" if not findings else f"{len(findings)} finding(s)"
    print(f"astlint: {n_files} files, {len(RULE_NAMES)} rules, {status}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
