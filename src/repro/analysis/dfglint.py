"""Structural pre-mapping lint over (DFG, CGRAConfig) pairs.

Two severities:

- ``error`` — the DFG cannot be mapped by any engine backend: the
  pipeline either crashes on it (dangling edge ids, a distance-0
  recurrence cycle) or every candidate pair of some dependence edge is
  a conflict (`conflict._dep_ok` is False for *all* placements: a VIN
  with a predecessor, a VOUT with a successor).  `analysis.analyze`
  turns these into "cannot map at all" verdicts.
- ``warn`` — the shape breaks the generator-family invariants that
  `core.workloads` upholds (and now asserts, sharing these exact
  rules): such DFGs are mappable in principle but are the slow/doomed
  corner cases — e.g. an op with two VIO predecessors needs both port
  rows at once, and two VOOs sharing a producer contest one column —
  the quantitative side of which `analysis.demand` bounds soundly.

Rules (names are stable test/CLI identifiers):

========================  ========  ====================================
rule                      severity  fires when
========================  ========  ====================================
dangling-edge             error     edge endpoint id not in ``dfg.ops``
zero-distance-cycle       error     intra-iteration (distance-0) cycle
vin-has-pred              error     edge into a VIN
vout-has-succ             error     edge out of a VOUT
multi-vio-pred            warn      op with > 1 distinct VIN preds
shared-voo-producer       warn      producer feeding > 1 VOO, or a VOO
                                    with != 1 producer
vio-overfanout            warn      RD(vio) > m_eff: the scheduler will
                                    clone ports / insert routing PEs
vio-unconsumed            warn      VIN with no consumers
========================  ========  ====================================
"""

from __future__ import annotations

import dataclasses

from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG, OpKind

from .demand import effective_fanout


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    severity: str        # 'error' | 'warn'
    message: str
    ops: tuple[int, ...] = ()

    def summary(self) -> str:
        return f"[{self.severity}] {self.rule}: {self.message}"


def generator_invariant_findings(dfg: DFG) -> list[LintFinding]:
    """The `core.workloads` family invariants as warn-level findings —
    the single source of truth both the generators' assertions and the
    full lint share.

    - **multi-vio-pred**: every op has <= 1 distinct VIO predecessor
      (bus delivery pins a consumer to its VIO's row; two VIO preds
      demand two rows at once).
    - **shared-voo-producer**: VOOs have exactly one producer and no
      two VOOs share one (two VOOs fed by one op land on one column
      and contest its OPORT/OBUS cells slot by slot).
    """
    findings: list[LintFinding] = []
    ops = dfg.ops
    for oid in ops:
        vio_preds = sorted({p for p in dfg.predecessors(oid)
                            if p in ops and ops[p].kind == OpKind.VIN})
        if ops[oid].kind != OpKind.VIN and len(vio_preds) > 1:
            findings.append(LintFinding(
                "multi-vio-pred", "warn",
                f"op {oid} has {len(vio_preds)} VIO predecessors "
                f"{vio_preds} (family invariant: <= 1)",
                ops=(oid, *vio_preds)))
    fed: dict[int, list[int]] = {}
    for vo in dfg.v_o:
        prods = sorted({p for p in dfg.predecessors(vo) if p in ops})
        if len(prods) != 1:
            findings.append(LintFinding(
                "shared-voo-producer", "warn",
                f"VOO {vo} has {len(prods)} producers {prods} "
                f"(family invariant: exactly 1)", ops=(vo, *prods)))
        for p in prods:
            fed.setdefault(p, []).append(vo)
    for p, vos in sorted(fed.items()):
        if len(vos) > 1:
            findings.append(LintFinding(
                "shared-voo-producer", "warn",
                f"producer {p} feeds VOOs {sorted(vos)} (family "
                f"invariant: distinct producers per VOO)",
                ops=(p, *sorted(vos))))
    return findings


def lint_dfg(dfg: DFG, cgra: CGRAConfig | None = None, *,
             max_bus_fanout: int | None = None) -> list[LintFinding]:
    """Run every rule; errors first.  ``cgra`` enables the fabric-aware
    rules (vio-overfanout)."""
    findings: list[LintFinding] = []
    ops = dfg.ops

    dangling = False
    for e in dfg.edges:
        for end in (e.src, e.dst):
            if end not in ops:
                dangling = True
                findings.append(LintFinding(
                    "dangling-edge", "error",
                    f"edge {e.src}->{e.dst} (distance {e.distance}) "
                    f"references missing op {end}",
                    ops=tuple(x for x in (e.src, e.dst) if x in ops)))
    if not dangling:
        try:
            dfg.topo_order()
        except ValueError:
            findings.append(LintFinding(
                "zero-distance-cycle", "error",
                "intra-iteration (distance-0) cycle: no ASAP schedule "
                "exists at any II", ops=()))

    for e in dfg.edges:
        if e.dst in ops and ops[e.dst].kind == OpKind.VIN:
            findings.append(LintFinding(
                "vin-has-pred", "error",
                f"edge {e.src}->{e.dst} targets VIN {e.dst}: no "
                f"candidate pair realizes a dependence into an input "
                f"port tuple", ops=(e.dst,)))
        if e.src in ops and ops[e.src].kind == OpKind.VOUT:
            findings.append(LintFinding(
                "vout-has-succ", "error",
                f"edge {e.src}->{e.dst} leaves VOUT {e.src}: no "
                f"candidate pair realizes a dependence out of an "
                f"output port tuple", ops=(e.src,)))

    findings.extend(generator_invariant_findings(dfg))

    for v in dfg.v_i:
        rd = len(dfg.successors(v))
        if rd == 0:
            findings.append(LintFinding(
                "vio-unconsumed", "warn",
                f"VIN {v} has no consumers", ops=(v,)))
        elif cgra is not None:
            m_eff = effective_fanout(cgra, max_bus_fanout)
            if rd > m_eff:
                findings.append(LintFinding(
                    "vio-overfanout", "warn",
                    f"VIN {v} fans out to {rd} consumers > m_eff="
                    f"{m_eff}: the scheduler will split it into "
                    f"port clones / routing PEs", ops=(v,)))

    findings.sort(key=lambda f: (f.severity != "error", f.rule, f.ops))
    return findings


def fatal_findings(findings: list[LintFinding]) -> list[LintFinding]:
    return [f for f in findings if f.severity == "error"]
