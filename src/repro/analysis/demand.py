"""Implied-bandwidth-demand lower bounds over TIN/TOUT port tuples.

ROADMAP exact-engine rung (b): `exact.hall` only reasons about *forced
drive* routing pairs inside one (scope, slot) bus grid, so a DFG whose
bandwidth demand is carried entirely by dense VIO/VOO port tuples (no
routing ops at all) slips through — `hall_pressure_edges` returns 0 on
it.  This module closes that gap *before any schedule exists*, straight
from (DFG, CGRAConfig) structure.

The bound
---------
Call a VIO **eligible** when ``RD(v) <= m_eff`` where
``m_eff = pes_per_ibus`` capped by ``max_bus_fanout`` (byte-identical
to `schedule._Scheduler`'s budget).  For an eligible VIO the scheduler
*always* takes the single-port bus path, in both modes and regardless
of ``use_grf``:

- GRF parking requires ``rd > m_eff`` (`_schedule_vio`), so it never
  fires;
- bandmap allocates ``Q = min(ceil(rd/m_eff), free) = 1`` port, busmap
  always 1 — no clones;
- ``_route_pes_needed(rd, cgra, m_eff) == 0`` for ``rd <= m_eff`` — no
  routing ops are inserted.

Bus delivery makes every consumer's candidate satisfy
``cons.pe[0] == prod.port`` (`conflict._dep_ok`): all consumers sit on
the VIO's row.  Consumers shared between two eligible VIOs therefore
tie the two VIOs to the *same* row, and each bus VIO exclusively
occupies ``(IPORT_r, slot)`` (`conflict._occupancy`) — so ``k`` VIOs
transitively tied to one row need ``k`` distinct modulo slots:
**II >= k**.  The column side is dual and unconditional: a VOO's
producer must sit on the VOO's column (``prod.pe[1] == cons.port``),
VOOs occupy ``(OPORT_c, slot)`` exclusively, and producer→VOO edges are
never rewritten by the scheduler — ``k`` VOOs tied through shared
producers need **II >= k**.

Components are computed by union–find over the bipartite
(port-tuple op ↔ anchor op) incidence; the per-component floor is
decided by the same SDR (Hall) machinery the exact backend uses
(`exact.hall.sdr_exists` over the uniform slot family).

Soundness contract
------------------
Every bound is relative to the engine's deterministic schedule family
(every schedule `schedule_dfg` can emit for any (II, jitter, seed,
mode, use_grf) at the given ``max_bus_fanout``) — exactly the family
`exact.backend` quantifies over, which is why its UNSAT runs
differentially confirm these verdicts (tests/test_analysis_demand.py).
A bound never flags a combination any engine backend can map; it is a
*lower* bound, free to be loose (the engine may fail even above it).
"""

from __future__ import annotations

import dataclasses

from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG, OpKind
from repro.core.schedule import mii
from repro.exact.hall import sdr_exists


def effective_fanout(cgra: CGRAConfig,
                     max_bus_fanout: int | None = None) -> int:
    """The per-port delivery budget ``m_eff``, byte-identical to
    `schedule._Scheduler`'s computation."""
    return cgra.pes_per_ibus if max_bus_fanout is None \
        else max(1, min(cgra.pes_per_ibus, max_bus_fanout))


@dataclasses.dataclass(frozen=True)
class DemandBound:
    """One co-location component and the II floor it implies."""
    scope: str                    # 'row' (VIO tuples) | 'col' (VOO tuples)
    tuple_ops: tuple[int, ...]    # the port-tuple ops pinned together
    anchor_ops: tuple[int, ...]   # computes/routes forcing co-location
    min_ii: int                   # == SDR floor of the slot family

    def summary(self) -> str:
        kind = "VIOs" if self.scope == "row" else "VOOs"
        return (f"{len(self.tuple_ops)} {kind} {list(self.tuple_ops)} "
                f"tied to one {self.scope} via ops "
                f"{list(self.anchor_ops)} need II >= {self.min_ii}")


class _UnionFind:
    def __init__(self) -> None:
        self._parent: dict = {}

    def find(self, x):
        p = self._parent.setdefault(x, x)
        while p != self._parent[p]:
            self._parent[p] = self._parent[self._parent[p]]
            p = self._parent[p]
        self._parent[x] = p
        return p

    def union(self, a, b) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self._parent[rb] = ra


def _component_floor(k: int) -> int:
    """Smallest II whose slot family {0..II-1} (one set per co-located
    tuple) admits a system of distinct representatives — the same Hall
    decision `exact.hall` applies to bus-cell grids."""
    for ii in range(1, k + 1):
        if sdr_exists([range(ii)] * k):
            return ii
    return k


def _side_bounds(pairs: list[tuple[int, int]],
                 scope: str) -> list[DemandBound]:
    """Union-find over (tuple op, anchor op) incidence ``pairs``."""
    uf = _UnionFind()
    for t, a in pairs:
        uf.union(("t", t), ("a", a))
    comps: dict = {}
    for t, a in pairs:
        root = uf.find(("t", t))
        tups, anchors = comps.setdefault(root, (set(), set()))
        tups.add(t)
        anchors.add(a)
    out = []
    for tups, anchors in comps.values():
        out.append(DemandBound(
            scope=scope, tuple_ops=tuple(sorted(tups)),
            anchor_ops=tuple(sorted(anchors)),
            min_ii=_component_floor(len(tups))))
    out.sort(key=lambda b: (-b.min_ii, b.tuple_ops))
    return out


def implied_demand_bounds(dfg: DFG, cgra: CGRAConfig, *,
                          max_bus_fanout: int | None = None
                          ) -> list[DemandBound]:
    """All component demand bounds (module docstring), strongest first.

    Only components with ``min_ii > 1`` are reported — singleton
    components bound nothing beyond MII (which is why the pre-pass is a
    provable no-op on every shipped kernel family)."""
    m_eff = effective_fanout(cgra, max_bus_fanout)
    anchor_kinds = (OpKind.COMPUTE, OpKind.ROUTE)

    row_pairs: list[tuple[int, int]] = []
    for v in dfg.v_i:
        # Eligibility must mirror the scheduler's rd (successor *list*
        # length, parallel edges included) or the no-clone guarantee
        # breaks.
        if len(dfg.successors(v)) > m_eff:
            continue
        for c in set(dfg.successors(v)):
            if dfg.ops[c].kind in anchor_kinds:
                row_pairs.append((v, c))

    col_pairs: list[tuple[int, int]] = []
    for v in dfg.v_o:
        for p in set(dfg.predecessors(v)):
            if dfg.ops[p].kind in anchor_kinds:
                col_pairs.append((v, p))

    bounds = _side_bounds(row_pairs, "row") + \
        _side_bounds(col_pairs, "col")
    return [b for b in bounds if b.min_ii > 1]


def demand_mii(dfg: DFG, cgra: CGRAConfig, *,
               max_bus_fanout: int | None = None) -> int:
    """Static II floor: classic MII joined with the component demand
    bounds.  Every (II, jitter) combination below it is unbindable
    within the engine's schedule family."""
    floor = mii(dfg, cgra)
    for b in implied_demand_bounds(dfg, cgra,
                                   max_bus_fanout=max_bus_fanout):
        floor = max(floor, b.min_ii)
    return floor
