"""Static pre-mapping analysis: sound verdicts before any search runs.

Two passes live here:

- **Domain pass** (`dfglint` + `demand`) — over a (DFG, CGRAConfig)
  pair: structural lint (dangling edges, distance-0 cycles, VIO/VOO
  shape rules shared with `core.workloads`' generator assertions),
  recomputed ResMII/RecMII floors, and the generalized
  implied-bandwidth-demand bound over TIN/TOUT port tuples per
  (scope, slot) — ROADMAP exact-engine rung (b), lifting
  `exact.hall`'s forced-drive-pair restriction so dense VIO/VOO
  components prune with *no* schedule and *no* routing ops in sight.
- **Repo pass** (`astlint`) — the CI linter enforcing the engine's
  written-down invariants over ``src/repro`` source (see its module
  docstring for the rule table).

Soundness contract
------------------
Every verdict this package emits is a **sound negative**: "no engine
backend maps this (DFG, config) at II < k" (`demand.demand_mii`) or
"... at any II" (`static_infeasibility`).  Precisely:

- *error*-severity `dfglint` findings hold absolutely: the pipeline
  either cannot process the DFG at all (dangling edge, distance-0
  cycle) or every candidate pair of some dependence edge conflicts
  under `conflict._dep_ok`, for every schedule.
- `demand` bounds are relative to the engine's deterministic schedule
  family — every schedule `schedule_dfg` can emit — the *same* family
  `exact.backend` proves UNSAT over, so `exact_map_dfg` differentially
  confirms each one (tests/test_analysis_demand.py property-tests both
  directions: no verdict ever flags a combination any backend maps).

The analyzer never emits "feasible": absence of findings promises
nothing.  Consumers:

- `bandmap.map_dfg(static_prepass=True)` skips II values below the
  static floor, recording one `IICertificate` per skipped II with
  ``stage='static-demand'`` and ``jitter=-1`` (all jitters at once —
  the bound is schedule-free).
- `serve.scheduler` rejects statically-infeasible requests on the
  calling thread (``source="static_reject"``) with a certificate-backed
  negative `MappingResult` that `serve.cache.store` admits
  (``attempts == 0``, ``proved_infeasible=True``) — the worker pool is
  never touched.
"""

from __future__ import annotations

import time as _time

from repro.core.bandmap import MappingResult
from repro.core.certify import IICertificate
from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG
from repro.core.schedule import mii

# `astlint` (the repo pass) is deliberately NOT imported here: it is a
# standalone CLI module (`python -m repro.analysis.astlint`) with no
# dependency on the engine, and importing it from the package __init__
# would shadow the `-m` entry point with a runpy warning.
from .demand import (DemandBound, demand_mii, effective_fanout,
                     implied_demand_bounds)
from .dfglint import (LintFinding, fatal_findings,
                      generator_invariant_findings, lint_dfg)

__all__ = [
    "DemandBound", "LintFinding", "analyze", "demand_mii",
    "effective_fanout", "fatal_findings",
    "generator_invariant_findings", "implied_demand_bounds",
    "lint_dfg", "static_infeasibility",
]


def analyze(dfg: DFG, cgra: CGRAConfig, *,
            max_bus_fanout: int | None = None
            ) -> tuple[list, list]:
    """Convenience: (lint findings, demand bounds) for one pair."""
    findings = lint_dfg(dfg, cgra, max_bus_fanout=max_bus_fanout)
    if fatal_findings(findings):
        return findings, []
    return findings, implied_demand_bounds(
        dfg, cgra, max_bus_fanout=max_bus_fanout)


def static_infeasibility(dfg: DFG, cgra: CGRAConfig, *,
                         mode: str = "bandmap", max_ii: int = 32,
                         min_ii: int | None = None,
                         max_bus_fanout: int | None = None
                         ) -> MappingResult | None:
    """Full-range static verdict: a certificate-backed negative
    `MappingResult` when the pair provably cannot map at any
    II <= ``max_ii`` (fatal structural lint, or a MII/demand floor past
    the range), else ``None``.

    The result is cache-admissible under `serve.cache.store`'s existing
    negative rules: ``attempts == 0`` with certificates attached and
    ``proved_infeasible=True`` — the same encoding a full
    certified-UNSAT engine run produces, minus the engine."""
    t0 = _time.perf_counter()
    findings = lint_dfg(dfg, cgra, max_bus_fanout=max_bus_fanout)
    fatal = fatal_findings(findings)
    floor = None
    if not fatal:
        floor = demand_mii(dfg, cgra, max_bus_fanout=max_bus_fanout)
        if floor <= max_ii:
            return None
        detail = f"static demand floor II >= {floor} > max_ii={max_ii}"
    else:
        detail = "; ".join(f.summary() for f in fatal[:3])
    try:
        the_mii = mii(dfg, cgra)
    except (ValueError, KeyError, RuntimeError):
        # Fatally malformed DFGs (dangling edges, cycles) can defeat
        # even the MII recurrence scan; the claim covers the full range
        # regardless.
        the_mii = 1
    start = max(the_mii if not fatal else 1, min_ii or 0, 1)
    certs = [IICertificate(ii=ii, jitter=-1, stage="static-demand",
                           detail=detail, nodes=0, wall_s=0.0)
             for ii in range(start, max_ii + 1)]
    if not certs:
        # Range empty (e.g. MII already past max_ii): one certificate
        # carries the whole-range claim.
        certs = [IICertificate(ii=-1, jitter=-1, stage="static-demand",
                               detail=detail, nodes=0, wall_s=0.0)]
    return MappingResult(
        ok=False, mode=mode, ii=-1, mii=the_mii, n_routing_pes=0,
        ports_per_vio={}, placement={}, sched=None, report=None,
        cg_size=(0, 0), mis_size=0, n_ops=len(dfg.ops), attempts=0,
        wall_s=_time.perf_counter() - t0, certificates=certs,
        proved_infeasible=True, backend="static")
