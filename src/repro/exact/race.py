"""Exact-vs-portfolio race: first *sound* answer wins.

`race_map_dfg` runs the complete prover (`repro.exact.backend`) and the
stochastic portfolio (`bandmap.map_dfg`) on the same problem in two
threads and returns the first answer that is **sound**:

- an ``ok=True`` result (validator-accepted — from either side);
- an ``ok=False`` with ``proved_infeasible`` — the exact backend
  certified every (II, jitter) combination up to ``max_ii``, or the
  portfolio's pre-existing certificate-backed fast-fail covered the
  whole range with ``attempts == 0`` (`map_dfg` folds that judgement
  into the same flag, and clears it when a cancel cut the loop short).

A portfolio budget exhaustion is *not* sound — a different seed might
succeed — so the race holds it and waits for the prover.  The loser is
cancelled through a shared `core.cancel.CancelToken` chain threaded
into `map_dfg`'s harvest rounds, `PortfolioSBTS.run`'s iteration loop
and the CSP's node loop, so losing work stops within a bounded number
of iterations instead of running out its budget.  A crashed prover
degrades the race to portfolio-only (and vice versa); the request only
fails if both sides fail.

The contract is deliberately "first sound answer", not "best answer":
when the portfolio lands a validated II before the prover finishes,
that II is returned even though the prover might later certify a lower
one — the race trades the optimality *claim* (the winner's ``optimal``
flag is only set on exact wins) for latency, never soundness.  Winners
are tagged ``backend="race:exact"`` / ``"race:portfolio"``.
"""

from __future__ import annotations

import dataclasses
import time as _time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

from repro.core.bandmap import MappingResult
from repro.core.cancel import CancelToken
from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG
from repro.core.options import MapOptions
from repro.obs.flight import recording
from repro.obs.trace import live

from .backend import exact_map_dfg


def _is_sound(res: MappingResult | None) -> bool:
    """A result the race may return without waiting for the rival.

    Deliberately *not* the raw ``attempts == 0 and certificates``
    pattern: a side cancelled mid-II-loop returns certificates that
    only cover a prefix of the range, and `map_dfg` / `exact_map_dfg`
    already fold the "covered everything, uncancelled" judgement into
    ``proved_infeasible``."""
    return res is not None and (res.ok or res.proved_infeasible)


def race_map_dfg(dfg: DFG, cgra: CGRAConfig,
                 options: "MapOptions | dict | None" = None, *,
                 cancel=None, tracer=None, record=None,
                 **kwargs) -> MappingResult:
    """Race the exact backend against the portfolio (module docstring).

    Accepts the same `MapOptions` / dict / legacy-keyword forms as
    `map_dfg`; ``certify.exact_node_budget`` is the prover's
    per-(II, jitter) node budget (defaults to ``certify.budget``).
    Both sides run under the same ``seed``, so they explore the same
    deterministic schedule family — which is what makes an exact UNSAT
    binding on the portfolio side's schedules too.  ``cancel`` cancels
    the whole race.

    ``tracer`` records a "race" span (attrs: ``winner``,
    ``cancel_latency_s`` = cancel-request→loser-exit wall, and — when
    the loser is the portfolio — ``loser_iters_after_cancel``, the
    portfolio iterations the loser spent *after* the cancel request;
    the engine's poll-at-iteration-top contract bounds it at 1) plus
    one "race-side" span per side.  Both sides share the tracer: the
    span records carry thread ids, so the export lays them out as
    separate Perfetto tracks.

    ``record`` (`repro.obs.FlightRecorder`, default None) is shared
    with the portfolio side and additionally receives the race's own
    "race-cancel" / "race-winner" events; when no sound answer lands,
    the returned failure carries the full dump (the same
    ``result.flight`` contract as `map_dfg`)."""
    from repro.core.bandmap import map_dfg

    opts = MapOptions.coerce(options, kwargs)
    # Both sides run the problem directly — neither must re-enter the
    # race dispatch, so the shared option set pins backend explicitly.
    exact_opts = opts.replace(
        backend="exact",
        certify_budget=opts.certify.exact_node_budget
        if opts.certify.exact_node_budget is not None
        else opts.certify.budget)
    port_opts = opts.replace(backend="portfolio")
    trc = live(tracer)
    rec = recording(record)
    tok_exact = CancelToken(parent=cancel)
    tok_port = CancelToken(parent=cancel)

    def run_exact() -> MappingResult:
        with trc.span("race-side", side="exact") as sp:
            res = exact_map_dfg(dfg, cgra, options=exact_opts,
                                cancel=tok_exact, tracer=tracer)
            sp.set(ok=res.ok, wall_s=res.wall_s)
            return res

    def run_portfolio() -> MappingResult:
        with trc.span("race-side", side="portfolio") as sp:
            res = map_dfg(dfg, cgra, options=port_opts,
                          cancel=tok_port, tracer=tracer,
                          record=record)
            sp.set(ok=res.ok, wall_s=res.wall_s)
            return res

    rsp = trc.span("race", mode=opts.mode)
    pool = ThreadPoolExecutor(max_workers=2)
    try:
        futs = {pool.submit(run_exact): "exact",
                pool.submit(run_portfolio): "portfolio"}
        held: dict[str, MappingResult] = {}
        errors: dict[str, BaseException] = {}
        winner: tuple[str, MappingResult] | None = None
        pending = set(futs)
        while pending and winner is None:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                side = futs[fut]
                try:
                    res = fut.result()
                except Exception as exc:   # crashed worker: degrade to
                    errors[side] = exc     # the surviving side
                    continue
                if _is_sound(res):
                    winner = (side, res)
                    break
                held[side] = res
        # First sound answer in hand (or no side can produce one):
        # stop the rival — it polls the token within a bounded number
        # of iterations/nodes.  Snapshot the portfolio-iteration counter
        # *before* requesting the cancel, so the loser's post-cancel
        # work is the counter delta at its exit.
        iters_at_cancel = trc.counter_value("portfolio.iters")
        rec.emit("race-cancel",
                 winner=winner[0] if winner is not None else "none")
        t_cancel = _time.perf_counter()
        tok_exact.cancel()
        tok_port.cancel()
        # Drain the loser (the original code let pool.shutdown absorb
        # it, which is exactly why its cancel wall was invisible):
        # record cancel-request→exit latency per still-pending side.
        cancel_latency = None
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            t_exit = _time.perf_counter()
            for fut in done:
                side = futs[fut]
                try:
                    res = fut.result()
                except Exception as exc:
                    errors[side] = exc
                else:
                    held.setdefault(side, res)
                if winner is not None and side != winner[0]:
                    cancel_latency = t_exit - t_cancel
                    rsp.set(loser=side,
                            cancel_latency_s=cancel_latency)
                    if side == "portfolio":
                        rsp.set(loser_iters_after_cancel=int(
                            trc.counter_value("portfolio.iters")
                            - iters_at_cancel))
    finally:
        pool.shutdown(wait=True)
    with rsp:
        if winner is not None:
            side, res = winner
            rsp.set(winner=side)
            rec.emit("race-winner", winner=side,
                     cancel_latency_s=cancel_latency)
            res = dataclasses.replace(res, backend=f"race:{side}")
            if record is not None:
                # A sound negative (proved infeasible) is still a
                # failure worth a postmortem: refresh its dump so the
                # race-cancel/race-winner tail is included.
                if not res.ok:
                    res = dataclasses.replace(res,
                                              flight=record.dump())
            return res
        # No sound answer: prefer the portfolio's best-effort failure
        # (it carries the partial-coverage diagnostics), then the
        # prover's.
        rsp.set(winner="none")
        rec.emit("race-winner", winner="none",
                 cancel_latency_s=cancel_latency)
        for side in ("portfolio", "exact"):
            if side in held:
                res = dataclasses.replace(held[side],
                                          backend=f"race:{side}")
                if record is not None:
                    if not res.ok:
                        res = dataclasses.replace(res,
                                                  flight=record.dump())
                return res
        raise errors["portfolio"] if "portfolio" in errors \
            else errors["exact"]
