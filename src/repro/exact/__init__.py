"""Exact mapping backend and the exact-vs-portfolio race.

- `backend.exact_map_dfg` — complete prover over the engine's
  (II, jitter) schedule family: proven-optimal SAT or certified UNSAT.
- `hall.hall_pressure_edges` / `hall.sdr_exists` — Hall-style joint
  bus-demand bound over (scope, slot) grids.
- `race.race_map_dfg` — both engines at once, first sound answer wins,
  loser cancelled (`core.cancel.CancelToken`).

Entry point for callers: ``map_dfg(dfg, cgra, backend="exact")`` or
``backend="race"`` (`core.bandmap`).
"""

from repro.core.cancel import CancelToken

from .backend import exact_map_dfg
from .hall import hall_pressure_edges, sdr_exists
from .race import race_map_dfg

__all__ = ["CancelToken", "exact_map_dfg", "hall_pressure_edges",
           "race_map_dfg", "sdr_exists"]
