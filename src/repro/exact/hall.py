"""Hall-style set bound over (scope, slot) bus-demand grids.

`conflict.bus_pressure_edges` folds two pairwise-decidable shapes of
bus scarcity into the conflict graph: a forced drive with *no* feasible
(bus, cycle) cell, and two forced drives pinned to the *same single*
cell.  What it cannot see is the joint below-capacity case the ROADMAP
names: three forced demands over two surviving cells is unsatisfiable
even though every pair of them still fits — until now that shape was
caught only post-hoc by `validate._assign_buses`.

`hall_pressure_edges` closes it with Hall's theorem.  For a candidate
pair (u, v) of forced-drive vertices in one (scope, idx) grid, the
demand family a complete placement containing both must satisfy is:

- u's and v's own forced drives — each needs one cell from its
  feasible set (``buses_per_scope × forced window``, minus the
  schedule-saturated bus-0 cells, exactly as in `bus_pressure_edges`);
- one drive per *implied* third party: any other op whose candidates
  compatible with {u, v} (non-adjacent in the graph built so far) all
  demand a cell in the same grid — forced routing ops pinned to this
  scope, and bus-VIO / VOO port tuples hard-wired to their bus-0 cell.
  The third party's demand set is the union over its surviving
  candidates (a superset of the chosen candidate's set, so using it is
  conservative); an op with *no* surviving candidate makes the pair
  unconditionally un-completable, which is the degenerate Hall
  violation (empty demand set).

Drives of distinct producers never share a (bus, cycle) — one driver
per bus instance per cycle is the validator's replay rule — so the
family is satisfiable iff it has a system of distinct representatives.
`sdr_exists` decides that by augmenting-path bipartite matching; no SDR
⇒ the edge (u, v) is added.

Soundness contract (the same no-false-conflict contract
`bus_pressure_edges` carries, property-tested in
`tests/test_exact_hall.py`): every added edge endpoints-pair is one
`validate_mapping` rejects in any complete placement — Hall violations
only shrink under taking subsets/chosen candidates, so a conservative
union can never manufacture a false conflict.  The bound is used by
the exact backend (`repro.exact.backend`), where stronger pruning
means smaller UNSAT exhaustions; the portfolio path keeps its
byte-pinned `bus_pressure_edges`-only graph.
"""

from __future__ import annotations

import numpy as np

from repro.core.conflict import TIN, TOUT, _forced_drive_slots
from repro.core.dfg import OpKind
from repro.core.tec import COL, ROW


def sdr_exists(cell_sets) -> bool:
    """Hall's theorem, constructively: True iff the demand family
    ``cell_sets`` (iterables of hashable cells) admits a system of
    distinct representatives.  Plain augmenting-path bipartite matching
    — families here are a handful of sets over a few cells."""
    match: dict = {}
    sets = [list(s) for s in cell_sets]

    def aug(i: int, seen: set) -> bool:
        for c in sets[i]:
            if c in seen:
                continue
            seen.add(c)
            j = match.get(c)
            if j is None or aug(j, seen):
                match[c] = i
                return True
        return False

    return all(aug(i, set()) for i in range(len(sets)))


def hall_pressure_edges(bits, vertices, op_vertices, sched, cgra) -> int:
    """Add the Hall-bound edges (module docstring) to ``bits`` in
    place; returns the number of vertex pairs added."""
    dfg, ii = sched.dfg, sched.ii
    n_buses = cgra.buses_per_scope

    # Schedule-level saturation of the hardwired bus-0 cells (stage 1
    # of `bus_pressure_edges`, recomputed — it is a few lines over the
    # op list).
    vin_bus = [0] * ii
    vout = [0] * ii
    for oid, op in dfg.ops.items():
        m = sched.time[oid] % ii
        if op.kind == OpKind.VIN and \
                sched.delivery.get(oid, "bus") == "bus":
            vin_bus[m] += 1
        elif op.kind == OpKind.VOUT:
            vout[m] += 1
    sat = {ROW: [vin_bus[m] >= cgra.rows for m in range(ii)],
           COL: [vout[m] >= cgra.cols for m in range(ii)]}

    forced: dict[int, list[int]] = {}
    for oid, op in dfg.ops.items():
        if op.kind != OpKind.ROUTE:
            continue
        slots = _forced_drive_slots(sched, oid, sched.time[oid] % ii)
        if slots is not None:
            forced[oid] = slots
    if not forced:
        return 0

    def route_cells(oid: int, scope) -> frozenset:
        return frozenset((k, s) for k in range(n_buses)
                         for s in forced[oid]
                         if not (k == 0 and sat[scope][s]))

    # Pair endpoints: forced-drive route vertices, grouped per grid.
    grid_verts: dict[tuple, list[int]] = {}
    for oid in forced:
        for vi in op_vertices[oid]:
            v = vertices[vi]
            if v.drive is not None:
                grid_verts.setdefault(v.drive, []).append(vi)

    # Per-vertex demand (grid, cells) for third-party evaluation: route
    # candidates demand their drive grid, bus-VIO / VOO port tuples
    # their hard-wired bus-0 cell.
    demand_of: dict[int, tuple[tuple, frozenset]] = {}
    for v in vertices:
        if v.kind == TIN and v.mode == "bus":
            demand_of[v.idx] = ((ROW, v.port), frozenset({(0, v.m)}))
        elif v.kind == TOUT:
            demand_of[v.idx] = ((COL, v.port), frozenset({(0, v.m)}))
        elif v.op in forced and v.drive is not None:
            demand_of[v.idx] = (v.drive, route_cells(v.op, v.drive[0]))

    # Ops a pair must leave placeable: every op with at least one
    # demand-carrying candidate (only those can become grid-implied).
    party_ops = sorted({vertices[vi].op for vi in demand_of})
    party_doms = {o: np.asarray(op_vertices[o], dtype=np.int64)
                  for o in party_ops}

    n_pairs = 0
    src_acc: list[int] = []
    dst_acc: list[int] = []
    for grid, vis in grid_verts.items():
        scope, _ = grid
        cells_by_op = {}
        for vi in vis:
            o = vertices[vi].op
            if o not in cells_by_op:
                cells_by_op[o] = route_cells(o, scope)
        for a in range(len(vis)):
            u = vis[a]
            row_u = bits.row_u8(u)
            for b in range(a + 1, len(vis)):
                v = vis[b]
                ou, ov = vertices[u].op, vertices[v].op
                if ou == ov or bits.has_edge(u, v):
                    continue
                blocked = (row_u | bits.row_u8(v)) != 0
                demands = [cells_by_op[ou], cells_by_op[ov]]
                doomed = False
                for o in party_ops:
                    if o == ou or o == ov:
                        continue
                    comp = party_doms[o][~blocked[party_doms[o]]]
                    if comp.size == 0:
                        # No surviving candidate at all: the pair can
                        # never extend to a complete placement.
                        doomed = True
                        break
                    dsets = [demand_of.get(int(x)) for x in comp]
                    if all(d is not None and d[0] == grid
                           for d in dsets):
                        demands.append(
                            frozenset().union(*(d[1] for d in dsets)))
                if doomed or not sdr_exists(demands):
                    src_acc.append(u)
                    dst_acc.append(v)
                    n_pairs += 1
    if src_acc:
        bits.add_edges(np.asarray(src_acc), np.asarray(dst_acc))
    return n_pairs
