"""The exact mapping backend: a complete prover over the engine's own
search space.

`exact_map_dfg` walks the same (II, jitter) schedule lattice as
`bandmap.map_dfg` — the deterministic modulo scheduler at jitters
0..3 per II, II escalating from max(MII, ``min_ii``) — but replaces
the stochastic portfolio with the certificate machinery run to
*decision*:

- **Encoding.**  Per (II, jitter) schedule, the CP/SAT-style model is
  the mixed conflict graph itself: one variable per op over its
  candidate tuples (TIN port tuples, TOUT port tuples, QUAD PE slots,
  routing drives), pairwise constraints = occupancy cliques +
  dependency realizability + `bus_pressure_edges` + the Hall-style
  joint bus-demand bound (`repro.exact.hall`) folding per-(scope, bus,
  cycle) capacity into the graph.
- **Search.**  `certify._search_complete` with its MRV /
  most-constraining tie-break / forward checking and the *verified*
  row/column symmetry-orbit pruning, run in online mode: every
  complete conflict-free placement is handed to `validate_mapping`
  (the engine's single soundness authority — concrete bus-instance
  packing, LRF/GRF residency) as it is found.  Accept ⇒ SAT for this
  schedule; exhaustion with every placement rejected ⇒ UNSAT for this
  schedule (sound because the validator is equivariant under the
  fabric's row/column relabelings, so rejecting an orbit
  representative rejects its orbit — asserted in
  tests/test_exact_differential.py).
- **Verdicts.**  The first validator-accepted placement returns
  ``ok=True`` with ``optimal=True`` iff every lower (II, jitter)
  combination was certified UNSAT (or unschedulable): at II = MII the
  claim is absolute (MII is a sound lower bound for *any* modulo
  schedule); above it, it is optimality within the engine's schedule
  family — the exact guarantee the differential tests lean on, since
  the portfolio searches the same family and therefore can never beat
  a proven exact II.  If the whole range up to ``max_ii`` is certified,
  the result is ``ok=False`` with ``proved_infeasible=True`` — the
  certificate-backed negative the serve cache admits.

Budget knobs
------------
``node_budget`` caps CSP nodes per (II, jitter) combination (the knob
`map_dfg(backend="exact")` maps ``certify_budget`` onto).  A
combination that exhausts the budget is *unknown*: the backend keeps
escalating II and can still return a mapping, but drops the
``optimal`` / ``proved_infeasible`` claims — budgets degrade the
claim, never the soundness.  ``cancel`` (`core.cancel.CancelToken`) is
polled between combinations and every few dozen search nodes; a
cancelled run returns a claim-less ``ok=False`` result, which is how
the race driver (`repro.exact.race`) discards a losing prover
mid-search.
"""

from __future__ import annotations

import time as _time

import numpy as np

from repro.core.bandmap import MappingResult
from repro.core.certify import IICertificate, certify_ii_infeasible
from repro.core.cgra import CGRAConfig
from repro.core.conflict import build_conflict_graph
from repro.core.dfg import DFG
from repro.core.mis import ROW_CACHE_LIMIT, mis_indices
from repro.core.options import MapOptions
from repro.core.schedule import mii, schedule_dfg
from repro.core.validate import validate_mapping
from repro.obs.trace import live

from .hall import hall_pressure_edges


class _ValidateSink:
    """`on_solution` callback: validate each complete placement the CSP
    enumerates, keep the first accepted one."""

    def __init__(self, sched, cg, cgra) -> None:
        self.sched, self.cg, self.cgra = sched, cg, cgra
        self.tried = 0
        self.accepted: tuple | None = None

    def __call__(self, memb: np.ndarray) -> bool:
        self.tried += 1
        placement = {self.cg.vertices[i].op: self.cg.vertices[i]
                     for i in mis_indices(memb)}
        report = validate_mapping(self.sched, self.cgra, placement)
        if report.ok:
            self.accepted = (placement, report)
            return True
        return False


def exact_map_dfg(dfg: DFG, cgra: CGRAConfig,
                  options: "MapOptions | dict | None" = None, *,
                  cancel=None, tracer=None, **kwargs) -> MappingResult:
    """Prove the engine-optimal II (or certified infeasibility) for one
    DFG — see the module docstring for the exact claims.  Accepts the
    same `MapOptions` / dict / legacy-keyword forms as `map_dfg` so the
    race driver can hand both backends the same problem; the CSP node
    budget is ``certify.budget`` (the historical ``node_budget`` keyword
    is still accepted as an alias) and ``certify.hall`` gates the joint
    bus-demand bound (on by default — it only ever strengthens UNSAT
    proofs)."""
    if "node_budget" in kwargs:
        kwargs = dict(kwargs)
        kwargs["certify_budget"] = kwargs.pop("node_budget")
    opts = MapOptions.coerce(options, kwargs)
    mode, seed = opts.mode, opts.seed
    sch, ct = opts.schedule, opts.certify
    trc = live(tracer)
    t_start = _time.perf_counter()
    the_mii = mii(dfg, cgra)
    cache_limit = ROW_CACHE_LIMIT if opts.portfolio.row_cache_limit \
        is None else opts.portfolio.row_cache_limit
    certificates: list[IICertificate] = []
    proved_all = True      # every combination below the cursor decided
    attempts = 0
    last = (None, 0, (0, 0))
    cancelled = False
    for cur_ii in range(max(the_mii, sch.min_ii or 0), sch.max_ii + 1):
        for jitter in (0, 1, 2, 3):
            if cancel is not None and cancel.is_set():
                cancelled = True
                break
            try:
                sched = schedule_dfg(dfg, cgra, mode=mode, ii=cur_ii,
                                     max_ii=cur_ii, use_grf=sch.use_grf,
                                     jitter=jitter, seed=seed,
                                     max_bus_fanout=sch.max_bus_fanout)
            except RuntimeError:
                # The deterministic scheduler produces nothing at this
                # combination — there is no schedule to bind, so the
                # combination is decided (vacuously UNSAT within the
                # engine's family), not unknown.
                continue
            cg = build_conflict_graph(sched, cgra,
                                      bus_pressure=opts.bus_pressure,
                                      tracer=tracer)
            if ct.hall:
                hall_pressure_edges(cg.bits, cg.vertices,
                                    cg.op_vertices, sched, cgra)
            n_ops = len(sched.dfg.ops)
            # Memoized on the graph; hall edges are already folded in,
            # so the cache sees the strengthened adjacency.
            shared_u8 = cg.row_cache(cache_limit)
            sink = _ValidateSink(sched, cg, cgra)
            with trc.span("exact-csp", ii=cur_ii, jitter=jitter,
                          n_ops=n_ops) as xsp:
                cert, _ = certify_ii_infeasible(
                    cg, sched, cgra, jitter=jitter,
                    node_budget=ct.budget, row_cache=shared_u8,
                    row_cache_limit=cache_limit, on_solution=sink,
                    cancel=cancel, tracer=tracer)
                xsp.set(validations=sink.tried,
                        verdict="sat" if sink.accepted is not None
                        else "unsat" if cert is not None else "unknown")
                if cert is not None:
                    xsp.set(nodes=cert.nodes)
            trc.count("exact.validations", sink.tried)
            attempts += sink.tried
            last = (sched, n_ops, (cg.n, cg.n_edges))
            if sink.accepted is not None:
                placement, report = sink.accepted
                return MappingResult(
                    ok=True, mode=mode, ii=cur_ii, mii=the_mii,
                    n_routing_pes=sched.n_routing_ops,
                    ports_per_vio=dict(sched.ports_allocated),
                    placement=placement, sched=sched, report=report,
                    cg_size=(cg.n, cg.n_edges), mis_size=n_ops,
                    n_ops=n_ops, attempts=attempts,
                    wall_s=_time.perf_counter() - t_start,
                    certificates=certificates, optimal=proved_all,
                    backend="exact")
            if cert is not None:
                certificates.append(cert)
            else:
                # Budget out (or cancelled mid-search): this
                # combination is unknown, every claim past it degrades.
                proved_all = False
        if cancelled:
            break
    sched, n_ops, cg_size = last
    return MappingResult(
        ok=False, mode=mode, ii=sched.ii if sched else -1, mii=the_mii,
        n_routing_pes=sched.n_routing_ops if sched else 0,
        ports_per_vio=dict(sched.ports_allocated) if sched else {},
        placement={}, sched=sched, report=None, cg_size=cg_size,
        mis_size=0, n_ops=n_ops, attempts=attempts,
        wall_s=_time.perf_counter() - t_start,
        certificates=certificates,
        proved_infeasible=proved_all and not cancelled,
        backend="exact")
