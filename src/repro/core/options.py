"""`MapOptions` — the single source of truth for mapping knobs.

`map_dfg` grew 18 keyword arguments across PRs 1-8 (schedule shaping,
certificate budgets, portfolio tuning, backend selection); every engine
module read its slice of them from loose kwargs or option dicts, and
`serve.cache` fingerprinted the raw dict.  This module consolidates
them into one frozen dataclass tree:

- `ScheduleOptions`  — II range and schedule shaping (``max_ii``,
  ``min_ii``, ``use_grf``, ``max_bus_fanout``).
- `CertifyOptions`   — certificate stages, exact-search budgets and the
  static pre-pass (``enabled``, ``budget``, ``n_exact_placements``,
  ``static_prepass``, ``hall``, ``exact_node_budget``).
- `PortfolioOptions` — the stochastic engine (``restarts``, ``iters``,
  ``engine="numpy"|"device"``, ``device_seeds``, ``group_move``,
  ``row_cache_limit``).
- `MapOptions`       — top level: ``mode``, ``seed``, ``backend``,
  ``bus_pressure`` + the three groups above.

Engine modules (`core.bandmap`, `repro.exact`, `repro.comap`,
`serve.scheduler`) read knobs ONLY from a `MapOptions` instance — the
``options-single-source`` rule in `repro.analysis.astlint` forbids them
from pulling a knob name out of a dict.  Legacy keyword calls keep
working through exactly one adapter, :meth:`MapOptions.from_kwargs`
(unknown keys warn, they do not raise — forward compatibility for
option dicts that travel through the serve tier).

Fingerprint stability
---------------------
:meth:`MapOptions.fingerprint` is the cache-key ingredient
`serve.cache.options_fingerprint` delegates to.  It hashes the *sparse
legacy-kwarg rendering* — only fields that differ from their defaults,
under their legacy kwarg names, with ``seed`` always included — using
the exact formula the serve tier used before this module existed
(``sha256(repr(sorted(d.items())))[:12]``).  Every option dict the
serving scheduler historically produced (request options + a resolved
seed) renders to the same sparse dict, so on-disk cache entries written
before the migration still hit.
"""

from __future__ import annotations

import dataclasses
import hashlib
import warnings

from .mis import GroupMoveConfig


@dataclasses.dataclass(frozen=True)
class ScheduleOptions:
    """II range + schedule shaping (see `core.schedule.schedule_dfg`)."""
    max_ii: int = 32
    min_ii: int | None = None
    use_grf: bool | None = None
    max_bus_fanout: int | None = None


@dataclasses.dataclass(frozen=True)
class CertifyOptions:
    """Certificate stages + exact-search budgets (`core.certify`,
    `repro.exact`).  ``budget`` is the per-(II, jitter) CSP node budget
    (the old ``certify_budget``); ``exact_node_budget`` overrides it
    for the race's prover side only (`exact.race_map_dfg`)."""
    enabled: bool = True
    budget: int = 200_000
    n_exact_placements: int = 4
    static_prepass: bool = True
    hall: bool = True
    exact_node_budget: int | None = None


@dataclasses.dataclass(frozen=True)
class PortfolioOptions:
    """The stochastic MIS engine.  ``engine`` selects the numpy
    lock-step portfolio (`core.mis.PortfolioSBTS`, the oracle) or the
    accelerator-resident vmapped engine (`core.mis_device.DeviceSBTS`);
    ``device_seeds`` is the device engine's trajectory count (the numpy
    engine's count is ``restarts``, scaled by the II=MII boost)."""
    restarts: int = 10
    iters: int = 20_000
    engine: str = "numpy"
    device_seeds: int = 1024
    group_move: GroupMoveConfig | None = None
    row_cache_limit: int | None = None

    def __post_init__(self):
        if self.group_move is True:
            object.__setattr__(self, "group_move", GroupMoveConfig())
        elif self.group_move is False:
            object.__setattr__(self, "group_move", None)
        if self.engine not in ("numpy", "device"):
            raise ValueError(
                f"unknown portfolio engine {self.engine!r} "
                f"(expected 'numpy' or 'device')")


#: legacy `map_dfg` kwarg name -> (group attr | None, field name).
LEGACY_KNOBS: dict[str, tuple[str | None, str]] = {
    "mode": (None, "mode"),
    "seed": (None, "seed"),
    "backend": (None, "backend"),
    "bus_pressure": (None, "bus_pressure"),
    "max_ii": ("schedule", "max_ii"),
    "min_ii": ("schedule", "min_ii"),
    "use_grf": ("schedule", "use_grf"),
    "max_bus_fanout": ("schedule", "max_bus_fanout"),
    "certify": ("certify", "enabled"),
    "certify_budget": ("certify", "budget"),
    "n_exact_placements": ("certify", "n_exact_placements"),
    "static_prepass": ("certify", "static_prepass"),
    "hall": ("certify", "hall"),
    "exact_node_budget": ("certify", "exact_node_budget"),
    "mis_restarts": ("portfolio", "restarts"),
    "mis_iters": ("portfolio", "iters"),
    "engine": ("portfolio", "engine"),
    "device_seeds": ("portfolio", "device_seeds"),
    "group_move": ("portfolio", "group_move"),
    "row_cache_limit": ("portfolio", "row_cache_limit"),
}


@dataclasses.dataclass(frozen=True)
class MapOptions:
    """Every `map_dfg` knob, grouped.  See the module docstring."""
    mode: str = "bandmap"
    seed: int = 0
    backend: str = "portfolio"
    bus_pressure: bool = True
    schedule: ScheduleOptions = ScheduleOptions()
    certify: CertifyOptions = CertifyOptions()
    portfolio: PortfolioOptions = PortfolioOptions()

    # ------------------------------------------------------- adapters
    @staticmethod
    def from_kwargs(**kwargs) -> "MapOptions":
        """THE legacy adapter: flat `map_dfg`-style kwargs -> options
        tree.  Unknown keys warn and are dropped (an option dict from a
        newer client must not crash an older server)."""
        groups: dict[str, dict] = {"schedule": {}, "certify": {},
                                   "portfolio": {}}
        top: dict = {}
        unknown = []
        for key, value in kwargs.items():
            spec = LEGACY_KNOBS.get(key)
            if spec is None:
                unknown.append(key)
                continue
            group, field = spec
            (top if group is None else groups[group])[field] = value
        if unknown:
            warnings.warn(
                f"MapOptions.from_kwargs: unknown option keys "
                f"{sorted(unknown)} ignored", stacklevel=2)
        return MapOptions(
            schedule=ScheduleOptions(**groups["schedule"]),
            certify=CertifyOptions(**groups["certify"]),
            portfolio=PortfolioOptions(**groups["portfolio"]), **top)

    @staticmethod
    def coerce(options: "MapOptions | dict | None",
               kwargs: dict | None = None) -> "MapOptions":
        """Entry-point glue: accept a `MapOptions`, an option dict, or
        legacy kwargs (exactly one of ``options`` / ``kwargs``)."""
        if options is None:
            return MapOptions.from_kwargs(**(kwargs or {}))
        if kwargs:
            raise TypeError(
                "pass either options=MapOptions(...) or legacy keyword "
                f"arguments, not both (got extra {sorted(kwargs)})")
        if isinstance(options, MapOptions):
            return options
        if isinstance(options, dict):
            return MapOptions.from_kwargs(**options)
        raise TypeError(f"options must be MapOptions | dict | None, "
                        f"got {type(options).__name__}")

    def to_kwargs(self, *, sparse: bool = True) -> dict:
        """Render back to flat legacy kwargs.  ``sparse`` keeps only
        fields that differ from the defaults (plus ``seed``, always) —
        the canonical form :meth:`fingerprint` hashes."""
        defaults = _DEFAULTS
        out = {}
        for key, (group, field) in LEGACY_KNOBS.items():
            holder = self if group is None else getattr(self, group)
            value = getattr(holder, field)
            if sparse and key != "seed" \
                    and value == getattr(
                        defaults if group is None
                        else getattr(defaults, group), field):
                continue
            out[key] = value
        return out

    def replace(self, **kwargs) -> "MapOptions":
        """`dataclasses.replace` over *legacy* kwarg names (group
        routing included), e.g. ``opts.replace(seed=3, max_ii=8)``."""
        merged = self.to_kwargs(sparse=False)
        merged.update(kwargs)
        return MapOptions.from_kwargs(**merged)

    # ---------------------------------------------------- fingerprint
    def fingerprint(self) -> str:
        """Cache-key fingerprint — byte-compatible with the serve
        tier's historical ``sha256(repr(sorted(dict.items())))[:12]``
        over its sparse option dicts (see module docstring)."""
        d = self.to_kwargs(sparse=True)
        return hashlib.sha256(
            repr(sorted(d.items())).encode()).hexdigest()[:12]


_DEFAULTS = MapOptions()
