"""BandMap core: the paper's contribution (CGRA mapping with bandwidth
allocation) plus the TPU-mesh bandwidth planner built on the same machinery.
"""

from .bandmap import MappingResult, compare_modes, map_dfg
from .bitset import BitsetGraph
from .cancel import CancelToken
from .certify import IICertificate, certify_ii_infeasible
from .cgra import CGRAConfig
from .dfg import DFG, Edge, Op, OpKind
from .kernels_cnkm import (EXTRA_KERNELS, PAPER_KERNELS,
                           all_paper_kernels, cnkm_name, make_cnkm)
from .mis import (GroupMoveConfig, greedy_mis, solve_mis,
                  solve_mis_portfolio)
from .options import (CertifyOptions, MapOptions, PortfolioOptions,
                      ScheduleOptions)
from .schedule import ScheduledDFG, mii, res_mii, schedule_dfg
from .tec import TEC
from .workloads import (COMAP_16X16_SPECS, TraceRequest, WorkloadSpec,
                        generate, make_loop_kernel, make_reduction,
                        make_request_trace, make_stencil,
                        make_tightly_coupled, permute_dfg,
                        scale_16x16_loop, serve_catalog, sweep_specs)

__all__ = [
    "MappingResult", "compare_modes", "map_dfg", "BitsetGraph",
    "CancelToken", "IICertificate", "certify_ii_infeasible",
    "CGRAConfig", "DFG", "Edge", "Op", "OpKind", "EXTRA_KERNELS",
    "PAPER_KERNELS", "all_paper_kernels", "cnkm_name", "make_cnkm",
    "GroupMoveConfig", "greedy_mis", "solve_mis", "solve_mis_portfolio",
    "MapOptions", "ScheduleOptions", "CertifyOptions",
    "PortfolioOptions",
    "ScheduledDFG", "mii", "res_mii", "schedule_dfg", "TEC",
    "COMAP_16X16_SPECS", "TraceRequest", "WorkloadSpec", "generate",
    "make_loop_kernel", "make_reduction", "make_request_trace",
    "make_stencil", "make_tightly_coupled", "permute_dfg",
    "scale_16x16_loop", "serve_catalog", "sweep_specs",
]
