"""Cheap II-infeasibility certificates for the binding phase.

Before `bandmap.map_dfg` spends a full portfolio budget (K seeds × 20k
SBTS iterations × repair retries) on one (II, jitter) schedule, this
module tries to *prove* that no complete binding exists, in three stages
of increasing strength (and cost):

1. **Resource-slot counting** — per modulo slot, the number of ops whose
   every candidate occupies one resource class (PE / IPORT / OPORT
   instances) against the class capacity.  Pure arithmetic over the
   schedule; catches over-packed hand-built schedules in microseconds.
2. **Greedy clique extension** — each op's candidate set is a clique; its
   greedy extension is the set of vertices adjacent to *every* candidate
   (one AND-reduction over the packed adjacency rows).  If another op's
   whole candidate set lies inside that extension, the two op-cliques
   merge: a clique cover of the vertex set with fewer cliques than ops,
   so MIS < |ops| and the schedule is unbindable.  Vectorised over the
   ``uint64 [n, words]`` rows; milliseconds.
3. **Bounded exhaustive search** — exact CSP over (op → candidate) with
   most-constrained-op ordering and forward checking through the unpacked
   row cache.  Exhausting the space *is* the certificate: no complete
   independent placement exists.  The node budget keeps the worst case
   bounded; past it the result is "unknown", never a false certificate.
   The search runs in two phases: a cheap plain pass with a small node
   budget (feasible schedules usually resolve in tens of nodes), then —
   only on escalation — a symmetry-pruned pass that branches solely on
   orbit representatives: the homogeneous PEA makes the conflict graph
   invariant under row and column permutations, so candidates
   referencing only so-far-unused rows/columns are interchangeable
   under the stabilizer of the partial assignment.  That invariance is
   *verified* before use (every row/column transposition generator is
   checked against the unpacked adjacency; graphs that fail — e.g. a
   future heterogeneous PEA — silently fall back to the exact
   non-symmetric search), so the pruning can never manufacture a false
   certificate.  It is what turns the BusMap II=MII exhaustions from
   ~10^5 nodes into a few hundred.  Graphs past the engine's
   ROW_CACHE_LIMIT skip the unpacked cache (per-move row unpack, no
   symmetry) rather than materialising n^2 bytes.

What a certificate proves — and what it does not
------------------------------------------------
A certificate is a proof that **this scheduled DFG** (one II, one jitter,
one routing-op pre-allocation) admits no complete conflict-free binding
under the pairwise conflict rules the graph encodes (including the
bus-pressure edges when the caller built the graph with them — those are
themselves sound for complete placements, see `conflict.py`).  It is NOT
a proof that the II itself is infeasible for the kernel: a different
schedule at the same II (other jitter, other routing split) may bind, and
`map_dfg` accordingly skips only the certified (II, jitter) combination.
The converse also does not hold: stage-3 *finding* a complete placement
does not certify the II feasible — the validator may still reject it on
the capacity structure a pairwise graph cannot express (flexible
bus-instance packing, LRF/GRF residency), in which case the portfolio
search proceeds exactly as before.
"""

from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from repro.obs.trace import live

from .cgra import CGRAConfig
from .conflict import QUAD, TIN, TOUT, ConflictGraph
from .dfg import OpKind
from .mis import ROW_CACHE_LIMIT
from .schedule import ScheduledDFG

# Node budget of the plain first pass; symmetry verification (an
# O((rows+cols) * n^2) check) is paid only when a schedule survives it.
_PLAIN_NODES_FIRST = 4096


@dataclasses.dataclass(frozen=True)
class IICertificate:
    """Witness that one (II, jitter) schedule admits no complete binding."""
    ii: int
    jitter: int
    # 'resource-count' | 'clique-merge' | 'exhausted', plus
    # 'static-demand' for the schedule-free pre-pass bounds
    # (`repro.analysis`): those carry jitter=-1, meaning the claim
    # covers every jitter of the II at once.
    stage: str
    detail: str      # human-readable witness
    nodes: int       # stage-3 search nodes spent (0 for stages 1-2)
    wall_s: float

    def summary(self) -> str:
        return (f"II={self.ii} jitter={self.jitter} infeasible "
                f"[{self.stage}] {self.detail} "
                f"({self.nodes} nodes, {self.wall_s * 1e3:.1f} ms)")


def _resource_count_bound(sched: ScheduledDFG,
                          cgra: CGRAConfig) -> str | None:
    """Stage 1: per-slot op counts vs resource-class capacity."""
    ii = sched.ii
    classes = {OpKind.COMPUTE: "pe", OpKind.ROUTE: "pe",
               OpKind.VIN: "iport", OpKind.VOUT: "oport"}
    caps = {"pe": cgra.n_pes, "iport": cgra.n_iports,
            "oport": cgra.n_oports}
    counts: dict[tuple, int] = {}
    for oid, op in sched.dfg.ops.items():
        key = (classes[op.kind], sched.time[oid] % ii)
        counts[key] = counts.get(key, 0) + 1
    for (cls, m), c in counts.items():
        if c > caps[cls]:
            return f"{c} ops need {caps[cls]} {cls} instances at slot {m}"
    return None


def _clique_merge_bound(cg: ConflictGraph) -> str | None:
    """Stage 2: greedy clique extension over packed rows — two ops whose
    candidate cliques merge into one clique can never both be placed."""
    ops = sorted(cg.op_vertices)
    k = len(ops)
    if k < 2 or cg.n == 0:
        return None
    words = cg.bits.words
    ext = np.empty((k, words), dtype=np.uint64)   # adj to ALL candidates
    dom = np.zeros((k, words), dtype=np.uint64)   # candidate bitset
    for i, o in enumerate(ops):
        ids = np.asarray(cg.op_vertices[o], dtype=np.int64)
        if ids.size == 0:
            return f"op {o} has no candidates"
        ext[i] = np.bitwise_and.reduce(cg.bits.rows[ids], axis=0)
        np.bitwise_or.at(dom[i], ids >> 6,
                         np.uint64(1) << (ids & 63).astype(np.uint64))
    # ops i, j merge iff dom[j] ⊆ ext[i]: every candidate of j conflicts
    # with every candidate of i.  One [k, k, words] broadcast.
    outside = (dom[None, :, :] & ~ext[:, None, :]).any(axis=2)
    np.fill_diagonal(outside, True)
    hit = np.argwhere(~outside)
    if hit.size:
        i, j = hit[0]
        return (f"ops {ops[int(i)]} and {ops[int(j)]} are mutually "
                f"exclusive (their candidate cliques merge)")
    return None


def _vertex_key(v) -> tuple:
    return (v.op, v.kind, v.port, v.mode, v.pe, v.drive)


def _axis_swap_perm(vertices, axis: str, a: int, b: int) -> np.ndarray | None:
    """Vertex permutation induced by swapping rows (axis='row') or
    columns (axis='col') ``a`` and ``b`` of the PEA, or None when some
    vertex has no image (a non-uniform candidate set)."""
    from .tec import COL, ROW

    def sw(x):
        return b if x == a else a if x == b else x

    index = {_vertex_key(v): v.idx for v in vertices}
    perm = np.empty(len(vertices), dtype=np.int64)
    for v in vertices:
        port, pe, drive = v.port, v.pe, v.drive
        if axis == "row":
            if v.kind == TIN:
                port = sw(port)
            if v.kind == QUAD:
                pe = (sw(pe[0]), pe[1])
                if drive is not None and drive[0] == ROW:
                    drive = (ROW, sw(drive[1]))
        else:
            if v.kind == TOUT:
                port = sw(port)
            if v.kind == QUAD:
                pe = (pe[0], sw(pe[1]))
                if drive is not None and drive[0] == COL:
                    drive = (COL, sw(drive[1]))
        img = index.get((v.op, v.kind, port, v.mode, pe, drive))
        if img is None:
            return None
        perm[v.idx] = img
    return perm


def _symmetry_attrs(cg: ConflictGraph, cgra: CGRAConfig | None,
                    u8: np.ndarray) -> tuple | None:
    """Row/column references per vertex, iff the graph is verified
    invariant under every row/column transposition generator."""
    vertices = getattr(cg, "vertices", None)
    if vertices is None or cgra is None:
        return None
    from .tec import ROW
    for axis, count in (("row", cgra.rows), ("col", cgra.cols)):
        for x in range(1, count):
            perm = _axis_swap_perm(vertices, axis, 0, x)
            if perm is None or not (u8[perm][:, perm] == u8).all():
                return None
    n = cg.n
    vrow = np.full(n, -1, dtype=np.int64)
    vcol = np.full(n, -1, dtype=np.int64)
    vdrv = np.full(n, -1, dtype=np.int64)
    for v in vertices:
        if v.kind == TIN:
            vrow[v.idx] = v.port
        elif v.kind == TOUT:
            vcol[v.idx] = v.port
        else:
            vrow[v.idx], vcol[v.idx] = v.pe
            if v.drive is not None:
                vdrv[v.idx] = 0 if v.drive[0] == ROW else 1
    return vrow, vcol, vdrv


def _search_complete(cg: ConflictGraph, node_budget: int,
                     row_cache: np.ndarray | None = None,
                     cgra: CGRAConfig | None = None,
                     n_solutions: int = 1,
                     row_cache_limit: int | None = None,
                     on_solution=None, cancel=None, tracer=None,
                     ) -> tuple[bool | None, list[np.ndarray], int]:
    """Stage 3: exact bounded CSP.  Returns (verdict, placements, nodes):
    verdict False = proven infeasible, True = ``placements`` holds up to
    ``n_solutions`` distinct complete independent placements (bool [n]
    memberships, found by continuing the backtracking past the first
    hit), None = budget exhausted before either outcome.

    Enumerating several placements is what closes the residual slow
    path in `map_dfg`: when the validator rejects the first placement's
    bus packing, the next candidates are already in hand — the search
    yields them for a few extra nodes — instead of falling back to the
    full portfolio.

    ``on_solution`` turns the enumeration into an online decision
    procedure (the exact backend's mode, `repro.exact`): each complete
    placement is handed to the callback as a bool [n] membership; a
    True return accepts it and stops the search (verdict True, the
    placement recorded), a False return discards it and the search
    *continues exhausting the space*.  Exhaustion with every placement
    discarded is verdict False: no complete conflict-free placement the
    callback accepts exists.  Under the symmetry-pruned pass that claim
    extends to the full space only when the callback is equivariant
    under the verified row/column automorphisms — `validate_mapping`
    is (it reads row/column indices only as labels, and its restart
    RNG sequence is index-independent), which is what lets the exact
    backend treat an all-rejected exhaustion as UNSAT.

    ``cancel`` (a `core.cancel.CancelToken`) is polled every 64 nodes;
    a cancelled search returns verdict None (unknown), never a proof.
    """
    n = cg.n
    ops = sorted(cg.op_vertices)
    k = len(ops)
    if k == 0:
        return True, [np.zeros(0, dtype=bool)], 0
    # Unpacked rows: share the caller's cache, or materialise one only
    # within the engine's cache bound; past it fall back to per-move
    # row unpack (O(n/8) per expansion, no n^2 allocation).  uint8 rows
    # add directly into the int16 banned stack — no widened copy.
    cache_limit = ROW_CACHE_LIMIT if row_cache_limit is None \
        else row_cache_limit
    if row_cache is not None:
        u8 = row_cache
    elif 0 < n * n <= cache_limit:
        u8 = cg.bits.rows_u8(np.arange(n))
    else:
        u8 = None

    def row(v: int) -> np.ndarray:
        return u8[v] if u8 is not None else cg.bits.row_u8(v)

    op_code = np.empty(n, dtype=np.int64)
    doms = []
    offsets = np.empty(k, dtype=np.int64)
    for i, o in enumerate(ops):
        ids = np.asarray(cg.op_vertices[o], dtype=np.int64)
        op_code[ids] = i
        doms.append(ids)
        offsets[i] = ids[0] if ids.size else 0
    # build_conflict_graph lays candidates out op-contiguously, which
    # turns the per-op alive counts into one reduceat; fall back to
    # bincount for graphs assembled differently.
    contiguous = (all(d.size and (np.diff(d) == 1).all() for d in doms)
                  and (np.diff(offsets) > 0).all() and offsets[0] == 0
                  and doms[-1][-1] == n - 1)
    # MRV tie-break: among equally small domains, expand the op whose
    # candidates are the most constraining (highest mean degree) first —
    # its contradictions surface higher in the tree.  Empirically this
    # cuts the exhaustion on the tight BusMap II=MII instances by 1-2
    # orders of magnitude versus plain MRV.
    tb = np.array([float(np.bitwise_count(cg.bits.rows[d]).sum())
                   / max(d.size, 1) for d in doms])
    tb = -0.9 * tb / (tb.max() + 1.0)
    # Orbit-pruning hits, accumulated locally (one list append per skip
    # would be tracer traffic inside the node loop; one count at the
    # end is free) and published as the `certify.orbit_skips` counter.
    orbit_skips = [0]

    def run(sym: tuple | None, budget: int,
            ) -> tuple[bool | None, list[np.ndarray], int]:
        unassigned = np.ones(k, dtype=bool)
        chosen = np.full(k, -1, dtype=np.int64)
        stack = np.zeros((k + 2, n), dtype=np.int16)
        nodes = [0]
        solutions: list[np.ndarray] = []

        def dfs(depth: int, used_rows: frozenset,
                used_cols: frozenset) -> bool | None:
            nodes[0] += 1
            if nodes[0] > budget:
                return None
            if cancel is not None and not nodes[0] & 63 \
                    and cancel.is_set():
                return None
            if not unassigned.any():
                if on_solution is not None:
                    # Online mode: accept (stop) or discard (keep
                    # exhausting) — see the docstring's UNSAT claim.
                    memb = np.zeros(n, dtype=bool)
                    memb[chosen[chosen >= 0]] = True
                    if on_solution(memb):
                        solutions.append(chosen.copy())
                        return True
                    return False
                # Complete placement: record it and keep backtracking
                # (returning False) until the requested count is in hand.
                solutions.append(chosen.copy())
                return len(solutions) >= n_solutions
            banned = stack[depth]
            alive = banned == 0
            if contiguous:
                counts = np.add.reduceat(alive,
                                         offsets).astype(np.float64)
            else:
                counts = np.bincount(op_code[alive],
                                     minlength=k).astype(np.float64)
            counts += tb
            counts[~unassigned] = np.inf
            i = int(np.argmin(counts))
            if counts[i] < 0.0:
                return False
            unassigned[i] = False
            dom = doms[i]
            seen: set = set()
            result: bool | None = False
            for v in dom[alive[dom]]:
                nur, nuc = used_rows, used_cols
                if sym is not None:
                    # Orbit representative: under the stabilizer of the
                    # partial assignment (which references only used
                    # rows/cols), all still-unused rows are
                    # interchangeable, and likewise columns — one
                    # candidate per (drive-kind, row-or-fresh,
                    # col-or-fresh) key suffices.
                    vrow, vcol, vdrv = sym
                    r_ref, c_ref = int(vrow[v]), int(vcol[v])
                    key = (int(vdrv[v]),
                           r_ref if r_ref < 0 or r_ref in used_rows
                           else -2,
                           c_ref if c_ref < 0 or c_ref in used_cols
                           else -2)
                    if key in seen:
                        orbit_skips[0] += 1
                        continue
                    seen.add(key)
                    if r_ref >= 0:
                        nur = used_rows | {r_ref}
                    if c_ref >= 0:
                        nuc = used_cols | {c_ref}
                chosen[i] = v
                np.add(banned, row(v), out=stack[depth + 1])
                r = dfs(depth + 1, nur, nuc)
                if r is None or r:
                    result = r
                    break
            else:
                chosen[i] = -1
            unassigned[i] = True
            return result

        verdict = dfs(0, frozenset(), frozenset())
        return verdict, solutions, nodes[0]

    # Phase 1: plain search under a small budget — feasible schedules
    # usually resolve here, skipping the symmetry verification cost.
    # Graphs past the row-cache bound stop here too: without the u8
    # cache every node pays an O(n) row unpack and the symmetry
    # verification (which needs the full cache) is unavailable, so a
    # six-figure node budget burns seconds per (II, jitter) with no
    # realistic chance of exhausting a |V_C| ~ 10^4 space — "unknown"
    # after the cheap pass is the honest verdict at that scale.
    budget1 = min(node_budget, _PLAIN_NODES_FIRST)
    verdict, sols, spent = run(None, budget1)
    if verdict is None and not sols and node_budget > budget1 \
            and u8 is not None:
        sym = _symmetry_attrs(cg, cgra, u8) if u8 is not None else None
        verdict, sols, spent2 = run(sym, node_budget - spent)
        spent += spent2
    placements = []
    for chosen in sols:
        p = np.zeros(n, dtype=bool)
        p[chosen[chosen >= 0]] = True
        placements.append(p)
    if placements:
        # An exhausted (False) or budget-out (None) sweep that still
        # recorded placements is a feasibility witness, not a proof.
        verdict = True
    trc = live(tracer)
    trc.count("certify.csp_nodes", spent)
    trc.count("certify.orbit_skips", orbit_skips[0])
    return verdict, placements, spent


def certify_ii_infeasible(cg: ConflictGraph, sched: ScheduledDFG,
                          cgra: CGRAConfig, *, jitter: int = 0,
                          node_budget: int = 200_000,
                          row_cache: np.ndarray | None = None,
                          n_placements: int = 1,
                          row_cache_limit: int | None = None,
                          on_solution=None, cancel=None, tracer=None,
                          ) -> tuple[IICertificate | None,
                                     list[np.ndarray] | None]:
    """Run the certificate stages against one scheduled DFG.

    Returns ``(certificate, placements)``: a certificate when the
    schedule is proven unbindable (placements is None); otherwise
    ``certificate`` is None and ``placements`` holds up to
    ``n_placements`` complete conflict-free membership vectors stage 3
    enumerated within budget for the caller to validate directly (the
    list is empty when the budget ran out before any was found).

    ``on_solution``/``cancel`` are forwarded to `_search_complete` (see
    its docstring): with a callback installed, an exhausted search whose
    every placement was discarded still certifies the schedule — the
    certificate detail records that the claim covers callback-accepted
    placements, not just conflict-free ones."""
    trc = live(tracer)
    with trc.span("certify", ii=sched.ii, jitter=jitter,
                  n_ops=len(cg.op_vertices), n_vertices=cg.n) as sp:
        t0 = _time.perf_counter()
        detail = _resource_count_bound(sched, cgra)
        if detail is not None:
            sp.set(stage="resource-count", nodes=0)
            return IICertificate(sched.ii, jitter, "resource-count",
                                 detail, 0,
                                 _time.perf_counter() - t0), None
        detail = _clique_merge_bound(cg)
        if detail is not None:
            sp.set(stage="clique-merge", nodes=0)
            return IICertificate(sched.ii, jitter, "clique-merge",
                                 detail, 0,
                                 _time.perf_counter() - t0), None
        skips0 = trc.counter_value("certify.orbit_skips")
        verdict, placements, nodes = _search_complete(
            cg, node_budget, row_cache=row_cache, cgra=cgra,
            n_solutions=n_placements, row_cache_limit=row_cache_limit,
            on_solution=on_solution, cancel=cancel, tracer=tracer)
        sp.set(nodes=nodes,
               orbit_skips=trc.counter_value("certify.orbit_skips")
               - skips0)
        if verdict is False:
            what = "validator-accepted" if on_solution is not None \
                else "complete independent"
            detail = (f"exhaustive search: no {what} placement "
                      f"of {len(cg.op_vertices)} ops over "
                      f"{cg.n} candidates")
            sp.set(stage="exhausted")
            return IICertificate(sched.ii, jitter, "exhausted", detail,
                                 nodes, _time.perf_counter() - t0), None
        sp.set(stage="open" if verdict is None else "placed")
        return None, placements
