"""Phase 1: modulo scheduling with quantitative bandwidth allocation, and the
phase-2 routing-resource pre-allocation that the scheduler triggers when the
allocation policy falls short (paper §III-A, Fig. 4).

Policy (verbatim from the paper): at current modulo time m, if RD(VIO) > M,
allocate the VIO Q = min(ceil(RD/M), #available input ports) ports.  If
Q < ceil(RD/M), or the number of available PEs is smaller than RD, routing
PEs are adopted.  Multi-port binding is modelled by cloning the VIO into Q
copies of the same datum, each occupying one port (Fig. 2(c)(e)).

BusMap mode forces Q = 1 (one port per datum) and always covers the surplus
with routing PEs — this is the baseline the paper compares against.

Coverage model (see DESIGN.md §3): a port delivers to the M PEs of its row;
a routing PE occupies one delivery slot, caches the datum, and re-drives a
bus the next cycle, reaching (rows - 1) additional PEs in its column.  With
a GRF, a datum parked in the GRF is readable by all PEs (capacity-limited),
so GRF delivery removes the coverage constraint entirely.
"""

from __future__ import annotations

import dataclasses
import heapq
import math

from .cgra import CGRAConfig
from .dfg import DFG, OpKind


@dataclasses.dataclass
class ScheduledDFG:
    dfg: DFG                        # includes VIO clones + routing ops
    ii: int
    mii: int
    time: dict[int, int]            # op_id -> scheduled time t
    delivery: dict[int, str]        # VIO op_id -> 'bus' | 'grf'
    ports_allocated: dict[int, int] # original VIO id -> Q

    def mslot(self, oid: int) -> int:
        return self.time[oid] % self.ii

    @property
    def n_routing_ops(self) -> int:
        return sum(1 for o in self.dfg.ops.values() if o.kind == OpKind.ROUTE)


def res_mii(dfg: DFG, cgra: CGRAConfig) -> int:
    """Resource-constrained MII."""
    return max(
        math.ceil(len(dfg.v_r) / cgra.n_pes),
        math.ceil(len(dfg.v_i) / cgra.n_iports),
        math.ceil(len(dfg.v_o) / cgra.n_oports),
        1,
    )


def mii(dfg: DFG, cgra: CGRAConfig) -> int:
    return max(res_mii(dfg, cgra), dfg.rec_mii())


def _route_pes_needed(n_consumers: int, cgra: CGRAConfig,
                      m_eff: int | None = None) -> int:
    """Routing PEs so one port + k routing PEs cover ``n_consumers``.

    coverage(k) = M - k + k*(rows-1): each routing PE takes one direct
    delivery slot in the port's row and adds rows-1 column-bus listeners.
    ``m_eff`` caps the direct per-port budget below the physical M (see
    the ``max_bus_fanout`` scheduling knob).
    """
    m, rows = cgra.pes_per_ibus, cgra.rows
    if m_eff is not None:
        m = min(m, m_eff)
    if n_consumers <= m:
        return 0
    gain = rows - 2  # net coverage gain per routing PE
    if gain <= 0:    # degenerate 1-/2-row arrays
        return n_consumers - m
    return math.ceil((n_consumers - m) / gain)


class _Scheduler:
    def __init__(self, dfg: DFG, cgra: CGRAConfig, mode: str, ii: int,
                 use_grf: bool, jitter: int = 0, seed: int = 0,
                 max_bus_fanout: int | None = None):
        self.dfg = dfg
        self.cgra = cgra
        self.mode = mode
        self.ii = ii
        self.use_grf = use_grf
        # Effective per-port delivery budget.  The paper's policy serves
        # up to M = pes_per_ibus consumers from one port; on wide arrays
        # (M = 16) that pins a whole fan-out to a single row, which
        # couples placement so hard that structurally mappable kernels
        # stop binding.  ``max_bus_fanout`` caps the budget: RD beyond
        # it allocates extra ports (bandmap: Q = ceil(RD/m_eff) clones,
        # the same split a 4x4 array would have produced) or routing
        # PEs (busmap), restoring placement freedom.  None = physical M
        # (exact paper behaviour).
        self.m_eff = cgra.pes_per_ibus if max_bus_fanout is None \
            else max(1, min(cgra.pes_per_ibus, max_bus_fanout))
        # Phase-4 diversity: jitter > 0 delays ops by a random 0..jitter
        # slots past ASAP, producing distinct schedules on retry (ASAP alone
        # is II-invariant, so plain II escalation adds no slack).
        self.jitter = jitter
        import numpy as _np
        self.rng = _np.random.default_rng(seed * 7919 + jitter * 131 + 7)
        self.pe = [0] * ii
        self.iport = [0] * ii
        self.oport = [0] * ii
        self.grf_live = 0
        self.time: dict[int, int] = {}
        self.delivery: dict[int, str] = {}
        self.ports_alloc: dict[int, int] = {}
        self.heights = dfg.heights()
        self.n_preds = {i: sum(1 for e in dfg.in_edges(i) if e.distance == 0)
                        for i in dfg.ops}
        self.ready: list[tuple[int, int]] = []
        for i, c in self.n_preds.items():
            if c == 0:
                heapq.heappush(self.ready, (-self.heights[i], i))
    # ------------------------------------------------------------- helpers
    def _pick(self, n: int) -> int:
        if self.jitter <= 0 or n <= 1:
            return 0
        return int(self.rng.integers(0, min(n, self.jitter + 1)))

    def est(self, oid: int) -> int:
        t = 0
        for e in self.dfg.in_edges(oid):
            if e.src in self.time:
                lag = self.time[e.src] + self.dfg.ops[e.src].latency
                t = max(t, lag - e.distance * self.ii)
        return max(t, 0)

    def _commit(self, oid: int, t: int) -> None:
        """Record time and release successors whose preds are all scheduled."""
        self.time[oid] = t
        for e in self.dfg.out_edges(oid):
            if e.distance == 0 and e.dst not in self.time:
                self.n_preds[e.dst] -= 1
                if self.n_preds[e.dst] == 0:
                    heapq.heappush(self.ready,
                                   (-self.heights[e.dst], e.dst))

    # --------------------------------------------------------------- VIO
    def _schedule_vio(self, oid: int, t: int) -> None:
        dfg, cgra, m = self.dfg, self.cgra, t % self.ii
        rd = dfg.rd(oid)
        m_bus = self.m_eff
        q_need = math.ceil(rd / m_bus)

        if self.use_grf and rd > m_bus and self.grf_live < cgra.grf:
            # Park the datum in the GRF: one port, coverage-unconstrained.
            self.iport[m] += 1
            self.grf_live += 1
            self.delivery[oid] = "grf"
            self.ports_alloc[oid] = 1
            self._commit(oid, t)
            return

        q = 1 if self.mode == "busmap" else min(q_need,
                                                cgra.n_iports - self.iport[m])
        q = max(q, 1)
        self.iport[m] += q
        self.delivery[oid] = "bus"
        self.ports_alloc[oid] = q

        # Split consumers among the Q port clones (Fig. 2(c)(e)).  Rewiring
        # happens BEFORE any successor bookkeeping so ready-counts stay exact.
        consumers = dfg.successors(oid)
        groups = [consumers]
        if q > 1:
            chunk = math.ceil(len(consumers) / q)
            groups = [consumers[k * chunk:(k + 1) * chunk] for k in range(q)]
            groups = [g for g in groups if g]
        clone_ids = [oid]
        for g in groups[1:]:
            cid = dfg.clone_vio(oid, g)
            clone_ids.append(cid)
            self.delivery[cid] = "bus"
            self.n_preds[cid] = 0
            self.heights[cid] = self.heights[oid]

        # Phase 2: per-clone routing pre-allocation for residual coverage.
        for cid, g in zip(clone_ids, groups):
            n_route = _route_pes_needed(len(g), cgra, self.m_eff)
            if n_route > 0:
                self._insert_routes(cid, n_route)

        for cid in clone_ids:
            self._commit(cid, t)

    def _insert_routes(self, host: int, n_route: int) -> None:
        """Move overflow consumers of ``host`` onto fresh routing ops (each
        re-broadcasts on its column bus, reaching rows-1 PEs)."""
        dfg, cgra = self.dfg, self.cgra
        consumers = dfg.successors(host)
        capacity = max(cgra.rows - 1, 1)
        direct = max(0, self.m_eff - n_route)
        overflow = consumers[direct:]
        for k in range(n_route):
            part = overflow[k * capacity:(k + 1) * capacity]
            if not part:
                break
            rid = dfg.add_op(OpKind.ROUTE, f"rt{host}_{k}")
            dfg.add_edge(host, rid)
            for c in part:
                # Carry the iteration distance onto the re-broadcast leg
                # so inter-iteration consumers keep their semantics.
                dists = [e.distance for e in dfg.edges
                         if e.src == host and e.dst == c]
                dfg.remove_edge(host, c)
                dfg.add_edge(rid, c, distance=max(dists, default=0))
            # Bookkeeping for the new op: its only pred is `host` (not yet
            # committed), so it becomes ready when host commits.  Consumers'
            # pred-counts are unchanged (vio edge swapped for route edge).
            self.n_preds[rid] = 1
            self.heights[rid] = 1 + max(
                (self.heights[c] for c in part if c in self.heights),
                default=0)

    # --------------------------------------------------------------- main
    def run(self) -> ScheduledDFG | None:
        cgra, ii = self.cgra, self.ii
        while self.ready:
            _, oid = heapq.heappop(self.ready)
            if oid in self.time:
                continue
            op = self.dfg.ops[oid]
            t0 = self.est(oid)
            placed = False
            if op.kind in (OpKind.COMPUTE, OpKind.ROUTE):
                # ASAP: aligned chains concentrate each VIO's consumers at
                # few modulo slots, which keeps the port allocation at the
                # paper's quantitative minimum Q = ceil(RD/M).
                cands = sorted(t for t in range(t0, t0 + ii)
                               if self.pe[t % ii] < cgra.n_pes)
                if cands:
                    t = cands[self._pick(len(cands))]
                    self.pe[t % ii] += 1
                    self._commit(oid, t)
                    placed = True
            elif op.kind == OpKind.VOUT:
                cands = sorted(t for t in range(t0, t0 + ii)
                               if self.oport[t % ii] < cgra.n_oports)
                if cands:
                    t = cands[self._pick(len(cands))]
                    self.oport[t % ii] += 1
                    self._commit(oid, t)
                    placed = True
            else:  # VIN: earliest slot with the full port allocation free,
                # falling back to the slot offering the most ports.
                rd = self.dfg.rd(oid)
                q_need = (1 if self.mode == "busmap"
                          else math.ceil(rd / self.m_eff))
                cands = [t for t in range(t0, t0 + ii)
                         if self.iport[t % ii] < cgra.n_iports]
                if cands:
                    full = [t for t in cands
                            if cgra.n_iports - self.iport[t % ii] >= q_need]
                    t = min(full) if full else min(
                        cands, key=lambda t: (self.iport[t % ii], t))
                    self._schedule_vio(oid, t)
                    placed = True
            if not placed:
                return None
        if len(self.time) != len(self.dfg.ops):
            return None
        # Loop-carried sanity: a back edge's source is unscheduled when
        # the list scheduler places its destination (est() skips it), so
        # the recurrence bound time[dst] + d*II >= time[src] + latency
        # must be re-checked once all ops have times.  A violation means
        # this II leaves too little slack for the cycle's latency —
        # reject and let II escalation (schedule_dfg / map_dfg) retry.
        for e in self.dfg.edges:
            if e.distance > 0 and (
                    self.time[e.dst] + e.distance * self.ii
                    < self.time[e.src] + self.dfg.ops[e.src].latency):
                return None
        self._retime_vios()
        return ScheduledDFG(self.dfg, ii, 0, self.time, self.delivery,
                            self.ports_alloc)

    def _retime_vios(self) -> None:
        """As-late-as-possible VIO retiming: deliver each datum just before
        its earliest consumer.  ASAP delivery parks data for the whole chain
        length, which overflows the GRF (and inflates LRF latch holds) for
        deep chains; just-in-time delivery keeps residency ~1 slot/datum —
        this is what lets GRF runs reach MII (paper §IV-B)."""
        ii = self.ii
        for oid in self.dfg.v_i:
            cons = [self.time[c] for c in self.dfg.successors(oid)
                    if c in self.time]
            if not cons:
                continue
            t_new = max(min(cons) - self.dfg.ops[oid].latency, 0)
            t_old = self.time[oid]
            if t_new <= t_old:
                continue
            m_old, m_new = t_old % ii, t_new % ii
            if m_old == m_new:
                self.time[oid] = t_new
                continue
            if self.iport[m_new] < self.cgra.n_iports:
                self.iport[m_old] -= 1
                self.iport[m_new] += 1
                self.time[oid] = t_new


def schedule_dfg(dfg: DFG, cgra: CGRAConfig, *, mode: str = "bandmap",
                 ii: int | None = None, max_ii: int = 64,
                 use_grf: bool | None = None, jitter: int = 0,
                 seed: int = 0,
                 max_bus_fanout: int | None = None) -> ScheduledDFG:
    """Iterative modulo scheduling.  Tries II = MII, MII+1, ... ≤ max_ii."""
    assert mode in ("bandmap", "busmap")
    if use_grf is None:
        use_grf = cgra.grf > 0
    the_mii = mii(dfg, cgra)
    start = ii if ii is not None else the_mii
    for cur_ii in range(start, max_ii + 1):
        out = _Scheduler(dfg.copy(), cgra, mode, cur_ii, use_grf,
                         jitter=jitter, seed=seed,
                         max_bus_fanout=max_bus_fanout).run()
        if out is not None:
            out.mii = the_mii
            return out
    raise RuntimeError(f"no schedule found for II <= {max_ii}")
