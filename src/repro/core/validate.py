"""Post-MIS mapping validation (and the concrete bus/cycle assignment the
pairwise conflict graph intentionally leaves open — see conflict.py).

Checks, for a complete placement (one vertex per op):

1. every PE/port resource instance is used at most once per modulo slot
   (re-verification of the conflict graph's occupancy edges);
2. a concrete **bus assignment** exists: every PE→PE transfer gets a
   (bus, cycle) with ≤1 driver per bus instance, honouring the fixed drives
   (VIO delivery on IBUS_r at its slot, VOO export on OBUS_c at its slot);
3. LRF capacity: weight residency (one slot per MAC hosted by a PE) plus
   transient hold intervals (producer-hold, consumer-latch) fit `lrf` on
   every (PE, slot), counting modulo-wraparound multiplicity;
4. GRF capacity for GRF-parked data.
"""

from __future__ import annotations

import dataclasses

from .cgra import CGRAConfig
from .conflict import QUAD, TIN, TOUT, Vertex
from .dfg import OpKind
from .schedule import ScheduledDFG
from .tec import COL, ROW


@dataclasses.dataclass
class ValidationReport:
    ok: bool
    violations: list[str]
    bus_assignment: dict  # (edge src,dst) -> (scope, idx, k, slot)
    lrf_peak: int
    grf_peak: int


def _assign_buses(transfers: list, fixed_used: set, ii: int,
                  n_buses: int = 2,
                  n_restarts: int = 6) -> tuple[dict, list[str]]:
    """Concrete (bus, cycle) allocation for PE->PE transfers.

    Transfers from one producer into one scope (row/column) are *broadcasts*:
    a single drive serves every listener whose [ready, use] window contains
    the drive cycle.  Per (producer, scope) group we compute a minimal stab
    set (classic interval stabbing), keep the per-stab slot flexibility, and
    then allocate bus instances most-constrained-first with randomized
    restarts."""
    import random

    # Group listeners by (producer, scope).
    groups: dict[tuple, list[tuple[int, list[int]]]] = {}
    for src, dst, scopes, window in transfers:
        groups.setdefault((src, scopes[0]), []).append((dst, window))

    best: tuple[dict, list[str]] | None = None
    for attempt in range(n_restarts):
        rng = random.Random(attempt * 7919 + 13)
        used = set(fixed_used)
        assignment: dict = {}
        viol: list[str] = []
        demands = []  # (scope, member_edges, candidate_slots)
        for (src, (scope, idx)), members in groups.items():
            ms = sorted(members, key=lambda x: x[1][-1])
            covered: set[int] = set()
            for dst, w in ms:
                if dst in covered:
                    continue
                t_stab = w[-1]
                grp = [(d, w2) for d, w2 in ms
                       if d not in covered and t_stab in w2]
                lo = max(w2[0] for _, w2 in grp)
                hi = min(w2[-1] for _, w2 in grp)
                slots = sorted({t % ii for t in range(lo, hi + 1)})
                demands.append(((scope, idx), [(src, d) for d, _ in grp],
                                slots))
                covered.update(d for d, _ in grp)
        rng.shuffle(demands)
        pending = list(demands)
        ok = True
        while pending:
            def opts(dm):
                (scope, idx), _, slots = dm
                return [(scope, idx, k, s)
                        for k in range(n_buses) for s in slots
                        if (scope, idx, k, s) not in used]
            pending.sort(key=lambda dm: len(opts(dm)))
            dm = pending.pop(0)
            o = opts(dm)
            if not o:
                viol.append(f"bus congestion: no (bus,cycle) for drives "
                            f"{dm[1]} scope={dm[0]} slots={dm[2]}")
                ok = False
                continue
            key = o[0] if attempt == 0 else rng.choice(o)
            used.add(key)
            for edge in dm[1]:
                assignment[edge] = key
        if ok:
            return assignment, []
        if best is None or len(viol) < len(best[1]):
            best = (assignment, viol)
    return best if best is not None else ({}, [])


def _interval_slots(a: int, b: int, ii: int) -> dict[int, int]:
    """Multiplicity per modulo slot of cycles a..b inclusive."""
    out: dict[int, int] = {}
    if b < a:
        return out
    length = b - a + 1
    base, rem = divmod(length, ii)
    for s in range(ii):
        out[s] = base
    for k in range(rem):
        out[(a + k) % ii] = out.get((a + k) % ii, 0) + 1
    return {s: c for s, c in out.items() if c}


def validate_mapping(sched: ScheduledDFG, cgra: CGRAConfig,
                     placement: dict[int, Vertex]) -> ValidationReport:
    dfg, ii = sched.dfg, sched.ii
    viol: list[str] = []

    # ---- 1. hard occupancy re-check -------------------------------------
    seen: dict[tuple, int] = {}
    for oid, v in placement.items():
        keys: list[tuple] = []
        if v.kind == TIN:
            keys.append(("iport", v.port, v.m))
        elif v.kind == TOUT:
            keys.append(("oport", v.port, v.m))
        else:
            keys.append(("pe", v.pe, v.m))
        for k in keys:
            if k in seen:
                viol.append(f"occupancy clash {k}: ops {seen[k]} vs {oid}")
            seen[k] = oid

    # ---- 2. bus assignment ----------------------------------------------
    fixed_used: set[tuple] = set()   # (scope, idx, k, slot)
    for oid, v in placement.items():
        if v.kind == TIN and v.mode == "bus":
            key = (ROW, v.port, 0, v.m)
            if key in fixed_used:
                viol.append(f"IBUS clash {key} (VIO {oid})")
            fixed_used.add(key)
        elif v.kind == TOUT:
            key = (COL, v.port, 0, v.m)
            if key in fixed_used:
                viol.append(f"OBUS clash {key} (VOO {oid})")
            fixed_used.add(key)

    # Flexible PE->PE transfers: group by (producer, scope) — one bus drive
    # is a broadcast serving every listener whose window contains it.
    # Adjacent PEs (|Δr|+|Δc| == 1) are wired by dedicated NSEW neighbour
    # links (Fig. 1): the consumer reads the producer's output register
    # directly, consuming no bus slot.
    transfers = []  # (src, dst, scopes, window_set)
    for e in dfg.edges:
        pv, cv = placement.get(e.src), placement.get(e.dst)
        if pv is None or cv is None or pv.kind != QUAD or cv.kind != QUAD:
            continue
        t_ready = sched.time[e.src] + dfg.ops[e.src].latency
        t_use = sched.time[e.dst] + e.distance * ii
        if t_use < t_ready:
            # Loop-carried recurrence violated: iteration i's consumer
            # would read before iteration i-distance's producer wrote.
            # Checked before the LRF / neighbour-link shortcuts — those
            # paths need the value ready too (distance-0 edges satisfy
            # this by scheduler construction; only distance > 0 edges,
            # whose source the list scheduler cannot see, can trip it).
            viol.append(f"recurrence violated on edge {e.src}->{e.dst}: "
                        f"use t={t_use} < ready t={t_ready}")
            continue
        if pv.pe == cv.pe:
            continue  # LRF path
        if (pv.drive is None and
                abs(pv.pe[0] - cv.pe[0]) + abs(pv.pe[1] - cv.pe[1]) == 1):
            continue  # neighbour link (no bus resource)
        scopes = []
        if pv.drive is not None:
            scopes.append(pv.drive)
        else:
            if pv.pe[0] == cv.pe[0]:
                scopes.append((ROW, pv.pe[0]))
            if pv.pe[1] == cv.pe[1]:
                scopes.append((COL, pv.pe[1]))
        if not scopes:
            viol.append(f"unroutable edge {e.src}->{e.dst}: "
                        f"{pv.pe} -> {cv.pe}")
            continue
        window = list(range(t_ready, min(t_use, t_ready + ii - 1) + 1))
        transfers.append((e.src, e.dst, scopes, window))

    assignment, bus_viol = _assign_buses(transfers, fixed_used, ii,
                                         n_buses=cgra.buses_per_scope)
    viol.extend(bus_viol)

    # ---- 3. LRF capacity --------------------------------------------------
    lrf: dict[tuple, dict[int, int]] = {}

    def add_interval(pe, a, b):
        slots = _interval_slots(a, b, ii)
        d = lrf.setdefault(pe, {})
        for s, c in slots.items():
            d[s] = d.get(s, 0) + c

    for oid, v in placement.items():
        if v.kind == QUAD and dfg.ops[oid].kind == OpKind.COMPUTE:
            # Weight residency: one permanent slot for the op's constant.
            d = lrf.setdefault(v.pe, {})
            for s in range(ii):
                d[s] = d.get(s, 0) + 1

    for e in dfg.edges:
        pv, cv = placement.get(e.src), placement.get(e.dst)
        if pv is None or cv is None:
            continue
        t_src, t_dst = sched.time[e.src], sched.time[e.dst] + e.distance * ii
        if pv.kind == TIN:
            if pv.mode == "bus" and cv.kind == QUAD:
                add_interval(cv.pe, t_src, t_dst)  # latch at delivery
        elif cv.kind == TOUT:
            add_interval(pv.pe, t_src + dfg.ops[e.src].latency, t_dst)
        elif pv.kind == QUAD and cv.kind == QUAD:
            t_ready = t_src + dfg.ops[e.src].latency
            if pv.pe == cv.pe:
                add_interval(pv.pe, t_ready, t_dst)
            else:
                key = assignment.get((e.src, e.dst))
                t_d = key[3] if key else t_ready % ii
                # producer holds until drive; consumer latches after.
                add_interval(pv.pe, t_ready, t_ready + ((t_d - t_ready) % ii))
                drive_abs = t_ready + ((t_d - t_ready) % ii)
                add_interval(cv.pe, drive_abs, t_dst)

    lrf_peak = 0
    for pe, d in lrf.items():
        peak = max(d.values(), default=0)
        lrf_peak = max(lrf_peak, peak)
        if peak > cgra.lrf:
            viol.append(f"LRF overflow on PE {pe}: {peak} > {cgra.lrf}")

    # ---- 4. GRF capacity --------------------------------------------------
    grf_peak = 0
    grf_slots: dict[int, int] = {}
    for oid, v in placement.items():
        if v.kind == TIN and v.mode == "grf":
            t0 = sched.time[oid]
            # Park until the last *use*, which for an inter-iteration
            # consumer is e.distance * ii cycles past its scheduled slot
            # (same per-edge accounting as the LRF path above).
            t1 = max((sched.time[e.dst] + e.distance * ii
                      for e in dfg.out_edges(oid)), default=t0)
            for s, c in _interval_slots(t0, t1, ii).items():
                grf_slots[s] = grf_slots.get(s, 0) + c
    if grf_slots:
        grf_peak = max(grf_slots.values())
        if grf_peak > max(cgra.grf, 0):
            viol.append(f"GRF overflow: {grf_peak} > {cgra.grf}")

    return ValidationReport(not viol, viol, assignment, lrf_peak, grf_peak)
