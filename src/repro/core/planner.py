"""Bandwidth-allocating sharding planner — BandMap's insight applied to the
TPU mesh (DESIGN.md §2).

The CGRA story: data with spatial reuse degree RD > M forces either
routing PEs (store-and-forward, BusMap) or a *quantitative port/bandwidth
allocation* (multicast, BandMap).  On the mesh the same dichotomy appears
per tensor per step:

- **multicast** — one all-gather/broadcast on the mesh axis whose members
  reuse the tensor (XLA's all-gather uses all links of the axis at once —
  the crossbar-multicast analogue), or replication (RD = axis, zero
  per-step traffic, paid in memory);
- **relay**    — point-to-point / ring schedules (collective-permute
  chains) or, degenerately, re-gathering a tensor some device already
  holds: the "routing PE" of the mesh, spending link bandwidth and a PE
  (device) buffer to re-broadcast.

`plan()` builds a per-step **transfer DFG** (the same `core.dfg.DFG`
class the CGRA mapper uses; every tensor class is a VIO whose consumers
are device groups), computes RD per VIO, and allocates bandwidth:
logical-axis sharding rules + a collective strategy per tensor, plus a
bytes-per-step prediction the roofline pass checks against the compiled
HLO (§Dry-run / §Perf).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.models.transformer import ModelConfig

from .dfg import DFG, OpKind

# bytes per element
BF16 = 2
F32 = 4


@dataclasses.dataclass
class Transfer:
    """One tensor class crossing device boundaries each step."""
    tensor: str
    bytes_total: int          # full (unsharded) tensor bytes
    rd: int                   # spatial reuse degree: #devices needing it
    axis: str                 # mesh axis whose members reuse it
    strategy: str             # multicast | replicate | relay | reduce
    bytes_per_step: int       # predicted link bytes per device per step
    note: str = ""


@dataclasses.dataclass
class Plan:
    arch: str
    shape: str
    mesh_axes: dict           # axis -> size
    rules: dict               # logical axis -> mesh axis (str|tuple|None)
    transfers: list
    grad_compression: bool = False

    @property
    def collective_bytes(self) -> int:
        return sum(t.bytes_per_step for t in self.transfers)

    def summary(self) -> str:
        lines = [f"plan[{self.arch} × {self.shape}] "
                 f"mesh={self.mesh_axes} rules={self.rules}"]
        for t in sorted(self.transfers, key=lambda t: -t.bytes_per_step):
            lines.append(
                f"  {t.tensor:28s} RD={t.rd:<4d} {t.strategy:10s} "
                f"axis={t.axis:6s} {t.bytes_per_step/2**20:10.1f} MiB/step"
                f"  {t.note}")
        return "\n".join(lines)


def schedule_transfer_rounds(plan: "Plan", *, seed: int = 0,
                             max_rounds: int = 64) -> list[list[str]]:
    """Decompose a plan's byte-moving transfers into bandwidth rounds.

    Transfers on the same mesh axis contend for that axis's links — the
    mesh analogue of two ops driving one bus instance — so a round is an
    independent set of the contention graph.  We reuse the CGRA binder's
    packed-bitset MIS engine: peel a maximum independent set per round
    until every transfer is placed.  Returns tensor-name rounds, densest
    first; the round count is the plan's serialization depth (1 = all
    collectives can overlap)."""
    from .bitset import BitsetGraph
    from .mis import solve_mis

    act = [t for t in plan.transfers if t.bytes_per_step > 0]
    rounds: list[list[str]] = []
    remaining = list(range(len(act)))
    for _ in range(max_rounds):
        if not remaining:
            break
        g = BitsetGraph(len(remaining))
        for a in range(len(remaining)):
            for b in range(a + 1, len(remaining)):
                if act[remaining[a]].axis == act[remaining[b]].axis:
                    g.add_edge(a, b)
        # Greedy construction already yields the maximum IS for a union
        # of cliques; a short tabu budget covers non-clique extensions
        # without burning the solver's 20k-iteration default per round.
        sol = solve_mis(g, target=len(remaining), max_iters=200,
                        seed=seed)
        picked = {remaining[i] for i in np.flatnonzero(sol)}
        rounds.append([act[i].tensor for i in
                       sorted(picked, key=lambda i: -act[i].bytes_per_step)])
        remaining = [i for i in remaining if i not in picked]
    if remaining:  # max_rounds exhausted: serialize the tail
        rounds.extend([[act[i].tensor] for i in remaining])
    return rounds


def _param_bytes(cfg: ModelConfig) -> int:
    from repro.models.model import count_params
    return count_params(cfg) * F32


def _layer_classes(cfg: ModelConfig) -> list[tuple[str, int]]:
    """(name, bytes) of per-layer weight classes (full stack totals)."""
    d, L = cfg.d_model, cfg.n_layers
    cls = []
    if cfg.family in ("dense", "moe"):
        if cfg.attn_kind == "mla":
            attn = d * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim) \
                + d * cfg.kv_lora + d * cfg.qk_rope_dim \
                + cfg.kv_lora * cfg.n_heads * (cfg.qk_nope_dim
                                               + cfg.v_head_dim) \
                + cfg.n_heads * cfg.v_head_dim * d
        else:
            attn = d * cfg.head_dim * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                + cfg.n_heads * cfg.head_dim * d
        cls.append(("attn_w", attn * L * F32))
        if cfg.family == "moe":
            cls.append(("expert_w",
                        3 * cfg.n_experts * d * cfg.moe_d_ff * L * F32))
            if cfg.n_shared_experts:
                cls.append(("shared_w",
                            3 * d * cfg.moe_d_ff * cfg.n_shared_experts
                            * L * F32))
        else:
            mult = 3 if cfg.gated_mlp else 2
            cls.append(("mlp_w", mult * d * cfg.d_ff * L * F32))
    elif cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * d
        ssm = d * (2 * d_inner + 2 * cfg.ssm_groups * cfg.d_state
                   + d_inner // cfg.ssm_head_dim) + d_inner * d
        cls.append(("ssm_w", ssm * L * F32))
        if cfg.family == "hybrid":
            attn = d * cfg.head_dim * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                + cfg.n_heads * cfg.head_dim * d + 3 * d * cfg.d_ff
            cls.append(("shared_attn_w", attn * F32))   # ONE copy
    else:  # encdec
        attn = d * cfg.head_dim * (cfg.n_heads + 2 * cfg.n_kv_heads) \
            + cfg.n_heads * cfg.head_dim * d
        mult = 3 if cfg.gated_mlp else 2
        cls.append(("attn_w",
                    attn * (cfg.n_layers * 2 + cfg.n_enc_layers) * F32))
        cls.append(("mlp_w", mult * d * cfg.d_ff
                    * (cfg.n_layers + cfg.n_enc_layers) * F32))
    cls.append(("embed_w", cfg.vocab * d * F32 *
                (1 if cfg.tie_embeddings else 2)))
    return cls


def build_transfer_dfg(cfg: ModelConfig, kind: str, seq: int, batch: int,
                       mesh_axes: dict) -> tuple[DFG, dict]:
    """Transfer DFG: one VIO per reused tensor class; consumers are device
    groups.  RD(VIO) is literally `DFG.rd` — the paper's quantity."""
    dp = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
    tp = mesh_axes.get("model", 1)
    dfg = DFG()
    meta: dict[int, dict] = {}

    def vio(name, nbytes, rd, axis):
        v = dfg.add_op(OpKind.VIN, name)
        consumers = [dfg.add_op(OpKind.COMPUTE, f"{name}.c{i}")
                     for i in range(rd)]
        for c in consumers:
            dfg.add_edge(v, c)
        meta[v] = dict(name=name, bytes=nbytes, axis=axis)
        return v

    for name, nbytes in _layer_classes(cfg):
        if kind == "train":
            # FSDP-sharded weights: every data-axis member re-reads the
            # full tensor every step -> RD = dp (highest-RD VIOs).
            vio(f"{name}.fsdp_gather", nbytes, dp, "data")
            vio(f"{name}.grad_reduce", nbytes, dp, "data")
        else:
            vio(f"{name}.serve_read", nbytes, tp, "model")

    tok_bytes = batch * seq * cfg.d_model * BF16
    if kind == "train" and tp > 1:
        vio("tp_activations", tok_bytes, tp, "model")
    if cfg.family == "moe" and kind != "decode":
        vio("moe_dispatch", tok_bytes * cfg.top_k, min(tp, cfg.n_experts),
            "model")
    if kind == "decode":
        step_bytes = batch * cfg.d_model * BF16
        vio("tp_partial_out", step_bytes, tp, "model")
        if cfg.family == "encdec":
            vio("cross_kv", cfg.enc_seq * batch
                * cfg.n_heads * cfg.head_dim * 2 * BF16, tp, "model")
    return dfg, meta


def plan(cfg: ModelConfig, kind: str, seq: int, batch: int, mesh,
         *, optimized: bool = False, arch: str = "", shape: str = "") -> Plan:
    """Allocate bandwidth for every transfer-DFG VIO and emit sharding
    rules.  ``optimized=False`` is the paper-faithful baseline (BandMap's
    straightforward policy); ``optimized=True`` adds the beyond-paper
    knobs recorded in EXPERIMENTS §Perf."""
    mesh_axes = dict(mesh.shape)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    dp = math.prod(mesh_axes[a] for a in dp_axes)
    tp = mesh_axes.get("model", 1)

    dfg, meta = build_transfer_dfg(cfg, kind, seq, batch, mesh_axes)

    # ---------------- bandwidth allocation (the BandMap policy) ----------
    # M = "PEs per bus" analogue: members of one mesh axis reachable by a
    # single multicast drive.  RD > M would need multiple "ports" — on the
    # mesh, hierarchical collectives (per-axis stages).
    transfers: list[Transfer] = []
    for v in dfg.v_i:
        m = meta[v]
        rd = dfg.rd(v)
        axis_size = mesh_axes.get(m["axis"], 1)
        axis_links = max(axis_size - 1, 1)
        name, nbytes = m["name"], m["bytes"]
        if name.endswith(".grad_reduce"):
            # reduce: ring all-reduce 2·(n-1)/n per link; optionally int8
            per = int(2 * nbytes * axis_links / max(axis_size, 1))
            if optimized and "pod" in mesh_axes:
                per = per // 4 + nbytes // 4   # int8 across-pod stage
            transfers.append(Transfer(name, nbytes, rd, m["axis"],
                                      "reduce", per,
                                      "ring all-reduce of grads"))
        elif name.endswith(".fsdp_gather"):
            per = int(nbytes * axis_links / max(axis_size, 1))
            transfers.append(Transfer(name, nbytes, rd, m["axis"],
                                      "multicast", per,
                                      "FSDP all-gather (fwd+bwd reuse)"))
        elif name.endswith(".serve_read"):
            # weights TP-sharded and resident: RD satisfied by placement
            transfers.append(Transfer(name, nbytes, rd, m["axis"],
                                      "replicate", 0,
                                      "resident shard, no per-step bytes"))
        elif name == "moe_dispatch":
            per = int(nbytes / max(axis_size, 1))
            transfers.append(Transfer(name, nbytes, rd, m["axis"],
                                      "relay", per, "token all-to-all"))
        else:
            per = int(nbytes * axis_links / max(axis_size, 1))
            transfers.append(Transfer(name, nbytes, rd, m["axis"],
                                      "multicast", per,
                                      "TP partial-sum all-reduce"))

    # ---------------- sharding rules ------------------------------------
    rules: dict = {
        "batch": dp_axes if batch % dp == 0 else None,
        "seq": None,
        "embed": None,
        "vocab": "model",
        "heads": "model", "kv_heads": "model", "head_dim": None,
        "heads_merged": "model",
        "mlp": "model", "expert": None,
        "kv_lora": None,
        "ssm_inner": "model", "ssm_heads": "model", "ssm_state": None,
        "conv_w": None, "layer": None,
    }
    if kind == "train":
        rules["embed"] = "data"        # FSDP on the in-pod data axis
    if batch % dp != 0:
        # long_500k (batch 1): shard the sequence/cache over data —
        # flash-decoding style; the softmax reduce is the multicast.
        rules["seq"] = "data"
        rules["batch"] = None
    if optimized and kind == "decode" and rules["seq"] is None:
        # Flash-decoding: shard the KV-cache sequence over the model axis
        # (the per-step cache re-read is the dominant memory term; kv
        # heads that don't divide 16 would otherwise replicate the whole
        # cache — qwen1.5's 20 heads, mixtral's 8).  Rules drop duplicate
        # axes, so kv_heads→model yields to seq→model automatically.
        rules["seq"] = "model"
    if optimized and kind == "decode":
        # Secondary head_dim sharding: archs whose head count doesn't
        # divide the model axis (qwen1.5: 20) fall back to replicated
        # attention weights — shard the head_dim instead (128 % 16 == 0
        # everywhere).  The duplicate-axis drop makes this a no-op when
        # heads already took the model axis.
        rules["head_dim"] = "model"
    if optimized and kind == "train":
        rules["seq"] = "model"         # Megatron-SP residuals
    return Plan(arch=arch or cfg.name, shape=shape or kind,
                mesh_axes=mesh_axes, rules=rules, transfers=transfers,
                grad_compression=optimized and "pod" in mesh_axes)
