"""uint64-packed bitset adjacency — the conflict-graph storage engine.

The binder solves MIS on graphs whose size grows with |ops| x |PEA|
(an 8x8 CGRA already yields |V_C| > 1000), so the dense ``bool [n, n]``
matrix of the original implementation is both the memory and the traffic
bottleneck: every conflict-membership probe reads O(n) bytes.  Here a
vertex's neighbourhood is one row of ``ceil(n/64)`` uint64 words (bit j of
word j//64 = edge to vertex j, little-endian bit order), so membership
tests, degree counts and S-conflict counts become O(n/64) word ops:

- AND + popcount (``np.bitwise_count``) gives |N(v) ∩ S| per row, for the
  whole graph in one vectorised ``[n, words]`` expression;
- ``np.unpackbits`` turns a row back into a 0/1 vector for incremental
  conflict-count updates (O(n/8) memory traffic instead of an O(n) bool
  row, and one numpy call instead of a mask cascade);
- group conflicts (per-op cliques, resource-occupancy cliques) are row
  ORs of one precomputed group mask — no pairwise python loops.

All layouts are little-endian on the bit level (``bitorder="little"``), so
packing bool vectors via ``np.packbits(...).view(np.uint64)`` and the
arithmetic path (``1 << (i & 63)`` into word ``i >> 6``) agree.
"""

from __future__ import annotations

import sys

import numpy as np

WORD = 64
_ONE = np.uint64(1)
_LITTLE = sys.byteorder == "little"


def n_words(n: int) -> int:
    return (n + WORD - 1) // WORD


def make_set(n: int) -> np.ndarray:
    """Empty bitset over a universe of ``n`` elements."""
    return np.zeros(n_words(n), dtype=np.uint64)


def set_bit(words: np.ndarray, i: int) -> None:
    words[i >> 6] |= _ONE << np.uint64(i & 63)


def clear_bit(words: np.ndarray, i: int) -> None:
    words[i >> 6] &= ~(_ONE << np.uint64(i & 63))


def test_bit(words: np.ndarray, i: int) -> bool:
    return bool((words[i >> 6] >> np.uint64(i & 63)) & _ONE)


def pack_bool(mask: np.ndarray) -> np.ndarray:
    """Pack a bool/0-1 vector into uint64 words (little-endian bits)."""
    mask = np.ascontiguousarray(mask, dtype=bool)
    if _LITTLE:
        packed = np.packbits(mask, bitorder="little")
        pad = (-packed.size) % 8
        if pad:
            packed = np.concatenate([packed, np.zeros(pad, np.uint8)])
        return packed.view(np.uint64).copy()
    words = make_set(mask.size)
    idx = np.flatnonzero(mask)
    np.bitwise_or.at(words, idx >> 6,
                     _ONE << (idx & 63).astype(np.uint64))
    return words


def pack_bool_rows(mask: np.ndarray) -> np.ndarray:
    """Pack a bool matrix ``[m, n]`` into uint64 rows ``[m, words]``."""
    mask = np.ascontiguousarray(mask, dtype=bool)
    if mask.shape[1] == 0:
        return np.zeros((mask.shape[0], 0), dtype=np.uint64)
    if _LITTLE:
        packed = np.packbits(mask, axis=1, bitorder="little")
        pad = (-packed.shape[1]) % 8
        if pad:
            packed = np.pad(packed, ((0, 0), (0, pad)))
        return np.ascontiguousarray(packed).view(np.uint64)
    return np.stack([pack_bool(row) for row in mask])  # pragma: no cover


def pack_indices(idx, n: int) -> np.ndarray:
    """Bitset over ``n`` elements with the given indices set."""
    words = make_set(n)
    idx = np.asarray(idx, dtype=np.int64)
    np.bitwise_or.at(words, idx >> 6, _ONE << (idx & 63).astype(np.uint64))
    return words


def unpack(words: np.ndarray, n: int) -> np.ndarray:
    """Unpack a bitset (or a ``[..., words]`` batch) to 0/1 uint8 of
    length ``n`` along the last axis."""
    u8 = words.reshape(-1, words.shape[-1]).view(np.uint8)
    if not _LITTLE:  # pragma: no cover - big-endian fallback
        u8 = u8.reshape(-1, words.shape[-1], 8)[..., ::-1].reshape(
            u8.shape[0], -1)
    out = np.unpackbits(u8, axis=-1, bitorder="little", count=n)
    return out.reshape(words.shape[:-1] + (n,))


def popcount(words: np.ndarray) -> int:
    return int(np.bitwise_count(words).sum())


def indices(words: np.ndarray, n: int) -> np.ndarray:
    """Sorted element indices present in the bitset."""
    return np.flatnonzero(unpack(words, n))


class BitsetGraph:
    """Undirected graph as packed adjacency rows ``uint64 [n, words]``."""

    __slots__ = ("n", "words", "rows")

    def __init__(self, n: int):
        self.n = n
        self.words = n_words(n)
        self.rows = np.zeros((n, self.words), dtype=np.uint64)

    # ------------------------------------------------------------ build
    def add_edge(self, i: int, j: int) -> None:
        if i == j:
            return
        self.rows[i, j >> 6] |= _ONE << np.uint64(j & 63)
        self.rows[j, i >> 6] |= _ONE << np.uint64(i & 63)

    def add_edges(self, i_arr, j_arr) -> None:
        """Vectorised symmetric edge insertion for index arrays."""
        i = np.asarray(i_arr, dtype=np.int64)
        j = np.asarray(j_arr, dtype=np.int64)
        keep = i != j
        i, j = i[keep], j[keep]
        np.bitwise_or.at(self.rows, (i, j >> 6),
                         _ONE << (j & 63).astype(np.uint64))
        np.bitwise_or.at(self.rows, (j, i >> 6),
                         _ONE << (i & 63).astype(np.uint64))

    def add_clique(self, ids) -> None:
        """Pairwise-connect every pair of ``ids`` (diagonal bits are set
        too; call :meth:`clear_diagonal` once after building)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size < 2:
            return
        mask = pack_indices(ids, self.n)
        self.rows[ids] |= mask

    def clear_diagonal(self) -> None:
        idx = np.arange(self.n, dtype=np.int64)
        self.rows[idx, idx >> 6] &= ~(_ONE << (idx & 63).astype(np.uint64))

    # ----------------------------------------------------------- queries
    def has_edge(self, i: int, j: int) -> bool:
        return test_bit(self.rows[i], j)

    def degrees(self) -> np.ndarray:
        return np.bitwise_count(self.rows).sum(axis=1, dtype=np.int64)

    @property
    def n_edges(self) -> int:
        return popcount(self.rows) // 2

    def row_u8(self, v: int) -> np.ndarray:
        """Neighbourhood of ``v`` as a 0/1 uint8 vector."""
        return unpack(self.rows[v], self.n)

    def rows_u8(self, vs) -> np.ndarray:
        """Batched :meth:`row_u8` — one unpackbits call for many rows."""
        return unpack(self.rows[np.asarray(vs, dtype=np.int64)], self.n)

    def neighbors(self, v: int) -> np.ndarray:
        return np.flatnonzero(self.row_u8(v))

    def conflict_counts(self, s_words: np.ndarray) -> np.ndarray:
        """|N(v) ∩ S| for every v, one vectorised AND+popcount."""
        return np.bitwise_count(self.rows & s_words).sum(
            axis=1, dtype=np.int64)

    def union_rows(self, vs) -> np.ndarray:
        """Packed neighbourhood union ∪_{v ∈ vs} N(v) — one OR-reduce
        over the gathered rows, no per-vertex python loop."""
        vs = np.asarray(vs, dtype=np.int64)
        if vs.size == 0:
            return make_set(self.n)
        return np.bitwise_or.reduce(self.rows[vs], axis=0)

    def cluster_members(self, vs, s_words: np.ndarray) -> np.ndarray:
        """Conflict cluster of the candidate set ``vs`` against the
        selection ``s_words``: indices of every selected vertex adjacent
        to at least one of ``vs``.  This is the group-move neighbourhood's
        extraction primitive — for an unplaced op it names exactly the
        placements that pin it out, in one AND over the packed union."""
        return indices(self.union_rows(vs) & s_words, self.n)

    def any_conflict(self, s_words: np.ndarray) -> bool:
        """Does any member of S have a neighbour in S?"""
        members = indices(s_words, self.n)
        if members.size == 0:
            return False
        return bool((self.rows[members] & s_words).any())

    def rows_u32(self, n_pad: int | None = None) -> np.ndarray:
        """Adjacency rows re-viewed as uint32 words ``[n, n_pad//32]`` —
        the device-shaped export the Pallas engines consume
        (`kernels.sbts_step`, `core.mis_device`): `jax.numpy` has no
        uint64, so packed sets live as uint32 on device.  Bit j of word
        j//32 = edge to vertex j (same little-endian bit order as
        ``rows``; on big-endian hosts the uint64 view is byteswapped
        first).  ``n_pad`` pads both axes with zero rows/words up to the
        given vertex count (a multiple of 32) so kernels can tile
        without remainder handling — padded vertices have no edges."""
        n_pad = self.n if n_pad is None else n_pad
        if n_pad % 32 or n_pad < self.n:
            raise ValueError(f"n_pad={n_pad} must be a multiple of 32 "
                             f">= n={self.n}")
        out = np.zeros((n_pad, n_pad // 32), dtype=np.uint32)
        if _LITTLE:
            w32 = self.rows.view(np.uint32)
            out[:self.n, :min(w32.shape[1], out.shape[1])] = \
                w32[:, :out.shape[1]]
        else:  # pragma: no cover - big-endian fallback
            bits = np.zeros((self.n, n_pad), dtype=np.uint32)
            bits[:, :self.n] = unpack(self.rows, self.n)
            out[:self.n] = (
                bits.reshape(self.n, -1, 32)
                << np.arange(32, dtype=np.uint32)).sum(
                    axis=-1, dtype=np.uint32)
        return out

    # -------------------------------------------------------- conversion
    def to_dense(self) -> np.ndarray:
        return unpack(self.rows, self.n).astype(bool)

    @classmethod
    def from_dense(cls, adj: np.ndarray) -> "BitsetGraph":
        adj = np.asarray(adj)
        g = cls(adj.shape[0])
        if g.n == 0:
            return g
        g.rows = pack_bool_rows(adj.astype(bool))
        g.clear_diagonal()
        return g


def as_bitset_graph(adj) -> BitsetGraph:
    """Accept either a dense bool adjacency matrix or a BitsetGraph."""
    if isinstance(adj, BitsetGraph):
        return adj
    return BitsetGraph.from_dense(np.asarray(adj))
