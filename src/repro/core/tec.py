"""Time-Extended CGRA (TEC), T_II(V_T, E_T): the CGRA replicated for modulo
slots 0..II-1.  Binding places ops on TEC nodes; an edge of the TEC is a
single-hop routing path (same-PE across time via LRF, same-row via a row
bus, same-column via a column bus).

Bus inventory per DESIGN.md §3 (reconstructed from the quadruple notation
bus_{i,x} / bus_{j,y} in TABLE I — x/y index multiple buses per row/column):

- row r: bus (ROW, r, 0) = IBUS_r, fed by IPORT_r (or re-driven by a PE:
  "bus routing", which conflicts with port use — edge rule 2);
  bus (ROW, r, 1) = row routing bus, PE-driven.
- col c: bus (COL, c, 0) = OBUS_c, drained by OPORT_c, PE-driven;
  bus (COL, c, 1) = column routing bus, PE-driven.

One driver per bus per cycle.  A datum driven on a row(col) bus at slot m is
readable by every PE of that row(col) at m.
"""

from __future__ import annotations

import dataclasses

from .cgra import CGRAConfig

ROW = "row"
COL = "col"


@dataclasses.dataclass(frozen=True)
class TECNode:
    r: int
    c: int
    m: int  # modulo slot


class TEC:
    def __init__(self, cgra: CGRAConfig, ii: int):
        self.cgra = cgra
        self.ii = ii

    def nodes(self):
        for m in range(self.ii):
            for r in range(self.cgra.rows):
                for c in range(self.cgra.cols):
                    yield TECNode(r, c, m)

    def buses(self, scope: str, idx: int) -> list[tuple[str, int, int]]:
        """All physical buses of a row/column scope."""
        return [(scope, idx, k) for k in range(self.cgra.buses_per_scope)]

    @staticmethod
    def reachable(src: tuple[int, int], dst: tuple[int, int]) -> bool:
        """Single-hop reachability between PEs (same PE / row / column)."""
        return src == dst or src[0] == dst[0] or src[1] == dst[1]
