"""Synthetic workload generator: parameterized DFG families beyond CnKm.

Every kernel the repo shipped so far (CnKm, §IV-A) is acyclic, so the
loop-carried (distance > 0) RecMII path in `dfg.py` / `schedule.py` had no
workload exercising it, and nothing stressed the engine at 16x16-scale
candidate counts (|V_C| ~ 10^4).  This module generates seeded DFG
families that open both axes:

- **loop**    — random loop kernels with loop-carried accumulator cycles
  (distance >= 1): RecMII > 1 for tight recurrences, plus optional
  inter-iteration VIO consumers (the GRF park-window case).
- **stencil** — sliding-window kernels: ``points`` outputs, each a chain
  of ``taps`` MACs over a shared shifted input window, giving the
  non-uniform spatial-reuse profile (RD varies per VIO) the bandwidth
  allocator has to split unevenly.
- **reduction** — ``width``-wide ``arity``-ary reduction trees draining
  to one output: deep dependence chains, low reuse.
- **cnkm**    — the paper's family, included so sweeps can mix it in.

All builders are deterministic in ``seed``.  :func:`sweep_specs` yields
size sweeps up to 16x16-scale op counts; :func:`generate` builds a DFG
from a family name + params (the registry the co-mapper and benches
drive)."""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from .dfg import DFG, OpKind
from .kernels_cnkm import make_cnkm


def _assert_invariants(d: DFG) -> DFG:
    """Checked form of the generator-family invariants every builder in
    this module upholds — <= 1 VIO predecessor per op, one distinct
    producer per VOO.  The rule definitions (and the why) live in one
    place, `analysis.dfglint.generator_invariant_findings`; this
    assertion and the lint pass share them verbatim."""
    from repro.analysis.dfglint import generator_invariant_findings
    bad = generator_invariant_findings(d)
    assert not bad, "generator invariant violated: " + \
        "; ".join(f.summary() for f in bad)
    return d


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A named, reproducible workload: family + params."""
    name: str
    family: str
    params: dict

    def build(self) -> DFG:
        return generate(self.family, **self.params)


def make_loop_kernel(n_chains: int = 4, chain_len: int = 4,
                     n_inputs: int = 3, n_outputs: int = 2, *,
                     n_carries: int = 1, max_distance: int = 2,
                     cross_links: int = 1, vin_carry_distance: int = 0,
                     seed: int = 0) -> DFG:
    """Random loop kernel in the fabric-realizable chain shape:
    generalized CnKm with loop-carried accumulators.

    ``n_chains`` dependent chains of ``chain_len`` compute ops (a chain
    binds naturally to a column of the PEA, its levels to rows).  Each
    level draws one VIO shared by every chain at that level — the
    CnKm-style spatial reuse the bandwidth allocator splits — with the
    level→VIO assignment shuffled by ``seed``.  ``n_carries`` chains
    close a loop-carried back edge (distance 1..``max_distance``) from
    their last op to their first: RecMII = ceil(chain latency /
    distance), the recurrence shape no shipped CnKm kernel produces.
    ``cross_links`` adds stencil-style links between adjacent chains at
    a shared level.  With ``vin_carry_distance`` > 0 one VIO edge is
    rewired to that iteration distance — the inter-iteration-consumer
    case whose GRF/LRF park window regressed in PR 2.

    Why chains and not a random DAG: bus delivery pins all consumers of
    a VIO (clone) to one row, and a plain producer reaches only its
    row/column — a uniformly random DAG funnels whole kernels into a
    single column where same-(row, slot) ops collide, which is
    *provably* unbindable (the certificate stages exhaust it), not just
    hard.  Chain-structured kernels with per-level reuse and sparse
    cross-links are both realistic (MAC lattices, stencils) and
    fabric-realizable.
    """
    assert n_carries <= n_chains
    rng = np.random.default_rng(seed)
    d = DFG()
    # Only chain_len levels exist to consume inputs, so more VIOs than
    # levels would leave dangling ports — clamp instead.
    n_inputs = min(n_inputs, chain_len)
    vins = [d.add_op(OpKind.VIN, f"in{i}") for i in range(n_inputs)]
    # Level -> VIO assignment: every input covered, remainder random.
    levels = list(range(n_inputs)) + [
        int(rng.integers(0, n_inputs))
        for _ in range(chain_len - n_inputs)]
    rng.shuffle(levels)

    chains = [[d.add_op(OpKind.COMPUTE, f"c{j}_{l}")
               for l in range(chain_len)] for j in range(n_chains)]
    # Level-major VIO edges keep each VIO's consumer list in chain
    # order, so a multi-port split clones contiguous chain groups.
    for l in range(chain_len):
        if l < len(levels):
            for j in range(n_chains):
                d.add_edge(vins[levels[l]], chains[j][l])
    for j in range(n_chains):
        for a, b in zip(chains[j], chains[j][1:]):
            d.add_edge(a, b)

    # Loop-carried accumulators on the first n_carries chains.
    for j in range(n_carries):
        dist = int(rng.integers(1, max_distance + 1))
        d.add_edge(chains[j][-1], chains[j][0], distance=dist)

    # Stencil-style cross links: adjacent chains at one level.
    for _ in range(cross_links):
        if n_chains < 2:
            break
        j = int(rng.integers(0, n_chains - 1))
        l = int(rng.integers(0, chain_len - 1))
        d.add_edge(chains[j][l], chains[j + 1][l + 1])

    if vin_carry_distance > 0:
        # Inter-iteration VIO consumer: rewire one VIO edge to the
        # given distance (keeping the one-VIO-pred-per-op invariant).
        vin = vins[levels[-1]] if levels else vins[-1]
        late = chains[-1][len(levels) - 1 if levels else -1]
        d.remove_edge(vin, late)
        d.add_edge(vin, late, distance=vin_carry_distance)

    # One VOO per chain end (the shared-voo-producer invariant —
    # rationale in `analysis.dfglint.generator_invariant_findings`).
    for j in range(min(n_outputs, n_chains)):
        vo = d.add_op(OpKind.VOUT, f"out{j}")
        d.add_edge(chains[j][-1], vo)
    return _assert_invariants(d)


def make_stencil(points: int = 4, taps: int = 3, *, seed: int = 0) -> DFG:
    """1-D ``taps``-point stencil over ``points`` outputs.

    out[j] = sum_k w_k * in[j + k]: a sliding window of shared VIOs, so
    interior inputs are reused by up to ``taps`` MAC chains while edge
    inputs are reused less — the non-uniform RD profile.  ``seed`` is
    accepted for registry uniformity (the shape is deterministic)."""
    del seed
    d = DFG()
    n_inputs = points + taps - 1
    vins = [d.add_op(OpKind.VIN, f"in{i}") for i in range(n_inputs)]
    vouts = []
    for j in range(points):
        prev = None
        for k in range(taps):
            mac = d.add_op(OpKind.COMPUTE, f"mac{j}_{k}")
            d.add_edge(vins[j + k], mac)
            if prev is not None:
                d.add_edge(prev, mac)
            prev = mac
        vo = d.add_op(OpKind.VOUT, f"out{j}")
        d.add_edge(prev, vo)
        vouts.append(vo)
    return _assert_invariants(d)


def make_reduction(width: int = 8, arity: int = 2, *,
                   seed: int = 0) -> DFG:
    """Map-then-reduce: ``width`` inputs, one elementwise leaf op each,
    then an ``arity``-ary tree to one output.

    The leaf layer is what makes the shape bindable on the row/column
    fabric: a leaf sits on its own VIO's row, and sibling leaves meet
    their reducer through a shared column — a *raw* tree whose reducers
    consume two VIOs directly would need both ports on one row in one
    slot, which the port fabric cannot provide."""
    del seed
    assert arity >= 2
    d = DFG()
    frontier = []
    for i in range(width):
        vin = d.add_op(OpKind.VIN, f"in{i}")
        leaf = d.add_op(OpKind.COMPUTE, f"leaf{i}")
        d.add_edge(vin, leaf)
        frontier.append(leaf)
    level = 0
    while len(frontier) > 1:
        nxt = []
        for g in range(0, len(frontier), arity):
            group = frontier[g:g + arity]
            if len(group) == 1:
                nxt.extend(group)
                continue
            red = d.add_op(OpKind.COMPUTE, f"r{level}_{g // arity}")
            for s in group:
                d.add_edge(s, red)
            nxt.append(red)
        frontier = nxt
        level += 1
    vo = d.add_op(OpKind.VOUT, "out0")
    d.add_edge(frontier[0], vo)
    return _assert_invariants(d)


def make_tightly_coupled(n_vios: int = 8, fanout: int = 8,
                         cross_links: int = 2, n_outputs: int = 2, *,
                         link_run: int = 4, seed: int = 0) -> DFG:
    """Tightly-coupled kernel: high-fan-out VIOs whose consumer groups
    are chained *across* groups — the family that stalls the (1,1)-swap
    portfolio just below full coverage (the group-move regression
    fixture).

    ``n_vios`` VIOs each feed ``fanout`` consumers (one shared datum per
    group: bus delivery pins the whole group to the VIO's row).  With
    ``n_vios × fanout`` equal to the PE count, the consumer slot is
    exactly packed, so a cold-started SBTS packs computes first — each
    group's consumers scattered over many rows — and then no VIO has a
    row candidate conflicting with fewer than ~``fanout`` placements:
    the multi-vertex local minimum the ROADMAP describes ("a VIO whose
    placed consumers span rows"), escapable by a group move but not by
    (1,1) swaps.

    ``cross_links`` of the ``fanout`` lanes additionally chain consumer
    j of group i to consumer j of group i+1 over a run of ``link_run``
    consecutive groups, forcing those lanes to share a column across
    groups (cross-row consumer pressure).  Runs are kept short so that
    any full-coverage placement stays within the per-column bus budget
    at II=2 — ``link_run - 1`` chained transfers plus one VOO export fit
    ``2 × II`` (bus, cycle) cells even when no two linked groups land on
    adjacent rows (adjacent rows ride the free NSEW neighbour links).

    ``seed`` shuffles which lanes carry the cross links and where each
    run starts; the shape is otherwise deterministic.  The family
    invariants (see `_assert_invariants`) are checked on return.
    """
    assert cross_links <= fanout
    rng = np.random.default_rng(seed)
    d = DFG()
    vins = [d.add_op(OpKind.VIN, f"in{i}") for i in range(n_vios)]
    groups = [[d.add_op(OpKind.COMPUTE, f"g{i}_{j}")
               for j in range(fanout)] for i in range(n_vios)]
    for i in range(n_vios):
        for j in range(fanout):
            d.add_edge(vins[i], groups[i][j])
    lanes = list(range(fanout))
    rng.shuffle(lanes)
    run = min(link_run, n_vios)
    for j in lanes[:cross_links]:
        i0 = int(rng.integers(0, n_vios - run + 1))
        for i in range(i0, i0 + run - 1):
            d.add_edge(groups[i][j], groups[i + 1][j])
    for j in range(min(n_outputs, fanout)):
        vo = d.add_op(OpKind.VOUT, f"out{j}")
        d.add_edge(groups[-1][j], vo)
    return _assert_invariants(d)


FAMILIES: dict[str, Callable[..., DFG]] = {
    "loop": make_loop_kernel,
    "stencil": make_stencil,
    "reduction": make_reduction,
    "cnkm": make_cnkm,
    "tight": make_tightly_coupled,
}


def generate(family: str, **params) -> DFG:
    """Build a DFG from a family name + params (registry entry point)."""
    if family not in FAMILIES:
        raise KeyError(f"unknown workload family {family!r}; "
                       f"have {sorted(FAMILIES)}")
    return FAMILIES[family](**params)


def sweep_specs(scale: str = "4x4", *, seed: int = 0) -> list[WorkloadSpec]:
    """Seeded size sweep per PEA scale.

    ``scale`` picks the op-count regime: "4x4" stays at paper-kernel
    sizes; "8x8" roughly quadruples them; "16x16" pushes the compute-op
    count to the |V_C| ~ 10^4 candidate regime (ops x 256 PEs) the
    ROADMAP names as untried."""
    mult = {"4x4": 1, "8x8": 2, "16x16": 4}[scale]
    base = 10 * mult                 # 10 / 20 / 40-class op counts
    specs = [
        WorkloadSpec(f"loop{base}", "loop",
                     dict(n_chains=2 * mult, chain_len=5,
                          n_inputs=min(2 + mult, 8), n_outputs=2,
                          n_carries=mult, seed=seed)),
        WorkloadSpec(f"stencil{4 * mult}t3", "stencil",
                     dict(points=4 * mult, taps=3)),
        WorkloadSpec(f"reduce{8 * mult}", "reduction",
                     dict(width=8 * mult, arity=2)),
        WorkloadSpec("c2k6", "cnkm", dict(n=2, m=6)),
    ]
    return specs


def scale_16x16_loop(*, n_chains: int = 8, chain_len: int = 5,
                     seed: int = 0) -> DFG:
    """The |V_C| ~ 10^4 case: 40 compute ops on a 16x16 PEA give
    40 x 256 quad candidates (> 10^4 vertices), past the portfolio's
    default 32 MiB row-cache bound — the workload the per-move-unpack
    fallback is verified against."""
    return make_loop_kernel(
        n_chains=n_chains, chain_len=chain_len, n_inputs=5, n_outputs=4,
        n_carries=2, max_distance=2, cross_links=2, seed=seed)


def op_weight(d: DFG) -> int:
    """Region-area demand proxy used by the co-mapper's partitioner."""
    return max(len(d.v_r), 1)


# ----------------------------------------------------------- serving trace
def permute_dfg(d: DFG, *, seed: int = 0) -> DFG:
    """Random vertex relabeling of ``d``: the same mapping problem under
    a shuffled op-id assignment (and shuffled op/edge iteration order).

    This is what a client resubmitting a structurally-identical kernel
    looks like to the serving layer — the canonicalizer (`serve.canon`)
    must hash both labelings identically, and a cached placement must
    replay onto the permuted ids."""
    rng = np.random.default_rng(seed)
    ids = sorted(d.ops)
    shuffled = [ids[i] for i in rng.permutation(len(ids))]
    mapping = dict(zip(ids, shuffled))
    out = DFG()
    for oid in [ids[i] for i in rng.permutation(len(ids))]:
        op = d.ops[oid]
        nid = mapping[oid]
        out.ops[nid] = dataclasses.replace(
            op, op_id=nid,
            clone_of=mapping[op.clone_of] if op.clone_of >= 0 else -1)
    edges = [dataclasses.replace(e, src=mapping[e.src],
                                 dst=mapping[e.dst]) for e in d.edges]
    out.edges = [edges[i] for i in rng.permutation(len(edges))]
    out._next_id = max(out.ops, default=-1) + 1
    return out


def serve_catalog(scale: str = "8x8", *, seed: int = 0
                  ) -> list[WorkloadSpec]:
    """The distinct-kernel population a request trace draws from.

    Sized so each kernel maps in tens of milliseconds at its scale's
    fabric (the regime where a cache hit — canonicalize + relabel +
    validator replay, ~1 ms — is decisively cheaper than a fresh map),
    with enough variety that a Zipf tail still forces real misses."""
    mult = {"4x4": 1, "8x8": 2, "16x16": 4}[scale]
    specs = [
        WorkloadSpec("c2k4", "cnkm", dict(n=2, m=4)),
        WorkloadSpec("c2k6", "cnkm", dict(n=2, m=6)),
        WorkloadSpec("c3k6", "cnkm", dict(n=3, m=6)),
        WorkloadSpec("c4k4", "cnkm", dict(n=4, m=4)),
        WorkloadSpec("c4k8", "cnkm", dict(n=4, m=8)),
        WorkloadSpec("c5k5", "cnkm", dict(n=5, m=5)),
        WorkloadSpec("stencil4", "stencil", dict(points=4, taps=3)),
        WorkloadSpec(f"stencil{3 * mult}",
                     "stencil", dict(points=3 * mult, taps=3)),
        WorkloadSpec(f"reduce{8 * mult}",
                     "reduction", dict(width=8 * mult, arity=2)),
        WorkloadSpec("reduce6a3", "reduction", dict(width=6, arity=3)),
    ]
    for k in range(3):
        specs.append(WorkloadSpec(
            f"loop{mult}x{k}", "loop",
            dict(n_chains=2 * mult, chain_len=4,
                 n_inputs=min(2 + mult, 4), n_outputs=2,
                 n_carries=min(k, 2 * mult), max_distance=2,
                 seed=seed + k)))
    return specs


@dataclasses.dataclass
class TraceRequest:
    """One entry of a serving request trace."""
    name: str            # catalog spec the kernel was drawn from
    dfg: DFG             # freshly built (and usually permuted) instance
    deadline: float      # admission order hint (arrival index here)
    tenant: str | None = None


def make_request_trace(n_requests: int = 200, *, scale: str = "8x8",
                       zipf_s: float = 1.1, permute: bool = True,
                       seed: int = 0,
                       catalog: list[WorkloadSpec] | None = None
                       ) -> list[TraceRequest]:
    """Zipf-popularity request trace over the serving catalog.

    Kernel ``k`` (0-based catalog rank) is drawn with probability
    proportional to ``1 / (k+1)**zipf_s`` — the classic popularity skew
    under which a mapping cache earns its keep: a few hot kernels
    dominate the trace while the tail keeps producing compulsory
    misses.  With ``permute`` each instance carries a fresh random
    vertex relabeling, so hits are only reachable through canonical
    (isomorphism-invariant) hashing, never through accidental id
    equality.  Deterministic in ``seed``."""
    specs = catalog if catalog is not None else serve_catalog(scale)
    rng = np.random.default_rng(seed)
    p = np.arange(1, len(specs) + 1, dtype=float) ** -zipf_s
    p /= p.sum()
    draws = rng.choice(len(specs), size=n_requests, p=p)
    trace = []
    for t, k in enumerate(draws):
        d = specs[k].build()
        if permute:
            d = permute_dfg(d, seed=int(rng.integers(1 << 31)))
        trace.append(TraceRequest(specs[k].name, d, deadline=float(t)))
    return trace


# The canonical 16x16 co-mapping scenario: two loop kernels with
# loop-carried accumulators (RecMII 4 and 3) plus a 6-point stencil.
# Single source of truth for benchmarks/bench_mis.py (comap section),
# tests/test_comap.py (scale smoke) and examples/comap_demo.py — tune
# it here and all three stay in lockstep.
COMAP_16X16_SPECS: list[WorkloadSpec] = [
    WorkloadSpec("loopA", "loop",
                 dict(n_chains=4, chain_len=4, n_inputs=3, n_outputs=2,
                      n_carries=2, max_distance=2, seed=0)),
    WorkloadSpec("loopB", "loop",
                 dict(n_chains=5, chain_len=3, n_inputs=3, n_outputs=2,
                      n_carries=1, max_distance=1, seed=1)),
    WorkloadSpec("stencil6", "stencil", dict(points=6, taps=3)),
]
