"""CGRA architecture model (Fig. 1 of the paper).

Reconstructed resource model (documented in DESIGN.md §3):

- 2-D PEA with ``rows`` x ``cols`` PEs.  Following the paper's notation the
  number of PEs attached to a common IBUS is M = ``cols`` (a row shares one
  input bus) and tuples use ports n = 1..N with N = ``rows``.
- Each row r has an input bus IBUS_r fed by the hardwired input port
  IPORT_r; the memory-side crossbar can *multicast* one datum to several
  IPORTs in the same cycle — that is how a VIO bound to Q ports reaches
  Q x M PEs without routing PEs (Fig. 2(e)).
- Each column c has an output bus OBUS_c drained by OPORT_c.  A PE (r, c)
  hears IBUS_r and OBUS_c, and can drive OBUS_c (sending results out or
  PE->PE within the column) or re-drive IBUS_r (**bus routing**, the BusMap
  mechanism: a routing PE re-broadcasts a cached datum on a bus).  One driver
  per bus per cycle.
- Optional GRF: a global register file readable/writable by all PEs in
  parallel; a datum parked in the GRF is readable by every PE the next cycle
  (capacity-limited), which removes residual routing PEs (paper §IV).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CGRAConfig:
    rows: int = 4               # N: number of row buses / input ports
    cols: int = 4               # M: PEs per IBUS
    lrf: int = 8                # local register file capacity per PE
    grf: int = 0                # global register file capacity (0 = absent)
    # Physical buses per row/column scope (DESIGN.md §3: bus 0 is the
    # hardwired IBUS_r / OBUS_c, bus 1 the PE-driven routing bus).  The
    # single source of truth for bus capacity — tec.py::buses, the
    # validator's assignment search and the conflict graph's bus-pressure
    # edges all read it from here.
    buses_per_scope: int = 2

    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    @property
    def n_iports(self) -> int:
        return self.rows

    @property
    def n_oports(self) -> int:
        return self.cols

    @property
    def pes_per_ibus(self) -> int:
        """M in the paper's bandwidth-allocation policy."""
        return self.cols

    def pe_coords(self):
        for r in range(self.rows):
            for c in range(self.cols):
                yield (r, c)

    def view(self, rows: int, cols: int, *,
             grf: int | None = None) -> "CGRAConfig":
        """Region view: a ``rows`` x ``cols`` sub-array sharing this
        config's per-PE parameters (lrf, buses_per_scope).

        Used by the co-mapping subsystem (`repro.comap`): each rectangular
        region of the PEA is mapped as if it were a standalone CGRA of
        this shape, with the region's row/column indices translated back
        to global coordinates afterwards.  ``grf`` overrides the global
        register file share granted to the region (the GRF is a single
        physical resource, so co-resident regions must split it)."""
        assert 0 < rows <= self.rows and 0 < cols <= self.cols
        return dataclasses.replace(
            self, rows=rows, cols=cols,
            grf=self.grf if grf is None else grf)


# Resource identifiers used across scheduling / binding.  A resource instance
# is (kind, index, modulo_time).
PE = "pe"          # index = (row, col)
IPORT = "iport"    # index = row
OPORT = "oport"    # index = col
IBUS = "ibus"      # index = row
OBUS = "obus"      # index = col
GRF = "grf"        # index = slot
