"""The BandMap pipeline (paper Fig. 3): scheduling with bandwidth allocation
→ routing-resource pre-allocation → binding by MIS on the mixed conflict
graph → incomplete-mapping processing.

`map_dfg(..., mode="busmap")` runs the same pipeline with the BusMap
baseline policy (one port per datum, routing-PE broadcast), which is the
paper's comparison target.
"""

from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from repro.obs.flight import recording
from repro.obs.trace import live

from .certify import IICertificate, certify_ii_infeasible
from .cgra import CGRAConfig
from .conflict import (ConflictGraph, Vertex, build_conflict_graph,
                       constructive_init)
from .dfg import DFG
from .mis import (ROW_CACHE_LIMIT, PortfolioSBTS, ejection_repair,
                  mis_indices)
from .options import MapOptions
from .schedule import ScheduledDFG, mii, schedule_dfg
from .validate import ValidationReport, validate_mapping


@dataclasses.dataclass
class MappingResult:
    ok: bool
    mode: str
    ii: int
    mii: int
    n_routing_pes: int
    ports_per_vio: dict[int, int]
    placement: dict[int, Vertex]
    sched: ScheduledDFG | None
    report: ValidationReport | None
    cg_size: tuple[int, int]      # (|V_C|, |E_C|)
    mis_size: int
    n_ops: int
    attempts: int
    wall_s: float
    # II-infeasibility certificates collected along the way (one per
    # (II, jitter) combination proven unbindable and skipped).
    certificates: list[IICertificate] = dataclasses.field(
        default_factory=list)
    # Set by the exact backend (`repro.exact`).  ``optimal`` marks an
    # ok=True result whose II is proven minimal: every lower
    # (II, jitter) combination from MII up carries a certificate (MII
    # itself is a sound absolute lower bound, so the claim is absolute
    # at II=MII and relative to the engine's deterministic schedule
    # family above it).  ``proved_infeasible`` marks an ok=False result
    # where *every* (II, jitter) combination up to ``max_ii`` was
    # certified unbindable — the sound negative the serve cache admits
    # even when validation attempts were spent along the way.
    # ``backend`` records which engine produced the result
    # ("portfolio" | "exact" | "race:portfolio" | "race:exact").
    optimal: bool = False
    proved_infeasible: bool = False
    backend: str = "portfolio"
    # Flight-recorder dump (JSON-able event dicts, `repro.obs.flight`)
    # attached by `map_dfg` to every ok=False result mapped under a
    # live recorder — the last-N structured events (attempts,
    # certificates, harvest coverage, cancel) a postmortem needs
    # without a traced re-run.  Empty on successes and `record=None`
    # runs, so the common positive path stays lean.
    flight: tuple = ()

    @property
    def ii_ratio(self) -> float:
        """MII / II — the paper's throughput metric (1.0 = best)."""
        return self.mii / self.ii if self.ii else 0.0

    # ------------------------------------------------- serialization
    # Everything a MappingResult holds (ScheduledDFG, Vertex placement,
    # ValidationReport, IICertificate) is plain dataclasses + numpy, so
    # pickle round-trips it exactly; the version tag guards the serving
    # cache's on-disk artifacts (`serve.cache`) against silently loading
    # results written by an incompatible result layout.
    # v2: optimal / proved_infeasible / backend fields (exact backend).
    # v3: flight field (obs flight-recorder dump on failed results).
    SERIAL_VERSION = 3

    def to_bytes(self) -> bytes:
        import pickle
        return pickle.dumps((MappingResult.SERIAL_VERSION, self),
                            protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(data: bytes) -> "MappingResult":
        import pickle
        version, res = pickle.loads(data)
        if version != MappingResult.SERIAL_VERSION:
            raise ValueError(
                f"MappingResult serial version {version} != "
                f"{MappingResult.SERIAL_VERSION}")
        return res

    def summary(self) -> str:
        return (f"{self.mode}: II={self.ii} (MII={self.mii}, "
                f"ratio={self.ii_ratio:.2f}), routingPEs={self.n_routing_pes}, "
                f"|V_C|={self.cg_size[0]}, |E_C|={self.cg_size[1]}, "
                f"ok={self.ok}")

    def explain(self, *, tracer=None, flight=None):
        """Narrated report of *why* the mapping landed here: the II
        escalation path with per-II cause (static floor / certificate
        stage / portfolio exhaustion), routing-PE accounting, coverage
        curve and race outcome.  Returns `repro.obs.ExplainReport`;
        pass the run's ``tracer`` for the coverage/kick detail (the
        result alone carries certificates and any attached flight
        dump).  Imported lazily — `repro.obs.explain` must not be a
        dependency of constructing results."""
        from repro.obs.explain import explain_result
        return explain_result(self, tracer=tracer, flight=flight)


def map_dfg(dfg: DFG, cgra: CGRAConfig,
            options: "MapOptions | dict | None" = None, *,
            cancel=None, tracer=None, record=None,
            **kwargs) -> MappingResult:
    """Run the full 4-phase mapping.  Phase 4 (incomplete-mapping
    processing) = MIS restarts with fresh seeds, re-scheduling with jitter
    (ASAP schedules are II-invariant, so jitter supplies the diversity),
    then II escalation — the retry loop of Fig. 3.

    Options — the `MapOptions` migration
    ------------------------------------
    Every mapping knob lives in `core.options.MapOptions` (frozen,
    grouped: ``schedule`` / ``certify`` / ``portfolio``); this is the
    single source engine modules read knobs from (the
    ``options-single-source`` AST lint rule).  Three call styles:

    - structured: ``map_dfg(dfg, cgra, MapOptions(mode="busmap",
      schedule=ScheduleOptions(max_ii=8)))``;
    - a plain option dict (the serve tier's wire format):
      ``map_dfg(dfg, cgra, {"mode": "busmap", "max_ii": 8})``;
    - legacy keywords, bit-identical to the pre-`MapOptions` engine:
      ``map_dfg(dfg, cgra, mode="busmap", max_ii=8)``.

    Dict and keyword forms go through exactly one adapter,
    `MapOptions.from_kwargs` (unknown keys warn and are dropped); the
    legacy->group renaming is `core.options.LEGACY_KNOBS`
    (``mis_restarts`` -> ``portfolio.restarts``, ``certify_budget`` ->
    ``certify.budget``, ...).  ``cancel`` and ``tracer`` stay true
    keyword arguments: they are runtime handles, not reproducible
    mapping knobs, and never enter `MapOptions.fingerprint` (the serve
    cache key).

    Knob highlights (full reference: `core.options` docstrings):
    ``certify`` runs the II-infeasibility certificate stages before the
    portfolio; ``bus_pressure`` folds provable bus-capacity structure
    into the conflict graph; ``static_prepass`` skips statically-doomed
    IIs via the schedule-free demand analysis; ``min_ii`` floors the II
    escalation (the co-mapper's common-II handle); ``row_cache_limit``
    bounds the unpacked-row caches in bytes; ``max_bus_fanout`` caps
    consumers per delivery port; ``group_move`` enables the clustered
    kick neighbourhood (`mis.GroupMoveConfig`); ``backend`` selects
    ``"portfolio"`` | ``"exact"`` | ``"race"`` (`repro.exact`).

    ``engine`` (``portfolio.engine``) selects the portfolio
    implementation: ``"numpy"`` (the lock-step `mis.PortfolioSBTS`
    oracle, default) or ``"device"`` — the accelerator-resident vmapped
    engine (`core.mis_device.DeviceSBTS`, ``device_seeds`` trajectories
    through the `kernels.sbts_step` Pallas kernel, interpret mode on
    CPU backends).  Both feed the same harvest → dedupe → repair →
    validate loop; device rounds trace as "portfolio-device" spans.

    ``cancel`` (`core.cancel.CancelToken`) makes the run cooperatively
    cancellable: polled between (II, jitter) combinations, between
    harvest rounds, and inside the portfolio's iteration loop; a
    cancelled run returns its best-effort ``ok=False`` result.
    ``tracer`` (`repro.obs.Tracer`, default None) records the run as a
    span tree — "map-dfg" at the root, per-phase children (see
    `repro.obs` for the stable span taxonomy).  ``record``
    (`repro.obs.FlightRecorder`, default None) records the run's
    structured event stream into a bounded ring — cheap enough for
    production serving — and its `dump()` is attached as
    ``result.flight`` to every ``ok=False`` result, so failures carry
    their own postmortem.  All three defaults are bit-identical to the
    flag-less engine (NullTracer / NullFlightRecorder contracts,
    enforced by the ``tracer-default-none`` and
    ``recorder-default-none`` AST lint rules); like ``tracer``,
    ``record`` is a runtime handle, never a fingerprinted knob."""
    opts = MapOptions.coerce(options, kwargs)
    if opts.backend != "portfolio":
        from repro.exact import exact_map_dfg, race_map_dfg
        if opts.backend == "exact":
            return exact_map_dfg(dfg, cgra, options=opts, cancel=cancel,
                                 tracer=tracer)
        if opts.backend == "race":
            return race_map_dfg(dfg, cgra, options=opts, cancel=cancel,
                                tracer=tracer, record=record)
        raise ValueError(f"unknown mapping backend {opts.backend!r}")
    rec = recording(record)
    rec.emit("phase-begin", phase="map-dfg", mode=opts.mode,
             n_ops=len(dfg.ops))
    with live(tracer).span("map-dfg", mode=opts.mode,
                           n_ops=len(dfg.ops)) as sp:
        res = _map_dfg_portfolio(dfg, cgra, opts, cancel=cancel,
                                 tracer=tracer, record=record)
        sp.set(ok=res.ok, ii=res.ii, attempts=res.attempts)
    rec.emit("phase-end", phase="map-dfg", ok=res.ok, ii=res.ii,
             attempts=res.attempts)
    if record is not None:
        # Failed results carry their postmortem; successes stay lean.
        if not res.ok:
            res = dataclasses.replace(res, flight=record.dump())
    return res


def _map_dfg_portfolio(dfg: DFG, cgra: CGRAConfig, opts: "MapOptions",
                       *, cancel, tracer=None,
                       record=None) -> MappingResult:
    trc = live(tracer)
    rec = recording(record)
    t_start = _time.perf_counter()
    mode, seed = opts.mode, opts.seed
    sch, pf, ct = opts.schedule, opts.portfolio, opts.certify
    the_mii = mii(dfg, cgra)
    cache_limit = ROW_CACHE_LIMIT if pf.row_cache_limit is None \
        else pf.row_cache_limit
    device_engine = pf.engine == "device"
    round_span = "portfolio-device" if device_engine else "portfolio"
    static_floor, static_detail = the_mii, ""
    if ct.static_prepass:
        from repro.analysis.demand import implied_demand_bounds
        rec.emit("phase-begin", phase="static-prepass", mii=the_mii)
        with trc.span("static-prepass", mii=the_mii) as ssp:
            for b in implied_demand_bounds(
                    dfg, cgra, max_bus_fanout=sch.max_bus_fanout):
                if b.min_ii > static_floor:
                    static_floor, static_detail = b.min_ii, b.summary()
            ssp.set(floor=static_floor)
        rec.emit("phase-end", phase="static-prepass", floor=static_floor)
    attempts = 0
    certificates: list[IICertificate] = []
    last: tuple = (None, None, None, 0, (0, 0))
    for cur_ii in range(max(the_mii, sch.min_ii or 0), sch.max_ii + 1):
        if cancel is not None and cancel.is_set():
            break
        if cur_ii < static_floor:
            # Schedule-free demand bound: unbindable at every jitter
            # (jitter=-1 marks the whole-slice claim) — skip the
            # schedule, the certificate stages and the portfolio.
            certificates.append(IICertificate(
                ii=cur_ii, jitter=-1, stage="static-demand",
                detail=static_detail, nodes=0, wall_s=0.0))
            rec.emit("static-skip", ii=cur_ii, floor=static_floor)
            continue
        for jitter in (0, 1, 2, 3):
            if cancel is not None and cancel.is_set():
                break
            rec.emit("attempt", ii=cur_ii, jitter=jitter)
            try:
                with trc.span("schedule", ii=cur_ii, jitter=jitter):
                    sched = schedule_dfg(
                        dfg, cgra, mode=mode, ii=cur_ii,
                        max_ii=cur_ii, use_grf=sch.use_grf,
                        jitter=jitter, seed=seed,
                        max_bus_fanout=sch.max_bus_fanout)
            except RuntimeError:
                continue
            cg = build_conflict_graph(sched, cgra,
                                      bus_pressure=opts.bus_pressure,
                                      tracer=tracer)
            n_ops = len(sched.dfg.ops)
            # One unpacked-row cache per conflict graph, shared by the
            # certificate search, the portfolio and the repair retries
            # (memoized on the graph — harvest rounds and repair retries
            # reuse it instead of re-unpacking n² rows each).
            shared_u8 = cg.row_cache(cache_limit)
            if ct.enabled:
                cert, csp_sols = certify_ii_infeasible(
                    cg, sched, cgra, jitter=jitter,
                    node_budget=ct.budget, row_cache=shared_u8,
                    n_placements=ct.n_exact_placements,
                    row_cache_limit=cache_limit, cancel=cancel,
                    tracer=tracer)
                if cert is not None:
                    # Proven unbindable: skip the whole portfolio budget
                    # for this (II, jitter) combination.
                    certificates.append(cert)
                    rec.emit("certificate", ii=cur_ii, jitter=jitter,
                             stage=cert.stage, nodes=cert.nodes)
                    if last[0] is None:
                        last = (sched, None, None, 0, (cg.n, cg.n_edges))
                    continue
                # The exhaustive stage enumerated complete conflict-free
                # placements — try each on the validator before paying
                # for the portfolio (several, because bus packing / LRF
                # residency can reject the first).
                for csp_sol in csp_sols or ():
                    attempts += 1
                    placement = {cg.vertices[i].op: cg.vertices[i]
                                 for i in mis_indices(csp_sol)}
                    with trc.span("validate", ii=cur_ii, source="csp"):
                        report = validate_mapping(sched, cgra, placement)
                    last = (sched, placement, report, n_ops,
                            (cg.n, cg.n_edges))
                    if not report.ok:
                        rec.emit("validate-reject", ii=cur_ii,
                                 source="csp")
                    if report.ok:
                        return MappingResult(
                            ok=True, mode=mode, ii=cur_ii, mii=the_mii,
                            n_routing_pes=sched.n_routing_ops,
                            ports_per_vio=dict(sched.ports_allocated),
                            placement=placement, sched=sched,
                            report=report, cg_size=(cg.n, cg.n_edges),
                            mis_size=n_ops, n_ops=n_ops,
                            attempts=attempts,
                            wall_s=_time.perf_counter() - t_start,
                            certificates=certificates)
            # Spend extra effort at II = MII: throughput is the top concern
            # (paper §III-A), so a success there dominates any II+1 mapping.
            budget = pf.restarts * (2 if cur_ii == the_mii else 1)
            # Multi-seed SBTS portfolio: K independent trajectories advance
            # in lock-step over the packed adjacency, early-exiting as soon
            # as any seed covers every op.  Most seeds warm-start from the
            # structure-aware constructive placement; some stay cold.
            base = seed * 1001 + cur_ii * 131 + jitter * 31
            with trc.span("portfolio-init", ii=cur_ii, jitter=jitter,
                          seeds=budget, engine=pf.engine):
                inits = [constructive_init(cg, sched, cgra,
                                           seed=base + k)
                         if k % 3 != 2 else None for k in range(budget)]
                attempts += budget
                op_of = cg.op_of
                if device_engine:
                    # Accelerator-resident engine: the same constructive
                    # warm starts, fanned out to `device_seeds` lock-step
                    # trajectories on-device (interpret mode on CPU).
                    from .mis_device import DeviceSBTS
                    sbts = DeviceSBTS(cg.bits, inits,
                                      k=pf.device_seeds, seed=base)
                else:
                    sbts = PortfolioSBTS(cg.bits, inits, seed=base,
                                         row_cache=shared_u8,
                                         row_cache_limit=cache_limit,
                                         op_of=op_of,
                                         group_move=pf.group_move)
            # Repair retries reuse the same cache; when the graph was too
            # big for it, row_cache() materialises one lazily so the
            # retries don't each re-unpack n² rows.
            row_cache = shared_u8
            seen_sols: set[bytes] = set()
            remaining = pf.iters
            # Harvest rounds: run the portfolio until some seed covers all
            # ops, validate every distinct complete solution, and — when
            # the validator rejects them all (bus congestion / LRF
            # overflow are invisible to the pairwise graph) — re-arm the
            # complete seeds with a diversifying perturbation and resume
            # the same trajectories until the iteration budget is spent.
            fresh = budget
            for rnd in range(4 * budget):
                if cancel is not None and cancel.is_set():
                    break
                start_it = sbts.it
                with trc.span(round_span, ii=cur_ii, jitter=jitter,
                              round=rnd) as psp:
                    bests = sbts.run(remaining, target=n_ops,
                                     cancel=cancel, tracer=tracer)
                    best_cov = int(sbts.best_size.max()) if sbts.k \
                        else 0
                    psp.set(iters=sbts.it - start_it, best=best_cov,
                            coverage=best_cov / n_ops if n_ops else 1.0)
                    trc.gauge("portfolio.best", best_cov)
                    trc.gauge("portfolio.coverage",
                              best_cov / n_ops if n_ops else 1.0)
                rec.emit("harvest-round", ii=cur_ii, jitter=jitter,
                         round=rnd, best=best_cov,
                         coverage=best_cov / n_ops if n_ops else 1.0)
                remaining -= sbts.it - start_it
                order = np.argsort(-bests.sum(axis=1), kind="stable")
                for k in order:
                    sol = bests[k].copy()
                    key = sol.tobytes()
                    if key in seen_sols:
                        # Seeds often converge to the same best set;
                        # repairing duplicates wastes the ejection budget.
                        continue
                    seen_sols.add(key)
                    size = int(sol.sum())
                    if 0 < n_ops - size <= 4:
                        # Ejection-chain repair of small shortfalls
                        # (multi-seed: candidate order is randomised, so
                        # retries differ).
                        rs = base + rnd * 97 + int(k)
                        with trc.span("repair", ii=cur_ii,
                                      shortfall=n_ops - size):
                            if row_cache is None:
                                # Lazy n² unpack — on 16x16 graphs this
                                # dominates the first repair's wall.
                                row_cache = sbts.row_cache()
                            for rk in range(6):
                                fixed = ejection_repair(
                                    cg.bits, sol, cg.op_vertices, op_of,
                                    depth=4, seed=rs * 13 + rk,
                                    row_cache=row_cache)
                                if int(fixed.sum()) >= n_ops:
                                    sol = fixed
                                    break
                            else:
                                sol = fixed
                        size = int(sol.sum())
                    if size < n_ops:
                        last = (sched, None, None, size,
                                (cg.n, cg.n_edges))
                        continue
                    placement = {cg.vertices[i].op: cg.vertices[i]
                                 for i in mis_indices(sol)}
                    with trc.span("validate", ii=cur_ii,
                                  source="portfolio"):
                        report = validate_mapping(sched, cgra, placement)
                    last = (sched, placement, report, size,
                            (cg.n, cg.n_edges))
                    if not report.ok:
                        rec.emit("validate-reject", ii=cur_ii,
                                 source="portfolio")
                    if report.ok:
                        return MappingResult(
                            ok=True, mode=mode, ii=cur_ii, mii=the_mii,
                            n_routing_pes=sched.n_routing_ops,
                            ports_per_vio=dict(sched.ports_allocated),
                            placement=placement, sched=sched,
                            report=report, cg_size=(cg.n, cg.n_edges),
                            mis_size=size, n_ops=n_ops, attempts=attempts,
                            wall_s=_time.perf_counter() - t_start,
                            certificates=certificates)
                if remaining <= 0:
                    break
                # Alternate a local diversification with a fully fresh
                # restart (the portfolio analogue of the paper's
                # independent-restart retry) for every harvested seed.
                complete = np.flatnonzero(sbts.best_size >= n_ops)
                if device_engine:
                    # With K ~ 1000 device trajectories, hundreds may
                    # converge per round; re-seeding them all would pay
                    # a constructive_init per seed on the host.  The
                    # top 16 preserve the diversification pattern at
                    # bounded host cost.
                    complete = complete[:16]
                for j, k in enumerate(complete):
                    if j % 2 == 0:
                        sbts.rearm(int(k))
                    else:
                        fresh += 1
                        sbts.reset_seed(int(k), constructive_init(
                            cg, sched, cgra, seed=base + fresh))
    sched, placement, report, size, cg_size = last
    if cancel is not None and cancel.is_set():
        rec.emit("cancelled", ii=sched.ii if sched else -1)
    # attempts == 0 with certificates attached means every (II, jitter)
    # combination that scheduled was *proven* unbindable before any
    # stochastic search ran — a full-range UNSAT proof, unless a cancel
    # cut the II loop short (then the certificates only cover a prefix
    # of the range and the result must not claim the proof).
    proved = bool(certificates) and attempts == 0 \
        and not (cancel is not None and cancel.is_set())
    return MappingResult(
        ok=False, mode=mode, ii=sched.ii if sched else -1, mii=the_mii,
        n_routing_pes=sched.n_routing_ops if sched else 0,
        ports_per_vio=dict(sched.ports_allocated) if sched else {},
        placement=placement or {}, sched=sched, report=report,
        cg_size=cg_size, mis_size=size,
        n_ops=len(sched.dfg.ops) if sched else 0, attempts=attempts,
        wall_s=_time.perf_counter() - t_start,
        certificates=certificates, proved_infeasible=proved)


def compare_modes(dfg: DFG, cgra: CGRAConfig, *, seed: int = 0,
                  **kw) -> dict[str, MappingResult]:
    """BandMap vs BusMap on the same DFG/CGRA — the paper's experiment."""
    return {m: map_dfg(dfg, cgra, mode=m, seed=seed, **kw)
            for m in ("bandmap", "busmap")}
