"""CnKm kernel-loop DFG generators (paper §IV-A).

In every iteration a CnKm kernel consumes n input-channel data and produces
m output-channel data; each of the n channel data is spatially reused by the
m kernels.  The computing body is the MAC lattice

    acc[j] = sum_i  in[i] * w[i][j]        (j = 0..m-1)

with the weights held in LRFs (temporal reuse — only the *input* data is the
high-spatial-reuse case the paper targets), giving:

- n VIOs, each with RD = m (consumed by the m MACs of its column),
- n*m computing MAC ops, chained over i within each output channel j,
- m VOOs (RD = 1) fed by the last MAC of each chain.
"""

from __future__ import annotations

from .dfg import DFG, OpKind

# The seven kernels evaluated in the paper's Fig. 5.  The text names C2K4,
# C3K6 and C5K5; the remaining four are chosen to cover the m<=4 / m>4 split
# the figure shows (see DESIGN.md §3).
PAPER_KERNELS: list[tuple[int, int]] = [
    (1, 2), (2, 4), (2, 6), (3, 6), (4, 4), (2, 8), (5, 5),
]

# Extra kernels beyond the paper's seven: heavier packing stress (C4K8,
# C3K8) and a port-starved case (C8K6) where even BandMap's allocation
# falls back to routing PEs (Q < ceil(RD/M)).
EXTRA_KERNELS: list[tuple[int, int]] = [(4, 8), (3, 8), (8, 6)]


def cnkm_name(n: int, m: int) -> str:
    return f"C{n}K{m}"


def make_cnkm(n: int, m: int) -> DFG:
    """Build the CnKm DFG described above."""
    d = DFG()
    vins = [d.add_op(OpKind.VIN, f"in{i}") for i in range(n)]
    # mac[i][j]: consumes in[i]; chained over i per output channel j.
    mac = [[d.add_op(OpKind.COMPUTE, f"mac{i}_{j}") for j in range(m)]
           for i in range(n)]
    for i in range(n):
        for j in range(m):
            d.add_edge(vins[i], mac[i][j])
            if i > 0:
                d.add_edge(mac[i - 1][j], mac[i][j])
    vouts = [d.add_op(OpKind.VOUT, f"out{j}") for j in range(m)]
    for j in range(m):
        d.add_edge(mac[n - 1][j], vouts[j])
    return d


def all_paper_kernels() -> dict[str, DFG]:
    return {cnkm_name(n, m): make_cnkm(n, m) for n, m in PAPER_KERNELS}
