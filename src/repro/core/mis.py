"""Phase 3b: maximum-independent-set solver.

The paper applies SBTS — general Swap-Based multiple neighborhood Tabu
Search (Jin & Hao, 2015) — to the conflict graph.  This is a faithful
re-implementation of its core loop over numpy adjacency:

- greedy (min-degree, randomized) construction of an initial solution,
- (1,0) *add* moves: insert any vertex with zero conflicts in S,
- (1,1) *swap* moves: insert a vertex with exactly one conflicting member u
  and evict u (tabu on u for `tenure` iterations, aspiration on best),
- perturbation (random k-eviction) when the search plateaus.

`solve_mis` stops early when `target` (= |V_D|, one placement per op) is
reached — the mapping use-case never needs a certified maximum.
"""

from __future__ import annotations

import numpy as np


def greedy_mis(adj: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    n = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.int64)
    alive = np.ones(n, dtype=bool)
    in_s = np.zeros(n, dtype=bool)
    while alive.any():
        cand = np.flatnonzero(alive)
        d = deg[cand] + rng.random(cand.size)  # random tie-break
        v = cand[int(np.argmin(d))]
        in_s[v] = True
        kill = adj[v] & alive
        alive[v] = False
        alive[kill] = False
        deg -= adj[:, kill].sum(axis=1)
    return in_s


def solve_mis(adj: np.ndarray, *, target: int | None = None,
              max_iters: int = 20000, tenure: int = 7,
              seed: int = 0, init: np.ndarray | None = None) -> np.ndarray:
    """Return a boolean membership vector of an (approximately maximum)
    independent set of the conflict graph ``adj``.  ``init`` may supply an
    independent set to warm-start from (e.g. the constructive placement)."""
    n = adj.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    rng = np.random.default_rng(seed)
    in_s = init.copy() if init is not None else greedy_mis(adj, rng)
    # conf[v] = number of members of S adjacent to v.
    conf = adj[:, in_s].sum(axis=1).astype(np.int64)
    best = in_s.copy()
    best_size = int(in_s.sum())
    if target is not None and best_size >= target:
        return best
    tabu = np.zeros(n, dtype=np.int64)
    stall = 0
    for it in range(1, max_iters + 1):
        size = int(in_s.sum())
        # (1,0) add moves: all conflict-free outsiders at once.
        addable = (~in_s) & (conf == 0)
        if addable.any():
            order = np.flatnonzero(addable)
            rng.shuffle(order)
            for v in order:
                if not in_s[v] and conf[v] == 0:
                    in_s[v] = True
                    conf += adj[v]
            size = int(in_s.sum())
            if size > best_size:
                best_size, best = size, in_s.copy()
                stall = 0
                if target is not None and best_size >= target:
                    return best
            continue
        # (1,1) swap: v outside with exactly one conflicting member u.
        cand = np.flatnonzero((~in_s) & (conf == 1) & (tabu <= it))
        if cand.size:
            v = int(rng.choice(cand))
            u = int(np.flatnonzero(adj[v] & in_s)[0])
            in_s[u] = False
            conf -= adj[u]
            in_s[v] = True
            conf += adj[v]
            tabu[u] = it + tenure + int(rng.integers(0, 4))
            stall += 1
        else:
            stall += 3
        if stall > 60:
            # Perturbation: evict a random ~10 % of S.
            members = np.flatnonzero(in_s)
            k = max(1, members.size // 10)
            evict = rng.choice(members, size=k, replace=False)
            for u in evict:
                in_s[u] = False
                conf -= adj[u]
                tabu[u] = it + tenure
            stall = 0
    return best


def mis_indices(membership: np.ndarray) -> np.ndarray:
    return np.flatnonzero(membership)


def ejection_repair(adj: np.ndarray, in_s: np.ndarray,
                    op_vertices: dict[int, list[int]],
                    op_of: np.ndarray, *, depth: int = 3,
                    seed: int = 0) -> np.ndarray:
    """Ejection-chain repair: try to place every op that has no selected
    candidate by inserting one of its candidates, evicting the (≤2)
    conflicting members, and recursively re-placing the evicted ops'
    alternatives up to ``depth``.  Closes the 1–2-vertex shortfalls SBTS
    plateaus on for tightly-packed instances (e.g. BusMap C4K8)."""
    rng = np.random.default_rng(seed)
    in_s = in_s.copy()
    conf = adj[:, in_s].sum(axis=1).astype(np.int64)
    nodes = [0]  # search-node budget (keeps worst-case bounded)

    def place(op: int, d: int, banned: set[int]) -> bool:
        nonlocal conf
        nodes[0] += 1
        if nodes[0] > 20000:
            return False
        cands = [v for v in op_vertices[op] if not in_s[v] and v not in banned]
        rng.shuffle(cands)
        # Prefer fewest evictions.
        cands.sort(key=lambda v: conf[v])
        for v in cands:
            evict = np.flatnonzero(adj[v] & in_s)
            if conf[v] == 0:
                in_s[v] = True
                conf += adj[v]
                return True
            if d == 0 or len(evict) > 2:
                continue
            evicted_ops = [int(op_of[u]) for u in evict]
            # Snapshot: recursive placements mutate state and `all` short-
            # circuits, so restore wholesale on failure.
            in_s_snap, conf_snap = in_s.copy(), conf.copy()
            for u in evict:
                in_s[u] = False
                conf -= adj[u]
            in_s[v] = True
            conf += adj[v]
            nb = banned | {v}
            if all(place(eo, d - 1, nb) for eo in evicted_ops):
                return True
            in_s[:] = in_s_snap
            conf = conf_snap
        return False

    placed_ops = {int(op_of[v]) for v in np.flatnonzero(in_s)}
    for op in op_vertices:
        if op not in placed_ops:
            if place(op, depth, set()):
                placed_ops.add(op)
    assert not adj[np.ix_(in_s, in_s)].any(), "repair broke independence"
    return in_s
