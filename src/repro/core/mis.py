"""Phase 3b: maximum-independent-set solver on packed-bitset adjacency.

The paper applies SBTS — general Swap-Based multiple neighborhood Tabu
Search (Jin & Hao, 2015) — to the conflict graph.  This re-implements its
core loop over :class:`~repro.core.bitset.BitsetGraph` rows:

- greedy (min-degree, randomized) construction of an initial solution,
- (1,0) *add* moves: insert any vertex with zero conflicts in S,
- (1,1) *swap* moves: insert a vertex with exactly one conflicting member u
  and evict u (tabu on u for `tenure` iterations, aspiration on best),
- perturbation (random k-eviction) when the search plateaus.

Two entry points:

- :func:`solve_mis` — one SBTS trajectory (the original API; accepts a
  dense bool matrix or a BitsetGraph);
- :func:`solve_mis_portfolio` — K independent seeds advanced in lock-step:
  every per-iteration quantity (conflict counts, move candidates, tabu
  clocks) is a ``[K, n]`` array, so one numpy expression serves the whole
  portfolio and the per-iteration interpreter overhead is amortised K-fold.
  The portfolio exits as soon as any seed reaches ``target`` (= |V_D|, one
  placement per op) — the mapping use-case never needs a certified maximum.
"""

from __future__ import annotations

import dataclasses
import math as _math

import numpy as np

from .bitset import BitsetGraph, as_bitset_graph, pack_bool

# Unpacked-row caches ([n, n] uint8) are materialised only below this
# byte bound; larger graphs fall back to per-move unpack.
ROW_CACHE_LIMIT = 1 << 25


@dataclasses.dataclass(frozen=True)
class GroupMoveConfig:
    """Knobs for the clustered group-move ("kick") neighbourhood.

    The (1,1) swap neighbourhood moves one vertex at a time, so a VIO
    whose bus-fed consumers ended up spread over several rows can never
    be repaired: every candidate of the unplaced op conflicts with >= 2
    selected vertices at once, and the portfolio stalls just below full
    coverage.  The kick ejects the *whole* blocking cluster — the
    unplaced op's conflicting placements, discovered from the packed
    adjacency in one union-AND (`BitsetGraph.cluster_members`) — and
    re-inserts the cluster's ops atomically at a different row/slot
    assignment, with the ejected placements tabu'd for ``tenure``
    iterations so the seed cannot immediately rebuild the local minimum.

    ``cadence``     — kick every this many portfolio super-iterations
                      (the kick replaces that iteration's swap, so the
                      flag-on/off iteration budgets stay comparable).
    ``max_cluster`` — cap on the number of ops ejected per kick; a
                      candidate blocked by more ops than this is not
                      kicked (the cluster for a stalled VIO is its
                      target row's occupants plus its own stray
                      consumers, ~rows + fanout ops).
    ``tenure``      — tabu tenure applied to ejected placements; longer
                      than the swap tenure so a kick outlives the swap
                      phase's churn.
    """
    enabled: bool = True
    cadence: int = 40
    max_cluster: int = 24
    tenure: int = 30


def greedy_mis(adj, rng: np.random.Generator,
               row_cache: np.ndarray | None = None) -> np.ndarray:
    """Randomized min-degree construction; returns a maximal IS.

    The degree update unpacks only the *killed* rows (gathered from
    ``row_cache`` when the caller shares one): the decrement of
    ``deg[v]`` is the number of killed neighbours of v, i.e. the
    column sum of the killed vertices' rows — integer-identical to the
    old whole-matrix ``popcount(rows & kill)`` pass but O(|kill| * n)
    instead of O(n * words) per placement, which is what made cold
    portfolio warm-starts dominate 16x16-fabric map walls (PR 8
    traces)."""
    g = as_bitset_graph(adj)
    n = g.n
    deg = g.degrees()
    alive = np.ones(n, dtype=bool)
    in_s = np.zeros(n, dtype=bool)
    while alive.any():
        cand = np.flatnonzero(alive)
        d = deg[cand] + rng.random(cand.size)  # random tie-break
        v = cand[int(np.argmin(d))]
        in_s[v] = True
        kill = g.row_u8(v).astype(bool) & alive
        alive[v] = False
        alive[kill] = False
        killed = np.flatnonzero(kill)
        if killed.size:
            rows = row_cache[killed] if row_cache is not None \
                else g.rows_u8(killed)
            deg -= rows.sum(axis=0, dtype=np.int64)
    return in_s


class PortfolioSBTS:
    """K SBTS trajectories in lock-step over one BitsetGraph.

    State arrays are ``[K, n]``; one super-iteration applies one move per
    seed (a conflict-free add where available, else a tabu-guarded swap),
    with per-seed plateau perturbation.  Independence is invariant per
    seed: adds require ``conf == 0``, swaps evict the unique conflicting
    member before inserting.
    """

    def __init__(self, g: BitsetGraph, inits, *, tenure: int = 7,
                 seed: int = 0, row_cache: np.ndarray | None = None,
                 row_cache_limit: int | None = None,
                 op_of: np.ndarray | None = None,
                 group_move: "GroupMoveConfig | None" = None):
        self.g = g
        self.k = len(inits)
        self.tenure = tenure
        self.rng = np.random.default_rng(seed)
        n = g.n
        # Unpacked 0/1 row cache for delta updates: one unpackbits of the
        # whole packed adjacency (or a caller-shared one, e.g. the
        # certificate stage's), after which each move's row fetch is a
        # fancy gather.  Bounded to ``row_cache_limit`` bytes (default
        # ROW_CACHE_LIMIT = 32 MiB); beyond that, rows are unpacked per
        # move (still O(n/8) traffic) — the |V_C| ~ 10^4 regime of a
        # 16x16 PEA lands on this fallback.  Resolved before the inits
        # so cold greedy constructions gather from the shared cache.
        self.row_cache_limit = ROW_CACHE_LIMIT if row_cache_limit is None \
            else row_cache_limit
        if row_cache is not None:
            self._u8 = row_cache
        else:
            self._u8 = g.rows_u8(np.arange(n)) \
                if 0 < n * n <= self.row_cache_limit else None
        self.in_s = np.zeros((self.k, n), dtype=bool)
        for i, init in enumerate(inits):
            if init is None:
                self.in_s[i] = greedy_mis(g, self.rng, self._u8)
            else:
                self.in_s[i] = init
        # conf[k, v] = number of members of S_k adjacent to v.
        conf_dtype = np.int16 if n < (1 << 15) else np.int32
        self.conf = np.stack([g.conflict_counts(pack_bool(row))
                              for row in self.in_s]).astype(conf_dtype)
        self.tabu = np.zeros((self.k, n), dtype=np.int32)
        self.stall = np.zeros(self.k, dtype=np.int64)
        # Desynchronized plateau thresholds: members of a lock-step
        # portfolio stall together, so identical thresholds would fire
        # every perturbation (and its add-sweep refill) simultaneously.
        self._thresh = 60 + self.rng.integers(0, 24, self.k)
        # Pregenerated tabu-tenure jitter (values 0..3): cycling 256 draws
        # replaces a per-iteration bit-generator call.
        self._ints = self.rng.integers(0, 4, (256, self.k), dtype=np.int32)
        self.size = self.in_s.sum(axis=1)
        self.best = self.in_s.copy()
        self.best_size = self.size.copy()
        self.it = 0
        self._probe_adds = True
        self._rand = self.rng.random((self.k, 2 * max(n, 1)),
                                     dtype=np.float32)
        self._pool_uses = 0
        self._stride = 0   # drawn (coprime to n) at the first _draw
        self._u8_ext: np.ndarray | None = None  # row_cache() overflow copy
        # Group-move neighbourhood (off by default).  Everything below is
        # inert when disabled: the main loop's state arrays, RNG stream
        # and move sequence are untouched, so flag-off trajectories stay
        # bit-identical to a solver constructed without these arguments.
        self._gm = group_move if group_move is not None \
            and group_move.enabled else None
        if self._gm is not None and op_of is None:
            raise ValueError("group_move requires op_of (vertex -> op)")
        if op_of is not None:
            op_of = np.asarray(op_of, dtype=np.int64)
            _, self._op_idx = np.unique(op_of, return_inverse=True)
            self._n_ops = int(self._op_idx.max()) + 1 if n else 0
            order = np.argsort(self._op_idx, kind="stable")
            bounds = np.searchsorted(self._op_idx[order],
                                     np.arange(1, self._n_ops))
            self._op_cands = np.split(order, bounds)
        else:
            self._op_idx = None
        # Separate RNG stream: kicks never advance the main generator, so
        # enabling the flag perturbs only the iterations it fires on.
        self._gm_rng = np.random.default_rng(
            (seed * 2654435761 + 0x9E3779B9) & 0x7FFFFFFFFFFFFFFF)

    def row_cache(self) -> np.ndarray:
        """Unpacked 0/1 adjacency ``uint8 [n, n]``, shared with callers
        (e.g. ejection-repair retries).  When the constructor skipped the
        cache (graph beyond the 32 MiB bound), materialise it lazily here
        so the solver's per-move path keeps its per-move unpack policy
        while one-shot consumers still get a single unpack."""
        if self._u8 is not None:
            return self._u8
        if self._u8_ext is None:
            self._u8_ext = self.g.rows_u8(np.arange(self.g.n))
        return self._u8_ext

    def _rows(self, vs: np.ndarray) -> np.ndarray:
        return self._u8[vs] if self._u8 is not None else self.g.rows_u8(vs)

    def _row(self, v: int) -> np.ndarray:
        return self._u8[v] if self._u8 is not None else self.g.row_u8(v)

    def run(self, max_iters: int, target: int | None = None,
            cancel=None, tracer=None) -> np.ndarray:
        """Advance all seeds up to ``max_iters`` iterations each (an
        iteration is a full (1,0) add sweep or one (1,1) swap, matching
        the single-trajectory SBTS accounting); stop early when any
        seed's best reaches ``target``.  Returns per-seed best
        memberships ``bool [K, n]``.

        ``cancel`` (a `core.cancel.CancelToken`) is polled at the top of
        every iteration: a cancelled run stops before advancing further
        and returns the bests so far.  ``cancel=None`` leaves the
        trajectories bit-identical to the flag-less engine (the polling
        never touches the RNG streams)."""
        # Per-super-iteration counter handle; the NullCounter default
        # keeps the untraced loop at one no-op call per [K, n] sweep and
        # never touches the RNG streams either way.
        from repro.obs.trace import live
        iters_counter = live(tracer).counter("portfolio.iters")
        kick_counter = live(tracer).counter("portfolio.kicks")
        if self.g.n == 0 or self.k == 0:
            return self.best
        if target is not None and (self.best_size >= target).any():
            return self.best
        n, k_idx = self.g.n, np.arange(self.k)
        for _ in range(max_iters):
            if cancel is not None and cancel.is_set():
                break
            self.it += 1
            iters_counter.inc()
            it = self.it
            # Periodic group-move kick: spend this iteration ejecting and
            # atomically re-placing a blocking cluster per stalled seed
            # (see GroupMoveConfig).  Counts against the iteration budget
            # so flag-on/off runs compare at equal budgets.
            if self._gm is not None and it % self._gm.cadence == 0:
                kick_counter.inc()
                self._group_kick(target)
                if target is not None and \
                        (self.best_size >= target).any():
                    return self.best
                continue
            # Add moves appear only after evictions free a vertex's whole
            # neighbourhood — probe for them periodically (and right
            # after perturb/rearm/reset) instead of every iteration; a
            # deferred (1,0) sweep costs at most 3 iterations of delay.
            if self._probe_adds or it % 4 == 1:
                self._probe_adds = False
                # Tabu applies to re-insertion too: unlike the original
                # solver's add phase, rearm/perturb evictions stay out
                # for their tenure instead of being re-added on the next
                # probe — that is what makes those diversifications
                # actually diversify.
                addable = (self.conf == 0) & (self.tabu <= it)
                addable &= ~self.in_s
                can_add = addable.any(axis=1)
                if can_add.any():
                    # (1,0) sweep: absorb every conflict-free outsider of
                    # the affected seeds, then re-enter.
                    self._sweep_adds(np.flatnonzero(can_add), addable)
                    if target is not None and \
                            (self.best_size >= target).any():
                        return self.best
                    continue
            # Pure-swap fast path: every per-iteration quantity is one
            # [K, n] expression, no boolean-mask copies.  No ~in_s term:
            # members have conf == 0 by independence, so conf == 1
            # already excludes them.
            swapable = (self.conf == 1) & (self.tabu <= it)
            r = self._draw(n)
            vs = (r * swapable).argmax(axis=1)
            # Validity by gather, not a second [K, n] reduction: the
            # argmax lands on a candidate iff the seed has one.
            has = swapable[k_idx, vs]
            if not has.all():
                self.stall[~has] += 3
                if not has.any():
                    self._perturb()
                    continue
            rows_v = self._rows(vs)
            # Evict the unique in-S neighbour of each swap insertion.
            us = (rows_v & self.in_s).argmax(axis=1)
            rows_u = self._rows(us)
            jit4 = self._ints[it & 255]
            if has.all():
                self.in_s[k_idx, us] = False
                self.in_s[k_idx, vs] = True
                self.conf += rows_v
                self.conf -= rows_u
                self.tabu[k_idx, us] = it + self.tenure + jit4
                self.stall += 1
            else:
                hk = k_idx[has]
                self.in_s[hk, us[has]] = False
                self.in_s[hk, vs[has]] = True
                self.conf[has] += rows_v[has]
                self.conf[has] -= rows_u[has]
                self.tabu[hk, us[has]] = it + self.tenure + jit4[has]
                self.stall[has] += 1
            if (self.stall > self._thresh).any():
                self._perturb()
        return self.best

    def _draw(self, n: int) -> np.ndarray:
        """Tie-break randoms: a strided view into a pregenerated pool
        (refreshed every n draws), so the hot loop never calls the bit
        generator for [K, n] data.  The stride is re-drawn coprime to n
        at each refresh, so consecutive draws cycle through all n
        offsets (a fixed stride degenerates when n divides it)."""
        self._pool_uses += 1
        if self._pool_uses >= n or self._stride == 0:
            self._rand = self.rng.random((self.k, 2 * n),
                                         dtype=np.float32)
            self._pool_uses = 0
            self._stride = int(self.rng.integers(1, max(n, 2)))
            while _math.gcd(self._stride, n) != 1:
                self._stride += 1
        off = (self._pool_uses * self._stride) % n
        return self._rand[:, off:off + n]

    def _sweep_adds(self, states: np.ndarray, addable: np.ndarray) -> None:
        """(1,0) phase: per affected seed, shuffle the (non-tabu)
        conflict-free outsiders and insert them sequentially (earlier
        inserts may re-conflict later candidates)."""
        for k in states:
            cand = np.flatnonzero(addable[k])
            rows_c = self._rows(cand)
            if not rows_c[:, cand].any():
                # Pairwise conflict-free (the common case: a perturbation
                # evicted a sparse set): insert the whole batch at once.
                self.in_s[k, cand] = True
                self.conf[k] += rows_c.sum(axis=0, dtype=self.conf.dtype)
                self.size[k] += cand.size
            else:
                self.rng.shuffle(cand)
                for v in cand:
                    if self.conf[k, v] == 0 and not self.in_s[k, v]:
                        self.in_s[k, v] = True
                        self.conf[k] += self._row(v)
                        self.size[k] += 1
            if self.size[k] > self.best_size[k]:
                self.best_size[k] = self.size[k]
                self.best[k] = self.in_s[k]
                self.stall[k] = 0

    def rearm(self, k: int, frac: float = 0.25) -> None:
        """Diversify seed ``k`` after the caller harvested its best (e.g.
        the mapping validator rejected it): restart from the best set
        minus a random slice, tabu the evicted vertices so the seed does
        not immediately rebuild the same solution, and reset the best
        tracking so the target early-exit re-arms.

        With group moves enabled the random slice (and ``frac``) is
        replaced by a coherent cluster eviction (`_rearm_cluster`,
        capped at the kick's ``max_cluster``) — moving a coupled group
        together diversifies tightly-coupled instances where a random
        slice would be rebuilt verbatim."""
        self.in_s[k] = self.best[k]
        members = np.flatnonzero(self.in_s[k])
        if members.size:
            if self._gm is not None:
                # Clustered re-placement: evict a coherent blocking
                # cluster around one random placement instead of a
                # random slice — a diversification that actually moves
                # coupled groups (VIO + row-pinned consumers) together.
                evict = self._rearm_cluster(k, members)
                self.in_s[k, evict] = False
                self.tabu[k, evict] = self.it + self._gm.tenure + \
                    int(self._gm_rng.integers(0, 10))
            else:
                evict = self.rng.choice(
                    members, size=max(1, int(members.size * frac)),
                    replace=False)
                self.in_s[k, evict] = False
                self.tabu[k, evict] = self.it + 3 * self.tenure + \
                    self.rng.integers(0, 10)
        self._resync(k)

    def reset_seed(self, k: int, init: np.ndarray | None = None) -> None:
        """Fully restart one trajectory from ``init`` (or a fresh greedy
        construction) — the portfolio analogue of an independent SBTS
        restart, used when a harvested solution failed downstream
        validation and its basin looks exhausted."""
        self.in_s[k] = greedy_mis(self.g, self.rng, self._u8) \
            if init is None \
            else init
        self.tabu[k] = 0
        self._resync(k)

    def _resync(self, k: int) -> None:
        """Recompute seed ``k``'s derived state from ``in_s[k]`` after an
        out-of-band membership edit, and re-arm its best tracking."""
        if self._u8 is not None:
            self.conf[k] = self._u8[self.in_s[k]].sum(axis=0,
                                                      dtype=np.int32)
        else:
            self.conf[k] = self.g.conflict_counts(pack_bool(self.in_s[k]))
        self.size[k] = int(self.in_s[k].sum())
        self.best[k] = self.in_s[k]
        self.best_size[k] = self.size[k]
        self.stall[k] = 0
        self._probe_adds = True

    def _perturb(self) -> None:
        """Random ~10 % eviction for seeds whose search plateaued.  The
        per-seed thresholds are re-randomized after each firing, so in
        steady state a firing involves one or two seeds, not the whole
        lock-step portfolio at once."""
        for k in np.flatnonzero(self.stall > self._thresh):
            members = np.flatnonzero(self.in_s[k])
            if members.size:
                # ~10 % sample; duplicates dropped (cheaper than an
                # exact without-replacement draw at this size).
                pick = self.rng.integers(0, members.size,
                                         max(1, members.size // 10))
                evict = members[np.unique(pick)]
                self.in_s[k, evict] = False
                self.size[k] -= evict.size
                self.conf[k] -= self._rows(evict).sum(
                    axis=0, dtype=self.conf.dtype)
                self.tabu[k, evict] = self.it + self.tenure
            self.stall[k] = 0
            self._thresh[k] = 60 + self.rng.integers(0, 24)
            self._probe_adds = True

    # ------------------------------------------------- group-move kick
    def _eject(self, k: int, blockers: np.ndarray) -> None:
        """Remove ``blockers`` from seed ``k`` and tabu their (old)
        placements with the kick's tenure so the seed cannot
        immediately rebuild the minimum it just escaped."""
        self.in_s[k, blockers] = False
        self.conf[k] -= self._rows(blockers).sum(
            axis=0, dtype=self.conf.dtype)
        self.size[k] -= blockers.size
        self.tabu[k, blockers] = self.it + self._gm.tenure + \
            self._gm_rng.integers(0, 8, blockers.size)

    def _insert(self, k: int, v: int, fresh: np.ndarray) -> None:
        self.in_s[k, v] = True
        self.conf[k] += self._row(v)
        self.size[k] += 1
        fresh[v] = True

    def _reinsert_cluster(self, k: int, ejected: list[int],
                          budget: int, fresh: np.ndarray) -> None:
        """Re-place the ejected cluster's ops atomically, most-
        constrained-first.  A free non-tabu candidate is taken outright;
        an op with none may recursively eject the blockers of its
        cheapest candidate (second ring — e.g. the foreign occupants of
        the row its re-placed VIO now pins it to) while ``budget`` ops
        remain, except placements made by this very kick (``fresh``),
        which are never undone.  Ops left unplaced when the budget runs
        out stay uncovered for the swap/add phases to resume on;
        independence is invariant throughout."""
        it = self.it
        pending = list(ejected)
        guard = 4 * self._gm.max_cluster
        while pending and guard > 0:
            guard -= 1
            counts = [int((self.conf[k, self._op_cands[p]] == 0).sum())
                      for p in pending]
            op = pending.pop(int(np.argmin(counts)))
            c = self._op_cands[op]
            ok = (self.conf[k, c] == 0) & ~self.in_s[k, c] & \
                (self.tabu[k, c] <= it)
            free = c[ok]
            if free.size:
                self._insert(
                    k, int(free[self._gm_rng.integers(0, free.size)]),
                    fresh)
                continue
            if budget <= 0:
                continue
            cand = c[self.tabu[k, c] <= it]
            if cand.size == 0:
                continue
            costs = self.conf[k, cand] + self._gm_rng.random(cand.size)
            for v in cand[np.argsort(costs, kind="stable")[:4]]:
                v = int(v)
                blockers = np.flatnonzero(self._row(v) & self.in_s[k])
                if blockers.size > budget or fresh[blockers].any():
                    continue
                self._eject(k, blockers)
                self._insert(k, v, fresh)
                pending.extend(np.unique(self._op_idx[blockers]).tolist())
                budget -= blockers.size
                break

    def _kick_seed(self, k: int, o: int, fresh: np.ndarray) -> bool:
        """Group-move on seed ``k`` for uncovered op ``o``: choose the
        candidate of ``o`` blocked by the fewest current placements
        (``conf`` *is* the blocker-op count — an independent set holds
        at most one vertex per op), eject **all** of its blockers — the
        conflict cluster, e.g. a stalled VIO's consumers astray on other
        rows — insert the candidate, and re-place the ejected ops around
        it (with bounded second-ring ejections; `_reinsert_cluster`).
        Placements made earlier in the same kick phase (``fresh``) are
        never ejected, so successive kicks compose instead of undoing
        each other.  Returns True when a move was applied."""
        gm = self._gm
        it = self.it
        c = self._op_cands[o]
        ok = self.tabu[k, c] <= it
        if not ok.any():
            return False
        cand = c[ok]
        costs = self.conf[k, cand] + self._gm_rng.random(cand.size)
        for v in cand[np.argsort(costs, kind="stable")[:6]]:
            v = int(v)
            if self.conf[k, v] == 0:
                # Free candidate: a plain add closes it, no ejection.
                self._insert(k, v, fresh)
                return True
            blockers = np.flatnonzero(self._row(v) & self.in_s[k])
            cluster = np.unique(self._op_idx[blockers])
            if cluster.size > gm.max_cluster or fresh[blockers].any():
                continue
            self._eject(k, blockers)
            self._insert(k, v, fresh)
            self._reinsert_cluster(k, cluster.tolist(),
                                   gm.max_cluster - cluster.size, fresh)
            return True
        return False

    def _uncovered(self, k: int) -> np.ndarray:
        members = np.flatnonzero(self.in_s[k])
        covered = np.zeros(self._n_ops, dtype=bool)
        covered[self._op_idx[members]] = True
        return np.flatnonzero(~covered)

    def _group_kick(self, target: int | None = None) -> None:
        """Clustered re-placement pass: per seed, kick *every* uncovered
        op once (in random order, including ops a second-ring ejection
        newly uncovers), with the phase's own insertions protected from
        ejection — so a coherent multi-group rebuild can reach full
        coverage atomically instead of being churned away by the swap
        iterations between two single-op kicks."""
        for k in range(self.k):
            if target is not None and self.best_size[k] >= target:
                continue
            if self.stall[k] * 2 < self._gm.cadence:
                # The swap phase is still making progress on this seed;
                # kicking now would pay the pass for nothing.
                continue
            queue = self._uncovered(k)
            if queue.size == 0:
                continue
            self._gm_rng.shuffle(queue)
            fresh = np.zeros(self.g.n, dtype=bool)
            kicked = np.zeros(self._n_ops, dtype=bool)
            queue = queue.tolist()
            while queue:
                o = queue.pop()
                if kicked[o]:
                    continue
                kicked[o] = True
                self._kick_seed(k, int(o), fresh)
                if not queue:
                    # Second-ring ejections may have uncovered new ops;
                    # give each one kick in the same pass.
                    queue = [o for o in self._uncovered(k)
                             if not kicked[o]]
            if self.size[k] > self.best_size[k]:
                self.best_size[k] = self.size[k]
                self.best[k] = self.in_s[k].copy()
                self.stall[k] = 0
        self._probe_adds = True

    def _rearm_cluster(self, k: int, members: np.ndarray) -> np.ndarray:
        """Cluster eviction for :meth:`rearm`: one random placement, a
        random alternative candidate of its op, and every placement
        blocking that alternative — the coupled group that has to move
        together for the re-placement to land anywhere new."""
        p = int(members[self._gm_rng.integers(0, members.size)])
        c = self._op_cands[self._op_idx[p]]
        v = int(c[self._gm_rng.integers(0, c.size)])
        blockers = np.flatnonzero(self._row(v) & self.in_s[k])
        cluster = np.union1d(np.unique(self._op_idx[blockers]),
                             [self._op_idx[p]])
        if cluster.size > self._gm.max_cluster:
            cluster = self._gm_rng.choice(
                cluster, size=self._gm.max_cluster, replace=False)
        return members[np.isin(self._op_idx[members], cluster)]


def solve_mis_portfolio(adj, *, inits, target: int | None = None,
                        max_iters: int = 20000, tenure: int = 7,
                        seed: int = 0) -> np.ndarray:
    """Run ``len(inits)`` independent SBTS seeds (``None`` entries start
    from the randomized greedy construction) and return the per-seed best
    memberships ``bool [K, n]``, early-exiting when any seed hits
    ``target``."""
    g = as_bitset_graph(adj)
    if g.n == 0:
        return np.zeros((max(len(inits), 1), 0), dtype=bool)
    sbts = PortfolioSBTS(g, inits, tenure=tenure, seed=seed)
    return sbts.run(max_iters, target=target)


def solve_mis(adj, *, target: int | None = None,
              max_iters: int = 20000, tenure: int = 7,
              seed: int = 0, init: np.ndarray | None = None) -> np.ndarray:
    """Return a boolean membership vector of an (approximately maximum)
    independent set of the conflict graph ``adj`` (dense bool matrix or
    BitsetGraph).  ``init`` may supply an independent set to warm-start
    from (e.g. the constructive placement)."""
    g = as_bitset_graph(adj)
    if g.n == 0:
        return np.zeros(0, dtype=bool)
    bests = solve_mis_portfolio(g, inits=[init], target=target,
                                max_iters=max_iters, tenure=tenure,
                                seed=seed)
    return bests[0]


def mis_indices(membership: np.ndarray) -> np.ndarray:
    return np.flatnonzero(membership)


def ejection_repair(adj, in_s: np.ndarray,
                    op_vertices: dict[int, list[int]],
                    op_of: np.ndarray, *, depth: int = 3,
                    seed: int = 0,
                    row_cache: np.ndarray | None = None) -> np.ndarray:
    """Ejection-chain repair: try to place every op that has no selected
    candidate by inserting one of its candidates, evicting the (≤2)
    conflicting members, and recursively re-placing the evicted ops'
    alternatives up to ``depth``.  Closes the 1–2-vertex shortfalls SBTS
    plateaus on for tightly-packed instances (e.g. BusMap C4K8).

    ``row_cache`` may supply the unpacked 0/1 adjacency (e.g. a
    PortfolioSBTS's cache) so repeated repair attempts on one graph
    don't each re-unpack it."""
    g = as_bitset_graph(adj)
    rng = np.random.default_rng(seed)
    in_s = in_s.copy()
    conf = g.conflict_counts(pack_bool(in_s))
    # Unpacked row cache: the chain search touches rows many times per
    # node, so pay one unpackbits for the whole graph up front.
    u8 = row_cache if row_cache is not None else (
        g.rows_u8(np.arange(g.n)) if g.n
        else np.zeros((0, 0), dtype=np.uint8))
    doms = {op: np.asarray(ids, dtype=np.int64)
            for op, ids in op_vertices.items()}
    banned = np.zeros(g.n, dtype=bool)
    nodes = [0]  # search-node budget (keeps worst-case bounded)

    def place(op: int, d: int) -> bool:
        nonlocal conf
        nodes[0] += 1
        if nodes[0] > 20000:
            return False
        # Batched candidate scoring over the row cache: one gather gives
        # every alive candidate's current conflict count; a random key
        # added before the stable argsort is the vectorised equivalent of
        # shuffle-then-sort (fewest evictions first, random tie-break).
        dom = doms[op]
        alive = dom[~(in_s[dom] | banned[dom])]
        if alive.size == 0:
            return False
        order = np.argsort(conf[alive] + rng.random(alive.size),
                           kind="stable")
        cands = alive[order]
        n_evict = conf[cands]
        for v, ne in zip(cands, n_evict):
            if ne == 0:
                in_s[v] = True
                conf += u8[v]
                return True
            if d == 0 or ne > 2:
                continue
            evict = np.flatnonzero(u8[v] & in_s)
            evicted_ops = [int(op_of[u]) for u in evict]
            # Snapshot: recursive placements mutate state and `all` short-
            # circuits, so restore wholesale on failure.
            in_s_snap, conf_snap = in_s.copy(), conf.copy()
            for u in evict:
                in_s[u] = False
                conf -= u8[u]
            in_s[v] = True
            conf += u8[v]
            banned[v] = True
            if all(place(eo, d - 1) for eo in evicted_ops):
                banned[v] = False
                return True
            banned[v] = False
            in_s[:] = in_s_snap
            conf = conf_snap
        return False

    placed_ops = {int(op_of[v]) for v in np.flatnonzero(in_s)}
    for op in op_vertices:
        if op not in placed_ops:
            if place(op, depth):
                placed_ops.add(op)
    assert not g.any_conflict(pack_bool(in_s)), "repair broke independence"
    return in_s
