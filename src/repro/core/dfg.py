"""Data-flow graph (DFG) abstraction for CGRA mapping.

D(V_D, E_D) with V_D = V_r (computing ops) ∪ V_s (virtual ops),
V_s = V_i (virtual input ops, VIO) ∪ V_o (virtual output ops, VOO).
Edges carry an iteration ``distance`` (0 = intra-iteration) so RecMII can be
computed for loop-carried dependencies (CnKm kernels are acyclic, distance 0).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable


class OpKind(enum.Enum):
    COMPUTE = "compute"   # V_r: executes on a PE
    VIN = "vin"           # V_i: virtual input operation (VIO), occupies IPORT
    VOUT = "vout"         # V_o: virtual output operation (VOO), occupies OPORT
    ROUTE = "route"       # routing operation inserted in phases 2/4 (occupies a PE)


@dataclasses.dataclass
class Op:
    op_id: int
    kind: OpKind
    name: str = ""
    latency: int = 1
    # For VIO clones created by bandwidth allocation (Fig. 2(c)(e)): clone
    # group id shared by all copies of the same datum.  -1 = not a clone.
    clone_of: int = -1

    def __hash__(self) -> int:
        return self.op_id

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Op({self.op_id},{self.kind.value},{self.name})"


@dataclasses.dataclass
class Edge:
    src: int
    dst: int
    distance: int = 0  # iteration distance for loop-carried deps


class DFG:
    """Mutable DFG.  Ops are indexed by integer id."""

    def __init__(self) -> None:
        self.ops: dict[int, Op] = {}
        self.edges: list[Edge] = []
        self._next_id = 0

    # ---------------------------------------------------------------- build
    def add_op(self, kind: OpKind, name: str = "", latency: int = 1,
               clone_of: int = -1) -> int:
        oid = self._next_id
        self._next_id += 1
        self.ops[oid] = Op(oid, kind, name or f"{kind.value}{oid}", latency,
                           clone_of)
        return oid

    def add_edge(self, src: int, dst: int, distance: int = 0) -> None:
        assert src in self.ops and dst in self.ops
        self.edges.append(Edge(src, dst, distance))

    def remove_edge(self, src: int, dst: int) -> None:
        self.edges = [e for e in self.edges if not (e.src == src and e.dst == dst)]

    # ---------------------------------------------------------------- views
    @property
    def v_r(self) -> list[int]:
        return [i for i, o in self.ops.items()
                if o.kind in (OpKind.COMPUTE, OpKind.ROUTE)]

    @property
    def v_i(self) -> list[int]:
        return [i for i, o in self.ops.items() if o.kind == OpKind.VIN]

    @property
    def v_o(self) -> list[int]:
        return [i for i, o in self.ops.items() if o.kind == OpKind.VOUT]

    @property
    def v_s(self) -> list[int]:
        return self.v_i + self.v_o

    def successors(self, oid: int) -> list[int]:
        return [e.dst for e in self.edges if e.src == oid]

    def predecessors(self, oid: int) -> list[int]:
        return [e.src for e in self.edges if e.dst == oid]

    def out_edges(self, oid: int) -> list[Edge]:
        return [e for e in self.edges if e.src == oid]

    def in_edges(self, oid: int) -> list[Edge]:
        return [e for e in self.edges if e.dst == oid]

    # ---------------------------------------------------------- reuse degree
    def rd(self, oid: int) -> int:
        """Spatial reuse degree RD(op) for op ∈ V_s.

        For a VIO it is the number of computing consumers that need the datum
        (the fan-out); for a VOO it is 1 (output data has no spatial reuse).
        """
        op = self.ops[oid]
        if op.kind == OpKind.VIN:
            return len(self.successors(oid))
        return 1

    # ------------------------------------------------------------- analysis
    def topo_order(self) -> list[int]:
        """Topological order ignoring loop-carried (distance>0) edges."""
        indeg = {i: 0 for i in self.ops}
        for e in self.edges:
            if e.distance == 0:
                indeg[e.dst] += 1
        ready = [i for i, d in indeg.items() if d == 0]
        order: list[int] = []
        while ready:
            n = ready.pop()
            order.append(n)
            for e in self.edges:
                if e.distance == 0 and e.src == n:
                    indeg[e.dst] -= 1
                    if indeg[e.dst] == 0:
                        ready.append(e.dst)
        if len(order) != len(self.ops):
            raise ValueError("DFG has an intra-iteration cycle")
        return order

    def heights(self) -> dict[int, int]:
        """Longest path (in latencies) from each op to any sink; scheduling
        priority."""
        h = {i: 0 for i in self.ops}
        for oid in reversed(self.topo_order()):
            succ = [e.dst for e in self.edges if e.src == oid and e.distance == 0]
            h[oid] = self.ops[oid].latency + (max((h[s] for s in succ), default=0))
        return h

    def rec_mii(self) -> int:
        """Recurrence-constrained MII = max over cycles of
        ceil(sum(latency)/sum(distance)).  Uses a simple DFS cycle
        enumeration; CnKm DFGs are acyclic so this is usually 1."""
        # Build adjacency incl. distances
        adj: dict[int, list[Edge]] = {i: [] for i in self.ops}
        for e in self.edges:
            adj[e.src].append(e)
        best = 1
        # Bounded cycle search (graphs here are small); detect back edges
        for start in self.ops:
            stack = [(start, 0, 0, {start})]
            while stack:
                node, lat, dist, seen = stack.pop()
                for e in adj[node]:
                    nl = lat + self.ops[node].latency
                    nd = dist + e.distance
                    if e.dst == start and nd > 0:
                        best = max(best, -(-nl // nd))
                    elif e.dst not in seen and len(seen) < 12:
                        stack.append((e.dst, nl, nd, seen | {e.dst}))
        return best

    def clone_vio(self, oid: int, consumers: Iterable[int]) -> int:
        """Create a VIO clone representing the same datum (Fig. 2(c)(e)) and
        move ``consumers`` onto it.  Each clone occupies its own port."""
        op = self.ops[oid]
        assert op.kind == OpKind.VIN
        group = op.clone_of if op.clone_of >= 0 else oid
        self.ops[oid].clone_of = group
        new = self.add_op(OpKind.VIN, f"{op.name}'", op.latency, clone_of=group)
        for c in list(consumers):
            # Preserve each edge's iteration distance: an inter-iteration
            # consumer stays inter-iteration on the clone's port.
            dists = [e.distance for e in self.edges
                     if e.src == oid and e.dst == c]
            self.remove_edge(oid, c)
            self.add_edge(new, c, distance=max(dists, default=0))
        return new

    def copy(self) -> "DFG":
        d = DFG()
        d.ops = {i: dataclasses.replace(o) for i, o in self.ops.items()}
        d.edges = [dataclasses.replace(e) for e in self.edges]
        d._next_id = self._next_id
        return d

    def __repr__(self) -> str:  # pragma: no cover
        return (f"DFG(|V_r|={len(self.v_r)}, |V_i|={len(self.v_i)}, "
                f"|V_o|={len(self.v_o)}, |E|={len(self.edges)})")
