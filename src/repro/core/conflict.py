"""Phase 3a: the mixed tuple/quadruple resource-occupation conflict graph
CG(V_C, E_C) (paper §III-B).

Vertices are *placement candidates*:

- tuples  (port_n^t, op_s^t)  for virtual ops: every (VIO, IPORT) and
  (VOO, OPORT) combination at the op's scheduled modulo slot;
- quadruples (pe_{i,j}^t, op_r^t, bus_{i,x}^t, bus_{j,y}^t) for computing and
  routing ops: every PE position (and, for routing ops, the bus scope the op
  re-drives: its row or its column).

Edges = resource-occupation conflicts, the paper's three rules:

1. tuple–tuple: two virtual ops on one port at the same modulo time, or one
   op on two ports (we encode the latter as the universal "same op twice"
   rule, which also makes MIS pick exactly one candidate per op; VIO clones
   created by bandwidth allocation are distinct ops, so multi-port binding
   stays conflict-free — exactly Fig. 2(c)(e));
2. tuple–quadruple: the port's hardwired bus is simultaneously re-driven for
   bus routing by a routing op, or the PE consuming (producing) the tuple's
   datum is not attached to a bus the port drives (row mismatch for VIOs,
   column mismatch for VOOs);
3. quadruple–quadruple: two ops on one PE instance, one op on two PEs, bus
   driver clashes, or an unroutable dependency (producer/consumer neither
   co-located nor sharing a row/column).

Flexible bus-index assignment (which of the two row/column buses carries a
PE→PE transfer, and in which cycle) is resolved after MIS by the validator
(`validate.py`) — a pairwise conflict graph cannot express those capacity-2
constraints exactly; the paper's phase-4 retry loop covers the same gap.

`bus_pressure_edges` (flag-gated in :func:`build_conflict_graph`, enabled
by the `bandmap.map_dfg` pipeline) folds the *provable* part of that
validator structure back into the pairwise graph: schedule-level facts pin
some bus cells as occupied in **every** complete placement (all input
ports bus-driven at a slot ⇒ every IBUS_r bus 0 taken; all output ports
exporting at a slot ⇒ every OBUS_c bus 0 taken), and a routing op with a
consumer scheduled in its own modulo slot can never co-locate with that
consumer, so it must drive its bus within a schedule-fixed window.  When
the surviving (bus, cycle) cells for such a forced drive are exhausted or
collapse to a single cell contested by another forced driver, the
corresponding pair is infeasible in every complete placement and becomes a
regular conflict edge — SBTS stops proposing placements `_assign_buses`
is guaranteed to reject, without ever excluding a validatable placement.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bitset import BitsetGraph
from .cgra import CGRAConfig
from .dfg import OpKind
from .schedule import ScheduledDFG
from .tec import COL, ROW

TIN, TOUT, QUAD = "tin", "tout", "quad"


@dataclasses.dataclass(frozen=True)
class Vertex:
    idx: int
    op: int
    kind: str                      # tin | tout | quad
    t: int                         # scheduled time
    m: int                         # modulo slot
    port: int = -1                 # tin: row; tout: col
    mode: str = ""                 # tin: 'bus' | 'grf'
    pe: tuple[int, int] = (-1, -1)
    drive: tuple[str, int] | None = None  # routing ops: (ROW,r) or (COL,c)


@dataclasses.dataclass
class ConflictGraph:
    vertices: list[Vertex]
    bits: BitsetGraph              # packed adjacency, uint64 [n, words]
    op_vertices: dict[int, list[int]]
    n_ops: int
    _adj: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _u8_cache: np.ndarray | None = dataclasses.field(default=None,
                                                     repr=False)

    @property
    def n(self) -> int:
        return len(self.vertices)

    @property
    def n_edges(self) -> int:
        return self.bits.n_edges

    @property
    def adj(self) -> np.ndarray:
        """Dense bool view, materialised on first use (oracle/debug paths
        only — the solver operates on ``bits``)."""
        if self._adj is None:
            self._adj = self.bits.to_dense()
        return self._adj

    @property
    def op_of(self) -> np.ndarray:
        """Vertex -> op id, ``int64 [n]`` (what the portfolio's group
        moves and the repair pass key their clusters on)."""
        return np.fromiter((v.op for v in self.vertices),
                           dtype=np.int64, count=self.n)

    def row_cache(self, limit: int | None = None) -> np.ndarray | None:
        """Memoized unpacked 0/1 adjacency ``uint8 [n, n]``, shared by
        the certificate search, every portfolio construction and the
        repair retries over this graph — one unpackbits per conflict
        graph instead of one per consumer (the PR 8-traced
        portfolio-init hotspot on 16x16 fabrics).  Returns None when
        the dense cache would exceed ``limit`` bytes (pass the
        engine's ``row_cache_limit``); ``limit=None`` always
        materialises."""
        if self._u8_cache is None:
            if limit is not None and not 0 < self.n * self.n <= limit:
                return None
            self._u8_cache = self.bits.rows_u8(np.arange(self.n))
        return self._u8_cache


def _occupancy(v: Vertex, ii: int) -> list[tuple]:
    """Unconditional resource instances occupied by a candidate."""
    occ: list[tuple] = []
    if v.kind == TIN:
        occ.append(("iport", v.port, v.m))
        if v.mode == "bus":
            # IPORT_r drives IBUS_r = (ROW, r, 0) at the delivery slot.
            occ.append(("bus", ROW, v.port, 0, v.m))
    elif v.kind == TOUT:
        occ.append(("oport", v.port, v.m))
        # The export drive occupies OBUS_c = (COL, c, 0) at the VOO's slot.
        occ.append(("bus", COL, v.port, 0, v.m))
    else:
        occ.append(("pe", v.pe, v.m))
    return occ


def _dep_ok(prod: Vertex, cons: Vertex) -> bool:
    """Relational realizability of DFG edge prod.op -> cons.op under the two
    placements (single-hop; multi-hop paths exist only through explicit
    routing ops)."""
    if prod.kind == TIN:
        if prod.mode == "grf":
            return True  # GRF is readable by all PEs
        # Bus delivery: the consumer PE must sit on the port's row.
        return cons.pe[0] == prod.port
    if cons.kind == TOUT:
        # Producer drives OBUS_c: must sit on the OPORT's column.
        return prod.pe[1] == cons.port
    # quad -> quad
    if prod.drive is not None:
        scope, idx = prod.drive
        if scope == ROW:
            return cons.pe == prod.pe or cons.pe[0] == idx
        return cons.pe == prod.pe or cons.pe[1] == idx
    # plain compute producer: same PE (LRF), same row or same column (bus).
    return (cons.pe == prod.pe or cons.pe[0] == prod.pe[0]
            or cons.pe[1] == prod.pe[1])


def build_conflict_graph(sched: ScheduledDFG, cgra: CGRAConfig,
                         use_kernel: bool | str = False,
                         bus_pressure: bool = False,
                         tracer=None) -> ConflictGraph:
    """Build the mixed conflict graph.  With ``bus_pressure=False``
    (default) the adjacency is byte-identical to the seed formulation
    (`dense_conflicts_python` + `_dep_ok`); ``bus_pressure=True``
    additionally folds the provable bus-capacity structure in via
    :func:`bus_pressure_edges` (the pipeline default — see map_dfg).

    ``use_kernel`` selects the occupancy/clique formulation: False =
    packed bitset rows on the host (default), True = the dense-bool
    conflict-matrix kernel, "packed" = the packed-word variant's host
    oracle (dense ref + pack), "packed-pallas" = the packed-word Pallas
    kernel whose uint64 rows feed `BitsetGraph` directly — the TPU
    offload path with no python pack step (requires a TPU backend; the
    interpret-mode equivalence lives in tests/test_kernels.py).

    ``tracer`` (default None) records the build as a "conflict-build"
    span; the edge popcount for the span attrs is only paid on a live
    tracer."""
    from repro.obs.trace import live
    with live(tracer).span("conflict-build", ii=sched.ii) as sp:
        cg = _build_conflict_graph(sched, cgra, use_kernel, bus_pressure)
        if tracer is not None:
            sp.set(n_vertices=cg.n,
                   n_edges=int(np.bitwise_count(cg.bits.rows).sum()) // 2)
        return cg


def _build_conflict_graph(sched: ScheduledDFG, cgra: CGRAConfig,
                          use_kernel: bool | str = False,
                          bus_pressure: bool = False) -> ConflictGraph:
    dfg, ii = sched.dfg, sched.ii
    vertices: list[Vertex] = []
    op_vertices: dict[int, list[int]] = {}

    def add(v: Vertex) -> None:
        op_vertices.setdefault(v.op, []).append(v.idx)
        vertices.append(v)

    for oid, op in dfg.ops.items():
        t = sched.time[oid]
        m = t % ii
        if op.kind == OpKind.VIN:
            mode = sched.delivery.get(oid, "bus")
            for r in range(cgra.rows):
                add(Vertex(len(vertices), oid, TIN, t, m, port=r, mode=mode))
        elif op.kind == OpKind.VOUT:
            for c in range(cgra.cols):
                add(Vertex(len(vertices), oid, TOUT, t, m, port=c))
        elif op.kind == OpKind.ROUTE:
            for r in range(cgra.rows):
                for c in range(cgra.cols):
                    add(Vertex(len(vertices), oid, QUAD, t, m, pe=(r, c),
                               drive=(ROW, r)))
                    add(Vertex(len(vertices), oid, QUAD, t, m, pe=(r, c),
                               drive=(COL, c)))
        else:
            for r in range(cgra.rows):
                for c in range(cgra.cols):
                    add(Vertex(len(vertices), oid, QUAD, t, m, pe=(r, c)))

    # Group part (per-op cliques + occupancy clashes), emitted as packed
    # bitset rows directly: each group is one row-OR of its member mask,
    # never touching an n² bool matrix.  `dense_conflicts_python` below is
    # kept as the loop oracle for the equivalence tests; the tiled
    # conflict-matrix kernel (kernels/conflict_matrix, Pallas) is the
    # TPU-offload formulation of the same rules, proven equal in
    # tests/test_bandmap_core.py and test_kernels.py.
    if use_kernel in ("packed", "packed-pallas"):
        from repro.kernels.conflict_matrix.ops import conflict_matrix_packed
        bits = BitsetGraph(len(vertices))
        bits.rows = conflict_matrix_packed(
            vertices, use_pallas=use_kernel == "packed-pallas")
    elif use_kernel:
        from repro.kernels.conflict_matrix.ops import conflict_matrix
        bits = BitsetGraph.from_dense(np.asarray(conflict_matrix(vertices)))
    else:
        bits = bitset_group_conflicts(vertices, op_vertices, ii)

    # Routing ops re-driving IBUS_r clash with any port tuple on IBUS_r at
    # the same slot (edge rule 2, first clause).  A route with drive (ROW, r)
    # *may* use either row bus; only the pairing with (ROW, r, 0) while the
    # port tuple holds it is forbidden when the route's row routing bus is
    # also taken — that capacity split is validated post-MIS.  Here we only
    # forbid the guaranteed clash: two routing ops driving the same scope at
    # the same slot PLUS a port tuple would exceed the two buses; pairwise we
    # encode the port-vs-route clash only when both demand the same single
    # remaining bus, which cannot be decided pairwise — so it is left to the
    # validator by design.

    # Dependency realizability (rules 2b and 3b), vectorised per DFG edge
    # over the producer x consumer candidate block.
    _add_dep_conflicts(bits, vertices, op_vertices, dfg)

    if bus_pressure:
        bus_pressure_edges(bits, vertices, op_vertices, sched, cgra)

    return ConflictGraph(vertices, bits, op_vertices, len(dfg.ops))


def _forced_drive_slots(sched, oid: int, m: int) -> list[int] | None:
    """Modulo slots available to the mandatory bus drive of routing op
    ``oid`` (scheduled in slot ``m``), or ``None`` when no drive is
    provably required.

    A consumer scheduled in the same modulo slot can never share the
    route's PE (PE-instance occupancy), and routed producers reach
    non-co-located consumers only over their driven bus (no neighbour
    link), so at least one drive is forced.  Per-edge drive windows are
    schedule-fixed ([ready, use] clipped to one II) and all start at the
    route's ready cycle, so the nested windows always share a stab cycle:
    one broadcast drive inside the intersection serves every forced
    listener — the forced demand is exactly one drive in the slots of
    ``[t_ready, min over forced edges of window-end]``."""
    dfg, ii = sched.dfg, sched.ii
    t_ready = sched.time[oid] + dfg.ops[oid].latency
    hi = None
    for e in dfg.out_edges(oid):
        if dfg.ops[e.dst].kind == OpKind.VOUT:
            continue  # exports ride the VOO's own fixed OBUS drive
        t_use = sched.time[e.dst] + e.distance * ii
        if t_use % ii != m or t_use < t_ready:
            continue
        end = min(t_use, t_ready + ii - 1)
        hi = end if hi is None else min(hi, end)
    if hi is None:
        return None
    return sorted({t % ii for t in range(t_ready, hi + 1)})


def bus_pressure_edges(bits: BitsetGraph, vertices, op_vertices,
                       sched: ScheduledDFG, cgra: CGRAConfig) -> int:
    """Fold the provable bus-capacity structure into the pairwise graph.

    Every added edge is *sound with respect to complete placements*: if
    both endpoints are selected and every op receives some placement, the
    validator's `_assign_buses` is guaranteed to fail.  Three ingredients:

    1. **Saturated cells.**  If every input port at slot ``m`` carries a
       bus-mode VIO, the ports cover all rows, so every ``(ROW, r, 0, m)``
       cell is driven in any complete placement; likewise all VOO exports
       at a slot saturate ``(COL, c, 0, m)`` for every column.
    2. **Forced drives.**  A routing-op vertex whose op has a consumer in
       its own modulo slot must place one broadcast drive in a
       schedule-fixed window (see `_forced_drive_slots`).
    3. **Cell exhaustion.**  Subtracting (1) from a forced drive's
       ``buses_per_scope × window`` cell grid leaves its feasible cells.
       No cell left ⇒ the route vertex is infeasible against *every*
       candidate of its same-slot consumers (they can never co-locate).
       Exactly one cell left ⇒ two such vertices of different ops pinned
       to the same cell (or a port tuple hard-wired to it) are mutually
       exclusive — drives of distinct producers never share a
       (bus, cycle).

    Returns the number of vertex pairs added (0 when the schedule has no
    provable pressure — the common case on loose instances, where the
    graph stays byte-identical to the oracle rules).
    """
    dfg, ii = sched.dfg, sched.ii
    n_buses = cgra.buses_per_scope

    # --- 1. schedule-level saturation of the hardwired bus-0 cells ----
    vin_bus = [0] * ii
    vout = [0] * ii
    for oid, op in dfg.ops.items():
        m = sched.time[oid] % ii
        if op.kind == OpKind.VIN and sched.delivery.get(oid, "bus") == "bus":
            vin_bus[m] += 1
        elif op.kind == OpKind.VOUT:
            vout[m] += 1
    sat = {ROW: [vin_bus[m] >= cgra.rows for m in range(ii)],
           COL: [vout[m] >= cgra.cols for m in range(ii)]}

    # --- 2. forced drives per routing op --------------------------------
    forced_slots: dict[int, list[int]] = {}
    forced_consumers: dict[int, list[int]] = {}
    for oid, op in dfg.ops.items():
        if op.kind != OpKind.ROUTE:
            continue
        m = sched.time[oid] % ii
        slots = _forced_drive_slots(sched, oid, m)
        if slots is None:
            continue
        forced_slots[oid] = slots
        forced_consumers[oid] = [
            e.dst for e in dfg.out_edges(oid)
            if dfg.ops[e.dst].kind != OpKind.VOUT
            and (sched.time[e.dst] + e.distance * ii) % ii == m]

    # --- 3. cell exhaustion ---------------------------------------------
    n_pairs = 0
    pinned: dict[tuple, list[int]] = {}   # (scope, idx, bus, slot) -> verts
    dead: list[tuple[int, int]] = []      # (vertex, doomed consumer op)
    for oid, slots in forced_slots.items():
        for vi in op_vertices[oid]:
            v = vertices[vi]
            if v.drive is None:
                continue
            scope, idx = v.drive
            cells = [(k, s) for k in range(n_buses) for s in slots
                     if not (k == 0 and sat[scope][s])]
            if not cells:
                dead.extend((vi, c) for c in forced_consumers[oid])
            elif len(cells) == 1:
                k, s = cells[0]
                pinned.setdefault((scope, idx, k, s), []).append(vi)

    if dead:
        src = []
        dst = []
        for vi, cons_op in dead:
            for wj in op_vertices[cons_op]:
                src.append(vi)
                dst.append(wj)
        bits.add_edges(np.asarray(src), np.asarray(dst))
        n_pairs += len(src)

    # Port tuples hard-wired to a contested cell (only reachable when
    # buses_per_scope == 1, but kept general).
    fixed_cell: dict[tuple, list[int]] = {}
    for v in vertices:
        if v.kind == TIN and v.mode == "bus":
            fixed_cell.setdefault((ROW, v.port, 0, v.m), []).append(v.idx)
        elif v.kind == TOUT:
            fixed_cell.setdefault((COL, v.port, 0, v.m), []).append(v.idx)

    cliques = []
    for cell, vis in pinned.items():
        group = vis + fixed_cell.get(cell, [])
        ops_in = {vertices[i].op for i in group}
        if len(ops_in) > 1:
            cliques.append(group)
            n_pairs += len(group) * (len(group) - 1) // 2
    for group in cliques:
        bits.add_clique(group)
    if cliques:
        bits.clear_diagonal()
    return n_pairs


def bitset_group_conflicts(vertices, op_vertices, ii: int) -> BitsetGraph:
    """Per-op cliques + resource-occupancy cliques as packed rows.

    Occupancy groups include same-op pairs that `dense_conflicts_python`
    skips, but those pairs are already edges of the op's clique, so the
    union is byte-identical to the oracle.
    """
    g = BitsetGraph(len(vertices))
    for ids in op_vertices.values():
        g.add_clique(ids)
    by_res: dict[tuple, list[int]] = {}
    for v in vertices:
        for res in _occupancy(v, ii):
            by_res.setdefault(res, []).append(v.idx)
    for ids in by_res.values():
        g.add_clique(ids)
    g.clear_diagonal()
    return g


def _vertex_attrs(vertices) -> dict[str, np.ndarray]:
    """Columnar vertex attributes for the vectorised `_dep_ok` block."""
    n = len(vertices)
    kind = np.empty(n, np.int8)        # 0 = tin, 1 = tout, 2 = quad
    port = np.empty(n, np.int32)
    grf = np.empty(n, bool)
    pe_r = np.empty(n, np.int32)
    pe_c = np.empty(n, np.int32)
    drv = np.empty(n, np.int8)         # -1 = none, 0 = ROW, 1 = COL
    drv_idx = np.empty(n, np.int32)
    code = {TIN: 0, TOUT: 1, QUAD: 2}
    for i, v in enumerate(vertices):
        kind[i] = code[v.kind]
        port[i] = v.port
        grf[i] = v.mode == "grf"
        pe_r[i], pe_c[i] = v.pe
        if v.drive is None:
            drv[i], drv_idx[i] = -1, -1
        else:
            drv[i] = 0 if v.drive[0] == ROW else 1
            drv_idx[i] = v.drive[1]
    return dict(kind=kind, port=port, grf=grf, pe_r=pe_r, pe_c=pe_c,
                drv=drv, drv_idx=drv_idx)


def _dep_ok_block(at: dict[str, np.ndarray], prod: np.ndarray,
                  cons: np.ndarray) -> np.ndarray:
    """Vectorised `_dep_ok` over the |prod| x |cons| candidate block."""
    pi = {k: v[prod][:, None] for k, v in at.items()}
    cj = {k: v[cons][None, :] for k, v in at.items()}
    same_pe = (pi["pe_r"] == cj["pe_r"]) & (pi["pe_c"] == cj["pe_c"])
    drive_ok = same_pe | np.where(pi["drv"] == 0,
                                  cj["pe_r"] == pi["drv_idx"],
                                  cj["pe_c"] == pi["drv_idx"])
    plain_ok = (pi["pe_r"] == cj["pe_r"]) | (pi["pe_c"] == cj["pe_c"])
    quad_ok = np.where(pi["drv"] >= 0, drive_ok, plain_ok)
    tin_ok = pi["grf"] | (cj["pe_r"] == pi["port"])
    tout_ok = pi["pe_c"] == cj["port"]
    return np.where(pi["kind"] == 0, tin_ok,
                    np.where(cj["kind"] == 1, tout_ok, quad_ok))


def _add_dep_conflicts(bits: BitsetGraph, vertices, op_vertices,
                       dfg) -> None:
    at = _vertex_attrs(vertices)
    dep_pairs = {(e.src, e.dst) for e in dfg.edges}
    for src, dst in dep_pairs:
        prod = np.asarray(op_vertices[src], dtype=np.int64)
        cons = np.asarray(op_vertices[dst], dtype=np.int64)
        bad_i, bad_j = np.nonzero(~_dep_ok_block(at, prod, cons))
        if bad_i.size:
            bits.add_edges(prod[bad_i], cons[bad_j])


def dense_conflicts_python(vertices, op_vertices, ii: int) -> np.ndarray:
    """Reference python-loop formulation of the dense conflict rules
    (per-op cliques + occupancy) — oracle for the bitset/kernel
    equivalence tests; build_conflict_graph emits packed bitset rows."""
    n = len(vertices)
    adj = np.zeros((n, n), dtype=bool)

    def connect(i, j):
        adj[i, j] = True
        adj[j, i] = True

    for ids in op_vertices.values():
        for a in range(len(ids)):
            for b in range(a + 1, len(ids)):
                connect(ids[a], ids[b])
    by_res: dict[tuple, list[int]] = {}
    for v in vertices:
        for res in _occupancy(v, ii):
            by_res.setdefault(res, []).append(v.idx)
    for ids in by_res.values():
        for a in range(len(ids)):
            va = vertices[ids[a]]
            for b in range(a + 1, len(ids)):
                vb = vertices[ids[b]]
                if va.op != vb.op:
                    connect(ids[a], ids[b])
    return adj


def constructive_init(cg: ConflictGraph, sched: ScheduledDFG,
                      cgra: CGRAConfig, seed: int = 0) -> np.ndarray:
    """Structure-aware greedy placement used to warm-start SBTS.

    Ops are placed in scheduled-time order (VIOs before same-time compute).
    Quad candidates are scored by affinity to already-placed predecessors
    AND successors: same PE (LRF forward) > NSEW neighbour (dedicated link)
    > same column > same row (bus hop, capacity-limited) > disconnected.
    VIO rows are scored by how well their consumers can extend the placed
    chain predecessors (adjacent rows preferred).  Only conflict-free picks
    are kept, so the result is an independent set SBTS can repair/extend.
    """
    rng = np.random.default_rng(seed)
    dfg = sched.dfg
    in_s = np.zeros(cg.n, dtype=bool)
    conf = np.zeros(cg.n, dtype=np.int64)
    placed: dict[int, Vertex] = {}

    def pe_affinity(v_pe, o_pe) -> float:
        if v_pe == o_pe:
            return 0.0
        dr, dc = abs(v_pe[0] - o_pe[0]), abs(v_pe[1] - o_pe[1])
        if dr + dc == 1:
            return 0.5                       # neighbour link, bus-free
        if dc == 0:
            return 1.0                       # column bus
        if dr == 0:
            return 2.0                       # row bus
        return 4.0

    def bias_for(oid: int):
        nbrs = [placed[p] for p in
                (dfg.predecessors(oid) + dfg.successors(oid)) if p in placed]
        quads = [p for p in nbrs if p.kind == QUAD]
        kind = dfg.ops[oid].kind

        def bias(v: Vertex) -> float:
            if v.kind == TIN:
                # Row scored by adjacency of the VIO's consumers' chain
                # predecessors: a consumer extending a chain at row r wants
                # delivery on r (same PE/LRF) or r±1 (neighbour link).
                score = 0.0
                for c in dfg.successors(oid):
                    best = 0.5
                    for p in dfg.predecessors(c):
                        if p != oid and p in placed and \
                                placed[p].kind == QUAD:
                            d = abs(placed[p].pe[0] - v.port)
                            best = min(best, 0.0 if d <= 1 else float(d))
                    score += best
                return score
            if v.kind == TOUT:
                # Column forced to the producer by _dep_ok; neutral here.
                return 0.0
            if not quads:
                return 0.0
            return sum(pe_affinity(v.pe, p.pe) for p in quads) / len(quads)
        return bias

    order = sorted(dfg.ops, key=lambda o: (sched.time[o],
                                           dfg.ops[o].kind != OpKind.VIN))
    for oid in order:
        cands = [i for i in cg.op_vertices[oid] if conf[i] == 0]
        if not cands:
            continue
        bias = bias_for(oid)
        scored = [bias(cg.vertices[i]) + 1e-3 * rng.random() for i in cands]
        best = cands[int(np.argmin(scored))]
        in_s[best] = True
        conf += cg.bits.row_u8(best)
        placed[oid] = cg.vertices[best]
    return in_s
