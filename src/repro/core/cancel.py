"""Cooperative cancellation for racing mapping backends.

`CancelToken` is the one primitive the exact-vs-portfolio race
(`repro.exact.race`) threads through the engine: `map_dfg` checks it
between (II, jitter) combinations and harvest rounds,
`PortfolioSBTS.run` checks it once per lock-step iteration, and the
exact CSP (`certify._search_complete`) checks it every few dozen
search nodes.  Cancellation is *cooperative and loss-free*: a
cancelled solver stops at the next checkpoint and returns whatever it
has (an ``ok=False`` result, never a partial claim of proof), so the
race can discard the loser without waiting out its budget.

Tokens chain: a child token with a ``parent`` reports cancelled when
either itself or the parent is cancelled.  The race gives each
competitor its own child of the caller's token — the winner cancels
only its rival, while the caller can still cancel the whole race.
"""

from __future__ import annotations

import threading


class CancelToken:
    """Thread-safe cancellation flag (see module docstring)."""

    def __init__(self, parent: "CancelToken | None" = None) -> None:
        self._ev = threading.Event()
        self._parent = parent

    def cancel(self) -> None:
        self._ev.set()

    # threading.Event-compatible alias.
    set = cancel

    def is_set(self) -> bool:
        return self._ev.is_set() or (self._parent is not None
                                     and self._parent.is_set())
