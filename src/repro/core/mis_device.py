"""Accelerator-resident SBTS portfolio: K lock-step tabu trajectories
as one jitted jax program over packed adjacency rows.

`DeviceSBTS` is the ``engine="device"`` counterpart of
`mis.PortfolioSBTS` (which stays the oracle — see
`differential_vs_numpy` and tests/test_mis_device.py).  The numpy
engine advances ~20 seeds per core under the GIL; here the whole
``[K, n]`` state lives on the accelerator and a single compiled chunk
advances every trajectory:

- **Conflict-count evaluation** runs on packed uint32 adjacency rows
  (`BitsetGraph.rows_u32`) through the `kernels.sbts_step` Pallas
  kernel: one AND+popcount contraction yields |N(v) ∩ S_k| for every
  (trajectory, vertex) pair.  Interpret mode (CPU CI) traces the same
  kernel through XLA, so the compiled path is exercised end to end.
- **The per-seed step** (`_seed_step` below) is a pure jittable
  function of one trajectory's slice — tabu-guarded add/swap selection
  and plateau perturbation — ``vmap``ped over the K seeds; steps are
  chained with `lax.fori_loop` into chunks so host round-trips happen
  every ``chunk`` iterations, not every iteration.
- **Counter-based RNG**: every random draw derives from
  ``fold_in(fold_in(fold_in(base_key, seed_idx), it), channel)`` — a
  pure function of (seed, trajectory, iteration), replacing the numpy
  engine's stateful per-seed `np.random` streams.  Trajectories are
  therefore reproducible run-to-run and resume-safe: advancing 30+34
  iterations equals advancing 64 (asserted in the tests).

Step semantics (one lock-step iteration, all seeds)
---------------------------------------------------
With ``conf[v] = |N(v) ∩ S|``:

1. *Add phase* (taken whenever any vertex is addable: ``conf == 0``,
   not selected, not tabu).  All "safe" addables (no addable
   neighbour at all) enter at once; the remaining clustered addables
   enter via a degree-aware Luby round — each samples itself with
   probability 1/(1+addable-degree) and the sampled vertices with no
   sampled neighbour enter together (provably independent: a safe
   vertex has no addable neighbour, a winner no sampled one, and
   every addable has ``conf == 0`` against S).  If both sets come up
   empty, the top-priority clustered addable enters alone, so an add
   phase always makes progress.
2. *Swap phase* (no addable vertex): the top-priority vertex with
   ``conf == 1`` and an expired tabu replaces its unique selected
   neighbour, which becomes tabu for ``tenure + U{0..3}`` iterations.
3. *Plateau perturbation*: a trajectory whose best has not improved
   for ``thresh`` iterations evicts a random ~10% slice of its
   selection (tabu'd on the way out) and re-draws ``thresh``.

`map_dfg(engine="device")` harvests the top-scoring device seeds into
the same dedupe → repair → validate loop the numpy engine feeds, under
a "portfolio-device" span (`repro.obs.PHASES`).
"""

from __future__ import annotations

import functools

import numpy as np

from .bitset import BitsetGraph

_LANE = 128          # pad n to a multiple of this (fewer jit shapes,
#                    # device-lane friendly); always a multiple of 32.
_PERTURB_FRAC = 0.1  # eviction probability per member on a plateau


def _pad_n(n: int) -> int:
    return max(_LANE, -(-n // _LANE) * _LANE)


def _build_chunk(n: int, n_pad: int, k: int, tenure: int, seed: int,
                 block_n: int, block_k: int, interpret: bool):
    """Compile-time closure: returns the jitted chunk advancer
    ``(rows32, state, it0, n_steps) -> state`` with ``n_steps``
    static.  ``state`` is the tuple (in_s, tabu, stall, thresh, best,
    best_size) of device arrays."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.sbts_step.kernel import selection_counts_pallas

    w = n_pad // 32
    base_key = jax.random.PRNGKey(seed)
    valid = jnp.arange(n_pad) < n
    bit_w = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))

    def pack(bits):
        """bool [K, n_pad] -> packed uint32 [K, W] (little-endian)."""
        return (bits.reshape(k, w, 32).astype(jnp.uint32) * bit_w).sum(
            axis=-1, dtype=jnp.uint32)

    def counts(rows32, bits):
        return selection_counts_pallas(
            rows32, pack(bits), block_n=block_n, block_k=block_k,
            interpret=interpret)

    def unpack_row(words):
        """uint32 [W] -> bool [n_pad]."""
        return ((words[:, None] >> jnp.arange(32, dtype=jnp.uint32))
                & jnp.uint32(1)).astype(bool).reshape(n_pad)

    def draws(it):
        """Counter-based per-(seed, iteration) randomness."""
        def one(sid):
            kit = jax.random.fold_in(
                jax.random.fold_in(base_key, sid), it)
            r1 = jax.random.uniform(jax.random.fold_in(kit, 0), (n_pad,))
            r2 = jax.random.uniform(jax.random.fold_in(kit, 1), (n_pad,))
            j4 = jax.random.randint(jax.random.fold_in(kit, 2), (), 0, 4)
            dth = jax.random.randint(
                jax.random.fold_in(kit, 3), (), 0, 24)
            return r1, r2, j4, dth
        return jax.vmap(one)(jnp.arange(k))

    def _seed_step(rows32, it, in_s, tabu, stall, thresh, best,
                   best_size, conf, aconf, samp, sconf, r1, r2, j4,
                   dth):
        """One trajectory's add/swap/perturb update (vmapped over K)."""
        addable = valid & ~in_s & (conf == 0) & (tabu <= it)
        any_add = addable.any()
        # ---- add phase: safe set + Luby winners (+ forced fallback)
        safe = addable & (aconf == 0)
        winners = samp & (sconf == 0)
        clustered = addable & ~safe
        v_add = jnp.argmax(jnp.where(clustered, r1, -1.0))
        force = clustered.any() & ~safe.any() & ~winners.any()
        add_mask = safe | winners
        add_mask = add_mask.at[v_add].set(add_mask[v_add] | force)
        in_s_add = in_s | add_mask
        # ---- swap phase: conf==1 vertex in, its unique neighbour out
        swapable = valid & ~in_s & (conf == 1) & (tabu <= it)
        r_swap = jnp.where(swapable, r1, -1.0)
        v_swap = jnp.argmax(r_swap)
        has_swap = r_swap[v_swap] > 0.0
        row_v = unpack_row(rows32[v_swap])
        u_out = jnp.argmax(row_v & in_s)
        in_s_swap = jnp.where(
            has_swap, in_s.at[u_out].set(False).at[v_swap].set(True),
            in_s)
        tabu_swap = jnp.where(
            has_swap, tabu.at[u_out].set(it + tenure + j4), tabu)
        stall_swap = stall + jnp.where(has_swap, 1, 3)
        # ---- pick the phase, update the best
        in_s2 = jnp.where(any_add, in_s_add, in_s_swap)
        tabu2 = jnp.where(any_add, tabu, tabu_swap)
        stall2 = jnp.where(any_add, stall, stall_swap)
        size2 = in_s2.sum()
        better = size2 > best_size
        best2 = jnp.where(better, in_s2, best)
        bsz2 = jnp.maximum(best_size, size2)
        stall3 = jnp.where(better, 0, stall2)
        # ---- plateau perturbation
        pert = stall3 >= thresh
        evict = in_s2 & (r2 < _PERTURB_FRAC)
        evict = evict.at[jnp.argmax(jnp.where(in_s2, r2, -1.0))].set(
            in_s2.any())
        in_s3 = jnp.where(pert, in_s2 & ~evict, in_s2)
        tabu3 = jnp.where(pert,
                          jnp.where(evict, it + tenure + j4, tabu2),
                          tabu2)
        stall4 = jnp.where(pert, 0, stall3)
        thresh2 = jnp.where(pert, 60 + dth, thresh)
        return in_s3, tabu3, stall4, thresh2, best2, bsz2

    vstep = jax.vmap(
        _seed_step,
        in_axes=(None, None) + (0,) * 14)

    def lockstep(rows32, state, it):
        in_s, tabu, stall, thresh, best, best_size = state
        r1, r2, j4, dth = draws(it)
        conf = counts(rows32, in_s)
        addable = valid[None] & ~in_s & (conf == 0) & (tabu <= it)
        aconf = counts(rows32, addable)
        samp = addable & (aconf > 0) \
            & (r1 < 1.0 / (1.0 + aconf.astype(jnp.float32)))
        sconf = counts(rows32, samp)
        return vstep(rows32, it, in_s, tabu, stall, thresh, best,
                     best_size, conf, aconf, samp, sconf, r1, r2, j4,
                     dth)

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def chunk(rows32, state, it0, n_steps: int):
        def body(i, st):
            return lockstep(rows32, st, it0 + i)
        return jax.lax.fori_loop(0, n_steps, body, state)

    return chunk


class DeviceSBTS:
    """Device-resident drop-in for the `PortfolioSBTS` harvest-loop
    surface: ``run`` / ``best`` / ``best_size`` / ``it`` / ``rearm`` /
    ``reset_seed``.  ``interpret=None`` auto-selects interpret mode on
    CPU backends (the CI-validated path) and compiled Pallas
    elsewhere.  ``inits`` entries must be independent sets (e.g.
    `conflict.constructive_init` results); ``None`` entries and the
    seeds beyond ``len(inits)`` start cold — the add phase doubles as
    a randomized greedy construction, so cold seeds are cheap."""

    def __init__(self, g: BitsetGraph, inits=None, *, k: int = 1024,
                 tenure: int = 7, seed: int = 0,
                 interpret: bool | None = None, chunk: int = 64,
                 block_n: int = 1024, block_k: int = 8):
        if interpret is None:
            import jax
            interpret = jax.default_backend() == "cpu"
        self.g = g
        n = g.n
        self.k = int(max(k, len(inits) if inits else 0))
        self.tenure = int(tenure)
        self.seed = int(seed)
        self.chunk_size = int(chunk)
        self.it = 0
        self._n_pad = _pad_n(n)
        self.in_s = np.zeros((self.k, self._n_pad), dtype=bool)
        for i, init in enumerate(inits or []):
            if init is not None:
                self.in_s[i, :n] = np.asarray(init, dtype=bool)
        self.tabu = np.zeros((self.k, self._n_pad), dtype=np.int32)
        self.stall = np.zeros(self.k, dtype=np.int32)
        self.thresh = (60 + np.arange(self.k) % 24).astype(np.int32)
        self._best = self.in_s.copy()
        self.best_size = self._best.sum(axis=1).astype(np.int32)
        if n and self.k:
            import jax.numpy as jnp
            self._rows32 = jnp.asarray(g.rows_u32(self._n_pad))
            self._chunk = _build_chunk(
                n, self._n_pad, self.k, self.tenure, self.seed,
                block_n, block_k, interpret)
        else:
            self._rows32 = None
            self._chunk = None

    # ------------------------------------------------------- results
    @property
    def best(self) -> np.ndarray:
        """Per-seed best memberships ``bool [K, n]``."""
        return self._best[:, :self.g.n]

    def row_cache(self) -> np.ndarray:
        """Unpacked 0/1 adjacency for host-side repair consumers —
        same contract as `PortfolioSBTS.row_cache`."""
        return self.g.rows_u8(np.arange(self.g.n))

    # ----------------------------------------------------------- run
    def run(self, max_iters: int, target: int | None = None,
            cancel=None, tracer=None) -> np.ndarray:
        """Advance every trajectory up to ``max_iters`` lock-step
        iterations; early-exit (at chunk granularity) once any seed's
        best reaches ``target``.  ``cancel`` is polled between chunks.
        Returns per-seed best memberships ``bool [K, n]``."""
        from repro.obs.trace import live
        iters_counter = live(tracer).counter("portfolio.iters")
        if self.g.n == 0 or self.k == 0:
            return self.best
        if target is not None and (self.best_size >= target).any():
            return self.best
        import jax.numpy as jnp
        state = tuple(jnp.asarray(a) for a in (
            self.in_s, self.tabu, self.stall, self.thresh, self._best,
            self.best_size))
        done = 0
        while done < max_iters:
            if cancel is not None and cancel.is_set():
                break
            n_steps = min(self.chunk_size, max_iters - done)
            state = self._chunk(self._rows32, state, self.it, n_steps)
            self.it += n_steps
            done += n_steps
            iters_counter.inc(n_steps)
            best_size = np.asarray(state[5])
            if target is not None and (best_size >= target).any():
                break
        # np.array (copy), not np.asarray: a zero-copy view of a jax
        # buffer is read-only, and rearm/reset_seed write this state.
        (self.in_s, self.tabu, self.stall, self.thresh, self._best,
         self.best_size) = (np.array(a) for a in state)
        return self.best

    # ------------------------------------------- harvest re-seeding
    def _rng(self, k: int) -> np.random.Generator:
        """Counter-based host RNG: a pure function of
        (seed, trajectory, iteration) — resume-safe like the device
        streams."""
        return np.random.default_rng((self.seed, k, self.it))

    def rearm(self, k: int, frac: float = 0.25) -> None:
        """Diversify seed ``k`` from its harvested best: evict a
        random slice, tabu it out, reset the best tracking (mirrors
        `PortfolioSBTS.rearm`)."""
        self.in_s[k] = self._best[k]
        members = np.flatnonzero(self.in_s[k])
        if members.size:
            rng = self._rng(k)
            evict = rng.choice(
                members, size=max(1, int(members.size * frac)),
                replace=False)
            self.in_s[k, evict] = False
            self.tabu[k, evict] = self.it + 3 * self.tenure + \
                rng.integers(0, 10)
        self._resync(k)

    def reset_seed(self, k: int, init: np.ndarray | None = None) -> None:
        """Fully restart trajectory ``k`` from ``init`` (or cold)."""
        self.in_s[k] = False
        if init is not None:
            self.in_s[k, :self.g.n] = np.asarray(init, dtype=bool)
        self.tabu[k] = 0
        self._resync(k)

    def _resync(self, k: int) -> None:
        self.stall[k] = 0
        self._best[k] = self.in_s[k]
        self.best_size[k] = int(self.in_s[k].sum())


def differential_vs_numpy(g: BitsetGraph, *, inits=None, iters: int = 512,
                          k: int = 8, seed: int = 0,
                          target: int | None = None) -> dict:
    """The device-vs-oracle harness: run `DeviceSBTS` and
    `mis.PortfolioSBTS` on the same graph at equal seed count and equal
    lock-step iteration budget, and check the shared invariants —
    every best an independent set on both engines, device coverage >=
    numpy coverage.  Returns the measured dict (tests and
    `benchmarks.bench_mis` both consume it)."""
    from .mis import PortfolioSBTS

    if inits is None:
        inits = [None] * k
    dev = DeviceSBTS(g, inits, k=k, seed=seed)
    ref = PortfolioSBTS(g, list(inits), seed=seed)
    dev_best = dev.run(iters, target=target)
    ref_best = ref.run(iters, target=target)
    dev_ok = all(not g.any_conflict(_pack(row)) for row in dev_best)
    ref_ok = all(not g.any_conflict(_pack(row)) for row in ref_best)
    return dict(
        n=g.n, k=k, iters=iters,
        device_cov=int(dev.best_size.max()) if dev.k else 0,
        numpy_cov=int(ref.best_size.max()) if ref.k else 0,
        device_independent=dev_ok, numpy_independent=ref_ok)


def _pack(row: np.ndarray) -> np.ndarray:
    from .bitset import pack_bool
    return pack_bool(row)
