from .registry import (ARCHS, SHAPES, ShapeCell, applicable,  # noqa: F401
                       get_config, get_smoke_config, input_specs)
