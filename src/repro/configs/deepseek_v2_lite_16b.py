"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H MLA kv_lora=512,
MoE 64 routed top-6 + 2 shared, expert d_ff=1408, vocab=102400
[arXiv:2405.04434; hf]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, head_dim=192, vocab=102400,
    kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, head_dim=48, vocab=256,
    kv_lora=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
    n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=64,
)
