"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, layernorm + gelu (non-gated), QKV bias, RoPE
[arXiv:2402.19173; hf]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, head_dim=128, d_ff=18432, vocab=49152,
    norm="layernorm", act="gelu", gated_mlp=False, qkv_bias=True,
    rope_theta=1e5,
)

SMOKE_CONFIG = ModelConfig(
    name="starcoder2-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    norm="layernorm", act="gelu", gated_mlp=False, qkv_bias=True,
)
