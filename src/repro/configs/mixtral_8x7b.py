"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) MoE 8e top-2,
d_ff(expert)=14336, vocab=32000, SWA 4096 on every layer
[arXiv:2401.04088; hf]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, vocab=32000,
    n_experts=8, top_k=2, moe_d_ff=14336,
    sliding_window=4096, rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, vocab=256,
    n_experts=4, top_k=2, moe_d_ff=96,
    sliding_window=8, rope_theta=1e4,
)
