"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global (window 1024), 128k context
[hf:google/gemma-3-*-pt; unverified]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240, vocab=262144,
    sliding_window=1024, swa_global_every=6, rope_theta=1e6,
    embed_scale=True, tie_embeddings=True, act="gelu",
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke", family="dense", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    sliding_window=8, swa_global_every=2, embed_scale=True,
    tie_embeddings=True, act="gelu",
)
