"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552, RoPE, QKV bias [hf:THUDM/glm-4-9b]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, head_dim=128, d_ff=13696, vocab=151552,
    qkv_bias=True,
)

SMOKE_CONFIG = ModelConfig(
    name="glm4-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    qkv_bias=True,
)
