"""whisper-tiny [audio]: enc-dec 4L+4L d_model=384 6H d_ff=1536
vocab=51865; conv frontend STUBBED — input_specs provides precomputed
1500-frame embeddings [arXiv:2212.04356; unverified]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, n_enc_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6, head_dim=64, d_ff=1536,
    vocab=51865, norm="layernorm", act="gelu", gated_mlp=False,
    qkv_bias=True, tie_embeddings=True, enc_seq=1500,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, n_enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=256, norm="layernorm", act="gelu", gated_mlp=False,
    qkv_bias=True, tie_embeddings=True, enc_seq=24,
)
