"""zamba2-1.2b [hybrid]: 38L d_model=2048 Mamba2 backbone (d_state=64)
+ ONE shared attention block (32H kv=32, d_ff=8192) applied every 6
layers [arXiv:2411.15242; hf]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab=32000,
    d_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    hybrid_attn_every=6,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
    d_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8,
    hybrid_attn_every=2,
)
