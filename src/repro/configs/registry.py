"""Architecture & shape-cell registry.

Every assigned architecture is a module ``configs/<id>.py`` exposing
``CONFIG`` (the exact published configuration) and ``SMOKE_CONFIG`` (a
reduced same-family config for CPU smoke tests).  ``input_specs`` builds
the ShapeDtypeStruct stand-ins the dry-run lowers against — weak-type
correct, shardable, no device allocation.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.model import cache_specs
from repro.models.transformer import ModelConfig

ARCHS: tuple[str, ...] = (
    "mixtral-8x7b", "deepseek-v2-lite-16b", "gemma3-4b", "starcoder2-7b",
    "glm4-9b", "qwen1.5-4b", "whisper-tiny", "mamba2-2.7b", "qwen2-vl-72b",
    "zamba2-1.2b",
)

# Archs eligible for the long_500k cell (sub-quadratic attention paths:
# SWA everywhere, 5:1 local:global, SSM, hybrid).  Pure full-attention
# archs skip it (assignment rule; see DESIGN.md §5).
LONG_OK: frozenset = frozenset(
    {"mixtral-8x7b", "gemma3-4b", "mamba2-2.7b", "zamba2-1.2b"})


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def _module(arch: str):
    return importlib.import_module(
        f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE_CONFIG


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch × shape) cell."""
    if shape == "long_500k" and arch not in LONG_OK:
        return False, ("pure full-attention arch: 524k decode needs a "
                       "sub-quadratic path (assignment skip rule)")
    return True, ""


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                cache_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {batch: {tokens, labels[, vision_embeds, audio_embeds]}}
    prefill: {batch: {tokens[, ...]}, cache}
    decode:  {batch: {tokens (B,1)}, cache}
    """
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct

    def batch_specs(seq_tokens: int, with_labels: bool):
        bt: dict = {"tokens": sd((b, seq_tokens), i32)}
        if with_labels:
            bt["labels"] = sd((b, seq_tokens), i32)
        if cfg.n_vision_tokens:
            bt["vision_embeds"] = sd(
                (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            bt["audio_embeds"] = sd((b, cfg.enc_seq, cfg.d_model),
                                    jnp.bfloat16)
        return bt

    if cell.kind == "train":
        text = s - cfg.n_vision_tokens if cfg.n_vision_tokens else s
        return {"batch": batch_specs(text, True)}
    if cell.kind == "prefill":
        text = s - cfg.n_vision_tokens if cfg.n_vision_tokens else s
        return {"batch": batch_specs(text, False),
                "cache": cache_specs(cfg, b, s, cache_dtype)}
    # decode: one new token against a seq_len-deep cache
    bt = {"tokens": sd((b, 1), i32)}
    return {"batch": bt, "cache": cache_specs(cfg, b, s, cache_dtype)}
