"""mamba2-2.7b [ssm]: 64L d_model=2560 attention-free, SSD d_state=128,
expand=2 (d_inner=5120, 80 heads of 64), vocab=50280
[arXiv:2405.21060; unverified]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    vocab=50280, d_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    vocab=256, d_state=16, ssm_expand=2, ssm_head_dim=16,
    ssm_chunk=8, tie_embeddings=True,
)
