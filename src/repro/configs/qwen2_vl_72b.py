"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE (16,24,24); vision frontend STUBBED — input_specs
provides 256 precomputed patch embeddings [arXiv:2409.12191; hf]."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568, vocab=152064,
    qkv_bias=True, mrope_sections=(16, 24, 24), n_vision_tokens=256,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
    qkv_bias=True, mrope_sections=(2, 3, 3), n_vision_tokens=16,
)
