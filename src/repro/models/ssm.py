"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060), used by
mamba2-2.7b (pure SSM stack) and zamba2-1.2b (hybrid backbone).

Projections → causal depthwise conv → SSD scan (chunked for train/prefill,
recurrent step for decode) → gated RMSNorm → out-projection.  The scan math
lives in kernels/ssd (ref.py oracle + Pallas TPU kernel).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd.ref import ssd_step

from .layers import causal_conv1d, causal_conv1d_init, causal_conv1d_step, \
    dense, dense_init, rmsnorm, rmsnorm_init, truncnorm_init


def mamba2_init(key, d_model: int, *, d_state: int, expand: int = 2,
                head_dim: int = 64, n_groups: int = 1, conv_width: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 8)
    conv_dim = d_inner + 2 * n_groups * d_state
    return {
        # fused input projection: [x, z, B, C, dt]
        "in_x": dense_init(ks[0], d_model, d_inner),
        "in_z": dense_init(ks[1], d_model, d_inner),
        "in_b": dense_init(ks[2], d_model, n_groups * d_state),
        "in_c": dense_init(ks[3], d_model, n_groups * d_state),
        "in_dt": dense_init(ks[4], d_model, n_heads),
        "conv": causal_conv1d_init(ks[5], conv_dim, conv_width),
        "a_log": jnp.zeros((n_heads,), jnp.float32) + 0.5,
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner),
        "out": dense_init(ks[6], d_inner, d_model),
    }


def _proj_conv(p, x, *, d_state: int, n_groups: int, conv_state=None):
    """Shared projection+conv path; returns (xs, z, b, c, dt, new_conv)."""
    z = dense(p["in_z"], x)
    xs = dense(p["in_x"], x)
    b = dense(p["in_b"], x)
    c = dense(p["in_c"], x)
    dt = dense(p["in_dt"], x)
    xbc = jnp.concatenate([xs, b, c], axis=-1)
    if conv_state is None:
        xbc = causal_conv1d(p["conv"], xbc)
        new_conv = None
    else:
        xbc, new_conv = causal_conv1d_step(p["conv"], xbc[:, 0, :],
                                           conv_state)
        xbc = xbc[:, None, :]
    xbc = jax.nn.silu(xbc)
    d_inner = xs.shape[-1]
    xs = xbc[..., :d_inner]
    b = xbc[..., d_inner:d_inner + n_groups * d_state]
    c = xbc[..., d_inner + n_groups * d_state:]
    return xs, z, b, c, dt, new_conv


def mamba2_block(p, x, *, d_state: int, head_dim: int = 64,
                 n_groups: int = 1, chunk: int = 64,
                 cache: dict | None = None):
    """x: (B, S, D).  cache (decode/prefill): {"conv": (B,W-1,conv_dim),
    "ssm": (B,H,P,N)}.  Returns (out, new_cache).

    With a cache and S > 1 (prefill) the chunked scan runs from the
    cached state and the cache is refilled with the final SSM state and
    the conv-window tail."""
    bsz, s, _ = x.shape
    if cache is not None and s > 1:
        n_heads = p["a_log"].shape[0]
        z = dense(p["in_z"], x)
        xs = dense(p["in_x"], x)
        b = dense(p["in_b"], x)
        c = dense(p["in_c"], x)
        dt = dense(p["in_dt"], x)
        xbc_raw = jnp.concatenate([xs, b, c], axis=-1)
        xbc = jax.nn.silu(causal_conv1d(p["conv"], xbc_raw))
        d_inner = xs.shape[-1]
        xs = xbc[..., :d_inner]
        b = xbc[..., d_inner:d_inner + n_groups * d_state]
        c = xbc[..., d_inner + n_groups * d_state:]
        dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))
        xh = xs.reshape(bsz, s, n_heads, head_dim)
        y, final_state = ssd_ops.ssd(
            xh, dt, p["a_log"], b.reshape(bsz, s, n_groups, d_state),
            c.reshape(bsz, s, n_groups, d_state), chunk=min(chunk, s))
        y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh
        y = y.reshape(bsz, s, -1)
        w = cache["conv"].shape[1]
        new_cache = {"conv": xbc_raw[:, -w:, :].astype(cache["conv"].dtype),
                     "ssm": final_state.astype(cache["ssm"].dtype)}
        y = rmsnorm(p["norm"], y * jax.nn.silu(z))
        return dense(p["out"], y), new_cache
    if cache is not None:
        xs, z, b, c, dt, new_conv = _proj_conv(
            p, x, d_state=d_state, n_groups=n_groups,
            conv_state=cache["conv"])
        n_heads = p["a_log"].shape[0]
        dt = jax.nn.softplus(dt[:, 0, :] +
                             p["dt_bias"].astype(dt.dtype))   # (B,H)
        xh = xs[:, 0, :].reshape(bsz, n_heads, head_dim)
        y, new_ssm = ssd_step(cache["ssm"], xh, dt, p["a_log"],
                              b.reshape(bsz, n_groups, d_state),
                              c.reshape(bsz, n_groups, d_state))
        y = y + p["d_skip"].astype(y.dtype)[:, None] * xh
        y = y.reshape(bsz, 1, -1)
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    else:
        xs, z, b, c, dt, _ = _proj_conv(p, x, d_state=d_state,
                                        n_groups=n_groups)
        n_heads = p["a_log"].shape[0]
        dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))  # (B,S,H)
        xh = xs.reshape(bsz, s, n_heads, head_dim)
        y, _ = ssd_ops.ssd(xh, dt, p["a_log"],
                           b.reshape(bsz, s, n_groups, d_state),
                           c.reshape(bsz, s, n_groups, d_state),
                           chunk=chunk)
        y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh
        y = y.reshape(bsz, s, -1)
        new_cache = None

    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return dense(p["out"], y), new_cache


def mamba2_cache_spec(cfg_batch: int, *, d_model: int, d_state: int,
                      expand: int = 2, n_groups: int = 1,
                      conv_width: int = 4, head_dim: int = 64,
                      dtype=jnp.float32):
    """ShapeDtypeStructs for one layer's decode cache."""
    d_inner = expand * d_model
    conv_dim = d_inner + 2 * n_groups * d_state
    n_heads = d_inner // head_dim
    return {
        "conv": jax.ShapeDtypeStruct(
            (cfg_batch, conv_width - 1, conv_dim), dtype),
        "ssm": jax.ShapeDtypeStruct(
            (cfg_batch, n_heads, head_dim, d_state), jnp.float32),
    }
