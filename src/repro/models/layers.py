"""Shared neural-net primitives for the architecture pool.

Pure-JAX (no flax): parameters are nested dicts of ``jnp.ndarray``; every
init function mirrors an apply function.  Layer-stacked parameters carry a
leading ``layer`` axis consumed by ``jax.lax.scan`` in transformer.py so
compile time is depth-independent.

Logical sharding axes: every param tensor is annotated (in
``models/model.py: param_axes``) with logical axis names — 'embed', 'heads',
'kv_heads', 'head_dim', 'mlp', 'vocab', 'expert', 'layer', 'ssm_inner',
'ssm_state', ... — which launch/sharding.py maps onto the mesh via the
planner's rules (with divisibility fallback).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def truncnorm_init(key, shape, scale: float, dtype=jnp.float32):
    """Truncated-normal fan-in init (MaxText-style)."""
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out, *, bias: bool = False,
               dtype=jnp.float32):
    """d_out may be an int or a tuple (e.g. (heads, head_dim))."""
    out_shape = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    w = truncnorm_init(key, (d_in, *out_shape), scale=d_in ** -0.5,
                       dtype=dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(out_shape, dtype)
    return p


def dense(p, x, *, compute_dtype=jnp.bfloat16):
    """x: (..., d_in) @ w: (d_in, *out) -> (..., *out)."""
    w = p["w"].astype(compute_dtype)
    x = x.astype(compute_dtype)
    n_out = w.ndim - 1
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    del n_out
    return y


# --------------------------------------------------------------------- norms
def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, np.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *,
               theta: float = 10000.0,
               mrope_sections: tuple[int, ...] | None = None) -> jnp.ndarray:
    """Rotary embedding.

    x: (B, S, H, D); positions: (B, S) int32, or (3, B, S) for M-RoPE
    (temporal/height/width position streams, qwen2-vl §2.1).  With
    ``mrope_sections=(t, h, w)`` (pairs, summing to D/2) frequency bands are
    split across the three streams.
    """
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta))            # (D/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv   # (B,S,D/2)
    else:
        assert positions.ndim == 3 and sum(mrope_sections) == d // 2
        ang3 = positions[..., None].astype(jnp.float32) * inv  # (3,B,S,D/2)
        sec = np.cumsum((0,) + tuple(mrope_sections))
        parts = [ang3[i, ..., sec[i]:sec[i + 1]] for i in range(3)]
        ang = jnp.concatenate(parts, axis=-1)                  # (B,S,D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[:, :, None, :].astype(x.dtype)
    cos = cos[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


# ------------------------------------------------------------------ MLP/FFN
def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, bias=bias),
         "down": dense_init(ks[1], d_ff, d_model, bias=bias)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, bias=bias)
    return p


def mlp(p, x, *, act=jax.nn.silu):
    up = dense(p["up"], x)
    if "gate" in p:
        up = act(dense(p["gate"], x)) * up
    else:
        up = act(up)
    return dense(p["down"], up)


# ------------------------------------------------------------------- embeds
def embed_init(key, vocab: int, d_model: int):
    return {"table": truncnorm_init(key, (vocab, d_model), scale=1.0)}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    return jnp.take(p["table"].astype(compute_dtype), tokens, axis=0)


def unembed(p, x, compute_dtype=jnp.bfloat16, logits_dtype=jnp.float32):
    """Logits against the (possibly tied) embedding table."""
    return jnp.einsum("bsd,vd->bsv", x.astype(compute_dtype),
                      p["table"].astype(compute_dtype),
                      preferred_element_type=logits_dtype)


# ----------------------------------------------------------- causal conv1d
def causal_conv1d_init(key, channels: int, width: int):
    return {"w": truncnorm_init(key, (width, channels), scale=width ** -0.5),
            "b": jnp.zeros((channels,), jnp.float32)}


def causal_conv1d(p, x):
    """Depthwise causal conv over sequence. x: (B, S, C)."""
    w = p["w"].astype(x.dtype)                   # (W, C)
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    segs = [pad[:, i:i + x.shape[1], :] * w[i] for i in range(width)]
    return sum(segs) + p["b"].astype(x.dtype)


def causal_conv1d_step(p, x_t, conv_state):
    """Single decode step. x_t: (B, C); conv_state: (B, W-1, C)."""
    w = p["w"].astype(x_t.dtype)
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, w) + p["b"].astype(x_t.dtype)
    return y, window[:, 1:, :]
