"""Mixture-of-experts FFN (mixtral: 8 routed top-2; deepseek-v2-lite:
2 shared + 64 routed top-6).

Dispatch is sort-based with ``jax.lax.ragged_dot``: tokens are flattened,
sorted by assigned expert, pushed through the experts' weights as ragged
groups, and combined with the router weights.  This keeps compiled FLOPs at
the *active* count (6·N_active·D), unlike masked-dense MoE whose HLO FLOPs
blow up by E/k — that ratio is exactly what §Roofline's
MODEL_FLOPS/HLO_FLOPs column watches.

The planner (core/planner.py) treats the expert weights as the
highest-spatial-reuse tensors of MoE archs: every token block on every
device needs the same expert shard — the CGRA analogue is a VIO with
RD = |data axis|, so BandMap allocates them multicast (all-gather on the
data axis) rather than relay hops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp, mlp_init, truncnorm_init


def moe_init(key, d_model: int, *, n_experts: int, moe_d_ff: int,
             n_shared: int = 0, shared_d_ff: int | None = None):
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d_model, n_experts),
        # Stacked expert weights: (E, d_model, d_ff) / (E, d_ff, d_model).
        "w_gate": truncnorm_init(ks[1], (n_experts, d_model, moe_d_ff),
                                 scale=d_model ** -0.5),
        "w_up": truncnorm_init(ks[2], (n_experts, d_model, moe_d_ff),
                               scale=d_model ** -0.5),
        "w_down": truncnorm_init(ks[3], (n_experts, moe_d_ff, d_model),
                                 scale=moe_d_ff ** -0.5),
    }
    if n_shared:
        p["shared"] = mlp_init(
            jax.random.fold_in(key, 99), d_model,
            (shared_d_ff or moe_d_ff) * n_shared)
    return p


def moe_ffn(p, x, *, top_k: int, compute_dtype=jnp.bfloat16):
    """x: (B, S, D) -> (B, S, D).  Router in fp32 for numerics."""
    b, s, d = x.shape
    n_experts = p["router"]["w"].shape[-1]
    xf = x.reshape(b * s, d)
    t = b * s

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    gate_w, gate_i = jax.lax.top_k(logits, top_k)           # (T, k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)                # normalised over k

    # --- sort-based dispatch --------------------------------------------
    flat_expert = gate_i.reshape(-1)                        # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)             # (T*k,)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_expert)                        # stable
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]
    group_sizes = jnp.bincount(flat_expert, length=n_experts)

    xd = xf.astype(compute_dtype)[sorted_tok]               # (T*k, D) gather
    gate = jax.lax.ragged_dot(xd, p["w_gate"].astype(compute_dtype),
                              group_sizes)
    up = jax.lax.ragged_dot(xd, p["w_up"].astype(compute_dtype),
                            group_sizes)
    h = jax.nn.silu(gate) * up                              # (T*k, F)
    y = jax.lax.ragged_dot(h, p["w_down"].astype(compute_dtype),
                           group_sizes)                     # (T*k, D)

    # --- weighted combine (scatter-add back to token order) --------------
    y = y * sorted_w[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[sorted_tok].add(y)

    if "shared" in p:
        out = out + mlp(p["shared"], xf)

    aux = router_load_balancing_loss(logits, gate_i, n_experts, top_k)
    return out.reshape(b, s, d), aux


def moe_ffn_capacity(p, x, *, top_k: int, capacity_factor: float = 1.25,
                     compute_dtype=jnp.bfloat16):
    """Capacity-based MoE (§Perf optimized path).

    Tokens are sorted by expert and packed into an (E, cap, D) buffer
    (cap = ceil(T·k/E · capacity_factor); overflow tokens are dropped,
    standard capacity semantics), processed by ONE batched matmul per
    projection — (E, cap, D) @ (E, D, F) — and scattered back weighted.

    Why: `lax.ragged_dot` decomposes on non-TPU backends into a dense
    per-expert loop (T·k rows × EVERY expert -> E/k× the active FLOPs);
    the batched form compiles to exactly 2·E·cap·D·F everywhere, which is
    active-FLOPs × capacity_factor.  On the CGRA side this is BandMap's
    quantitative allocation: give each expert 'cap' guaranteed slots
    (ports) instead of letting the router relay everything everywhere.
    """
    bsz, s, d = x.shape
    n_experts = p["router"]["w"].shape[-1]
    xf = x.reshape(bsz * s, d)
    t = bsz * s

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    gate_w, gate_i = jax.lax.top_k(logits, top_k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)

    cap = int(-(-t * top_k // n_experts) * capacity_factor)
    cap = max(cap, 1)
    flat_expert = gate_i.reshape(-1)                     # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, stok, sw = flat_expert[order], flat_tok[order], flat_w[order]
    # position of each row within its expert group
    ones = jnp.ones_like(se)
    pos_in_group = jnp.cumsum(ones) - 1
    group_start = jnp.cumsum(jnp.bincount(se, length=n_experts)) \
        - jnp.bincount(se, length=n_experts)
    slot = pos_in_group - group_start[se]                # (T*k,)
    keep = slot < cap
    dest = se * cap + jnp.where(keep, slot, 0)

    xe = jnp.zeros((n_experts * cap, d), compute_dtype)
    xe = xe.at[dest].add(
        jnp.where(keep[:, None], xf[stok].astype(compute_dtype), 0))
    xe = xe.reshape(n_experts, cap, d)

    gate = jnp.einsum("ecd,edf->ecf", xe,
                      p["w_gate"].astype(compute_dtype))
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("ecf,efd->ecd", h,
                   p["w_down"].astype(compute_dtype))
    y = y.reshape(n_experts * cap, d)

    contrib = y[dest] * (sw * keep)[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[stok].add(contrib)

    if "shared" in p:
        out = out + mlp(p["shared"], xf)
    aux = router_load_balancing_loss(logits, gate_i, n_experts, top_k)
    return out.reshape(bsz, s, d), aux


def router_load_balancing_loss(logits, gate_i, n_experts: int, top_k: int):
    """Switch-style auxiliary load-balancing loss (fraction-dot-probability),
    returned for the training objective."""
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    density = jnp.mean(probs, axis=0)                       # mean router prob
    onehot = jax.nn.one_hot(gate_i, n_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / top_k
    return n_experts * jnp.sum(frac * density)
