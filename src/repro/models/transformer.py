"""Unified transformer/SSM/hybrid stack covering the 10-arch pool.

Families:
- ``dense``  — GQA attention + (gated) MLP          (gemma3, starcoder2,
               glm4, qwen1.5, qwen2-vl backbones)
- ``moe``    — GQA or MLA attention + MoE FFN       (mixtral, deepseek-v2)
- ``ssm``    — Mamba2 SSD blocks, attention-free    (mamba2-2.7b)
- ``hybrid`` — Mamba2 backbone + one *shared* GQA block invoked every k
               layers (zamba2-1.2b)
- ``encdec`` — encoder (full attn) + decoder (causal self + cross)
               (whisper-tiny; frontend stubbed to precomputed embeddings)

Homogeneous layer groups are stacked on a leading ``layer`` axis and driven
by ``jax.lax.scan`` so compile time is depth-independent; per-layer
differences (gemma3's 5:1 local:global window pattern) ride through the
scan as per-layer arrays.  ``jax.checkpoint`` wraps the scan body
(activation remat) when cfg.remat == "block".
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import constrain

from . import attention as attn
from . import layers as L
from . import moe as moe_mod
from . import ssm as ssm_mod


# ---------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 32000
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    gated_mlp: bool = True
    tie_embeddings: bool = False
    # attention pattern
    sliding_window: int | None = None
    swa_global_every: int = 0        # k>0: every k-th layer is global
    logit_cap: float | None = None
    mrope_sections: tuple[int, ...] | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    # MLA
    kv_lora: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # SSM
    d_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_groups: int = 1
    # hybrid
    hybrid_attn_every: int = 0       # shared attn block after every k layers
    # enc-dec / modality stubs
    n_enc_layers: int = 0
    enc_seq: int = 0                 # whisper: 1500 precomputed frames
    n_vision_tokens: int = 0         # qwen2-vl: stub patch embeddings
    # compute
    embed_scale: bool = False        # gemma/whisper style sqrt(d) scaling
    remat: str = "block"             # none | block
    use_pallas: bool = False
    max_decode_len: int = 0          # 0 = use shape cell's seq_len
    # §Perf knobs (baseline values are the paper-faithful defaults)
    moe_impl: str = "ragged"         # ragged | capacity
    logits_dtype: str = "float32"    # float32 | bfloat16 (bf16 backward)
    mla_absorbed: bool = False       # decode MLA in latent space (§Perf)

    @property
    def attn_kind(self) -> str:
        return "mla" if self.kv_lora else "gqa"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid, or SWA on every
        layer — gemma3's global layers bound their window by position)."""
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window is not None and self.swa_global_every == 0)

    def norm_fn(self):
        return (L.rmsnorm, L.rmsnorm_init) if self.norm == "rmsnorm" \
            else (L.layernorm, L.layernorm_init)

    def act_fn(self):
        return jax.nn.silu if self.act == "silu" else jax.nn.gelu


# ------------------------------------------------------------- layer init
def _attn_init(cfg: ModelConfig, key):
    if cfg.attn_kind == "mla":
        return attn.mla_init(key, cfg.d_model, cfg.n_heads,
                             kv_lora=cfg.kv_lora,
                             qk_nope_dim=cfg.qk_nope_dim,
                             qk_rope_dim=cfg.qk_rope_dim,
                             v_dim=cfg.v_head_dim)
    return attn.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, qkv_bias=cfg.qkv_bias)


def _ffn_init(cfg: ModelConfig, key):
    if cfg.family == "moe":
        return moe_mod.moe_init(key, cfg.d_model, n_experts=cfg.n_experts,
                                moe_d_ff=cfg.moe_d_ff,
                                n_shared=cfg.n_shared_experts,
                                shared_d_ff=cfg.moe_d_ff)
    return L.mlp_init(key, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                      bias=cfg.norm == "layernorm")


def _block_init(cfg: ModelConfig, key):
    _, norm_init = cfg.norm_fn()
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg.d_model), "attn": _attn_init(cfg, k1),
            "ln2": norm_init(cfg.d_model), "ffn": _ffn_init(cfg, k2)}


def _mamba_layer_init(cfg: ModelConfig, key):
    _, norm_init = cfg.norm_fn()
    return {"ln": norm_init(cfg.d_model),
            "mamba": ssm_mod.mamba2_init(
                key, cfg.d_model, d_state=cfg.d_state,
                expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                n_groups=cfg.ssm_groups)}


def _stack_init(per_layer_init, key, n: int):
    """vmap the per-layer init over a leading layer axis."""
    return jax.vmap(per_layer_init)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    _, norm_init = cfg.norm_fn()
    p: dict = {"embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model),
               "final_norm": norm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(ks[1], cfg.d_model, cfg.vocab)

    if cfg.family in ("dense", "moe"):
        p["layers"] = _stack_init(lambda k: _block_init(cfg, k), ks[2],
                                  cfg.n_layers)
    elif cfg.family == "ssm":
        p["layers"] = _stack_init(lambda k: _mamba_layer_init(cfg, k),
                                  ks[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        p["layers"] = _stack_init(lambda k: _mamba_layer_init(cfg, k),
                                  ks[2], cfg.n_layers)
        p["shared_attn"] = _block_init(cfg, ks[3])   # ONE copy, reused
    elif cfg.family == "encdec":
        p["enc_layers"] = _stack_init(
            lambda k: _block_init(cfg, k), ks[2], cfg.n_enc_layers)
        p["enc_norm"] = norm_init(cfg.d_model)
        p["layers"] = _stack_init(
            lambda k: _decoder_block_init(cfg, k), ks[3], cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return p


def _decoder_block_init(cfg: ModelConfig, key):
    _, norm_init = cfg.norm_fn()
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg.d_model), "attn": _attn_init(cfg, k1),
            "ln_x": norm_init(cfg.d_model),
            "xattn": attn.cross_attention_init(k2, cfg.d_model, cfg.n_heads,
                                               cfg.head_dim),
            "ln2": norm_init(cfg.d_model),
            "ffn": _ffn_init(cfg, k3)}


# --------------------------------------------------------------- windows
def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (0 = full causal).  gemma3: 5 local :
    1 global; mixtral: SWA everywhere."""
    w = np.zeros(cfg.n_layers, np.int32)
    if cfg.sliding_window is not None:
        w[:] = cfg.sliding_window
        if cfg.swa_global_every > 0:
            w[cfg.swa_global_every - 1::cfg.swa_global_every] = 0
    return w


# --------------------------------------------------------------- blocks
def _attn_apply(cfg: ModelConfig, p, x, positions, window, cache):
    if cfg.attn_kind == "mla":
        return attn.mla_attention(
            p, x, positions, n_heads=cfg.n_heads, kv_lora=cfg.kv_lora,
            qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim, rope_theta=cfg.rope_theta, cache=cache,
            absorbed=cfg.mla_absorbed)
    return attn.gqa_attention(
        p, x, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=window, mrope_sections=cfg.mrope_sections, cache=cache)


def _block_apply(cfg: ModelConfig, p, x, positions, window, cache):
    """Pre-norm transformer block.  window: None or dynamic scalar."""
    norm, _ = cfg.norm_fn()
    aux = jnp.zeros((), jnp.float32)
    h, new_cache = _attn_apply(cfg, p["attn"], norm(p["ln1"], x),
                               positions, window, cache)
    x = x + h
    ff_in = norm(p["ln2"], x)
    if cfg.family == "moe":
        moe_fn = (moe_mod.moe_ffn_capacity if cfg.moe_impl == "capacity"
                  else moe_mod.moe_ffn)
        h, aux = moe_fn(p["ffn"], ff_in, top_k=cfg.top_k)
    else:
        h = L.mlp(p["ffn"], ff_in, act=cfg.act_fn())
    x = x + h
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


def _mamba_apply(cfg: ModelConfig, p, x, cache):
    norm, _ = cfg.norm_fn()
    h, new_cache = ssm_mod.mamba2_block(
        p["mamba"], norm(p["ln"], x), d_state=cfg.d_state,
        head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
        chunk=cfg.ssm_chunk, cache=cache)
    x = x + h
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache


# ----------------------------------------------------------- main stacks
def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "block":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def _scan_blocks(cfg: ModelConfig, stacked, x, positions, windows, caches):
    """Scan homogeneous transformer blocks.  caches: stacked pytree with
    leading layer axis or None."""
    def body(carry, per_layer):
        xc, aux_acc = carry
        p, w, cache = per_layer
        xo, new_cache, aux = _block_apply(cfg, p, xc, positions,
                                          w if windows is not None else None,
                                          cache)
        return (xo, aux_acc + aux), new_cache

    body = _maybe_remat(cfg, body)
    wins = (jnp.asarray(windows) if windows is not None
            else jnp.zeros(cfg.n_layers, jnp.int32))
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (stacked, wins, caches))
    return x, aux, new_caches


def _scan_mamba(cfg: ModelConfig, stacked, x, caches):
    def body(carry, per_layer):
        p, cache = per_layer
        xo, new_cache = _mamba_apply(cfg, p, carry, cache)
        return xo, new_cache

    body = _maybe_remat(cfg, body)
    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches


def _hybrid_apply(cfg: ModelConfig, params, x, positions, caches):
    """zamba2: mamba backbone; ONE shared attention block (weights reused)
    applied after every ``hybrid_attn_every`` full layers.  The scan is
    split into segments so each shared-attn *invocation* gets its own KV
    cache — same weights, distinct activations."""
    k = cfg.hybrid_attn_every
    n = cfg.n_layers
    aux = jnp.zeros((), jnp.float32)
    new_mamba, new_attn = [], []
    mcaches = caches["mamba"] if caches is not None else None
    acaches = caches["attn"] if caches is not None else None
    start, inv = 0, 0
    while start < n:
        end = min(start + k, n)
        seg = jax.tree.map(lambda a: a[start:end], params["layers"])
        seg_cache = (jax.tree.map(lambda a: a[start:end], mcaches)
                     if mcaches is not None else None)
        x, nc = _scan_mamba(cfg, seg, x, seg_cache)
        new_mamba.append(nc)
        if end - start == k:        # full segment -> shared attn invocation
            ac = (jax.tree.map(lambda a: a[inv], acaches)
                  if acaches is not None else None)
            x, nac, a = _block_apply(cfg, params["shared_attn"], x,
                                     positions, None, ac)
            aux = aux + a
            if nac is not None:
                new_attn.append(nac)
            inv += 1
        start = end
    new_caches = None
    if caches is not None:
        new_caches = {
            "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                  *new_mamba),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                                 *new_attn),
        }
    return x, aux, new_caches


def n_hybrid_attn_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every


# ----------------------------------------------------------------- entry
def forward(cfg: ModelConfig, params, batch: dict, caches=None):
    """Unified forward.

    batch: {"tokens": (B, S_text) int32, optional "vision_embeds"
    (B, Tv, D), "audio_embeds" (B, S_enc, D), "positions"}.
    caches: None (train/prefill) or the decode cache pytree.
    Returns (logits, aux_loss, new_caches).
    """
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if cfg.n_vision_tokens and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x], axis=1)

    b, s = x.shape[:2]
    if caches is not None and "pos" in (caches or {}):
        positions = caches["pos"] + jnp.arange(s)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.mrope_sections is not None:
        positions = _mrope_positions(cfg, b, s, positions)

    x = constrain(x, ("batch", "seq", "embed"))
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        wins = layer_windows(cfg)
        lc = caches["layers"] if caches is not None else None
        x, aux, new_lc = _scan_blocks(
            cfg, params["layers"], x, positions,
            wins if cfg.sliding_window is not None else None, lc)
        new_caches = _bump(caches, new_lc, s)
    elif cfg.family == "ssm":
        lc = caches["layers"] if caches is not None else None
        x, new_lc = _scan_mamba(cfg, params["layers"], x, lc)
        new_caches = _bump(caches, new_lc, s)
    elif cfg.family == "hybrid":
        lc = caches["layers"] if caches is not None else None
        x, aux, new_lc = _hybrid_apply(cfg, params, x, positions, lc)
        new_caches = _bump(caches, new_lc, s)
    else:  # encdec
        x, aux, new_caches = _encdec_forward(cfg, params, batch, x,
                                             positions, caches)

    norm, _ = cfg.norm_fn()
    x = norm(params["final_norm"], x)
    # logits dtype: fp32 is the faithful default; the bf16 §Perf knob
    # keeps the whole backward cotangent chain in bf16 (the loss still
    # upcasts for logsumexp) — halves activation HBM traffic.
    ldt = jnp.float32 if cfg.logits_dtype == "float32" else jnp.bfloat16
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, logits_dtype=ldt)
    else:
        logits = L.dense(params["unembed"], x).astype(ldt)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux, new_caches


def _bump(caches, new_layer_caches, s):
    if caches is None:
        return None
    return {"layers": new_layer_caches, "pos": caches["pos"] + s}


def _mrope_positions(cfg: ModelConfig, b, s, positions):
    """Qwen2-VL M-RoPE position streams (temporal, height, width).

    Prefill/train (s covers the vision prefix): the Tv = g*g stub patch
    grid sits at t = 0 with (h, w) grid coordinates; text continues all
    three streams linearly from g.  Decode (s == 1): text-only, all three
    streams equal the absolute position (offset already in `positions`).
    """
    tv = cfg.n_vision_tokens
    g = int(np.sqrt(tv)) if tv else 0
    if tv and g * g == tv and s > tv:
        hh = jnp.repeat(jnp.arange(g), g)
        ww = jnp.tile(jnp.arange(g), g)
        tt = jnp.zeros(tv, jnp.int32)
        text = jnp.arange(s - tv) + g
        pos3 = jnp.stack([
            jnp.concatenate([tt, text]),
            jnp.concatenate([hh, text]),
            jnp.concatenate([ww, text])])                     # (3, S)
        return jnp.broadcast_to(pos3[:, None, :], (3, b, s))
    return jnp.broadcast_to(positions[None], (3, b, s))


def _encdec_forward(cfg: ModelConfig, params, batch, x, positions, caches):
    norm, _ = cfg.norm_fn()
    aux = jnp.zeros((), jnp.float32)

    if caches is None or caches.get("cross_kv") is None:
        enc_x = batch["audio_embeds"].astype(x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1])[None], enc_x.shape[:2])

        def enc_body(carry, p):
            xo, _, _ = _block_apply(cfg, p, carry, enc_pos, None, None)
            return xo, None

        enc_out, _ = jax.lax.scan(_maybe_remat(cfg, enc_body), enc_x,
                                  params["enc_layers"])
        enc_out = norm(params["enc_norm"], enc_out)
        # Precompute per-decoder-layer cross KV: the classic spatially-
        # reused tensor (RD = #decode steps) — computed ONCE.
        cross_kv = jax.vmap(
            lambda p: attn.encode_cross_kv(p["xattn"], enc_out)
        )(params["layers"])
    else:
        cross_kv = caches["cross_kv"]

    lc = caches["layers"] if caches is not None else None

    def dec_body(carry, per_layer):
        xc, aux_acc = carry
        p, ckv, cache = per_layer
        h, new_cache = _attn_apply(cfg, p["attn"], norm(p["ln1"], xc),
                                   positions, None, cache)
        xc = xc + h
        xc = xc + attn.cross_attention(p["xattn"], norm(p["ln_x"], xc),
                                       ckv, n_heads=cfg.n_heads,
                                       head_dim=cfg.head_dim)
        h = L.mlp(p["ffn"], norm(p["ln2"], xc), act=cfg.act_fn())
        xc = xc + h
        xc = constrain(xc, ("batch", "seq", "embed"))
        return (xc, aux_acc), new_cache

    (x, aux), new_lc = jax.lax.scan(
        _maybe_remat(cfg, dec_body), (x, aux),
        (params["layers"], cross_kv, lc))
    new_caches = None
    if caches is not None:
        new_caches = {"layers": new_lc, "cross_kv": cross_kv,
                      "pos": caches["pos"] + x.shape[1]}
    return x, aux, new_caches
