"""Attention variants for the architecture pool.

- GQA (grouped-query) with optional QKV bias, RoPE / M-RoPE, causal and
  sliding-window masks — covers mixtral, gemma3, starcoder2, glm4, qwen1.5,
  qwen2-vl, zamba2's shared attention block and whisper self-attention.
- MLA (multi-head latent attention, DeepSeek-V2): low-rank compressed KV
  cache (c_kv, k_pe) with both the naive (materialise K/V) and the
  *absorbed* decode path (attention directly in the latent space) — the
  absorbed path is the §Perf hillclimb for deepseek decode.
- Cross-attention (whisper decoder).

All paths use the chunked online-softmax implementation from
``repro.kernels.flash_attention.ref`` (pure jnp, compiles on every backend);
on a real TPU run the Pallas kernel in the same package is selected by
``use_pallas=True`` in the model config.

Shapes follow (B, S, H, D); KV caches are (B, S_max, H_kv, D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .layers import apply_rope, dense, dense_init


# ------------------------------------------------------------------ masking
def causal_window_mask(q_pos, k_pos, window):
    """(..., S_q, S_k) bool mask.  window: None or a (possibly traced)
    scalar; values <= 0 mean plain causal — this lets per-layer window
    arrays ride through `lax.scan` (gemma3's 5 local : 1 global)."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        w = jnp.asarray(window)
        win_ok = (q_pos[..., :, None] - k_pos[..., None, :]) < w
        m &= jnp.where(w > 0, win_ok, True)
    return m


def sdpa(q, k, v, mask, *, scale=None, logit_cap: float | None = None):
    """Masked softmax(QK^T)V with GQA head broadcasting.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D); mask: broadcastable to
    (B, Hq, Sq, Sk).  Uses fp32 softmax.  Memory O(Sq*Sk) — the chunked
    flash path in kernels/flash_attention is used for long sequences.
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qh = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                        preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    # mask: (B?, 1, Sq, Sk) — the head axis must be broadcastable (size 1);
    # insert the group axis so it broadcasts over (hkv, g).
    assert mask.ndim == 4 and mask.shape[1] == 1, mask.shape
    logits = jnp.where(mask[:, :, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(b, sq, hq, v.shape[-1])   # v dim may differ (MLA)


# ---------------------------------------------------------------------- GQA
def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             *, qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    return {
        "q": dense_init(ks[0], d_model, (n_heads, head_dim), bias=qkv_bias),
        "k": dense_init(ks[1], d_model, (n_kv, head_dim), bias=qkv_bias),
        "v": dense_init(ks[2], d_model, (n_kv, head_dim), bias=qkv_bias),
        "o": dense_init(ks[3], n_heads * head_dim, d_model),
    }


def _flash_or_sdpa(q, k, v, *, q_offset, window, flash_block: int):
    """Dispatch: chunked flash path for long sequences, plain SDPA for
    short ones (and for decode where Sq is tiny)."""
    sq, sk = q.shape[1], k.shape[1]
    if sq * sk > 4096 * 4096 or (sq == 1 and sk > 8192):
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, q_offset=q_offset, window=window,
            block_k=flash_block)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = causal_window_mask(q_pos, k_pos, window)[None, None]
    return sdpa(q, k, v, mask)


def gqa_attention(p, x, positions, *, n_heads: int, n_kv: int,
                  head_dim: int, rope_theta: float = 10000.0,
                  window: int | None = None,
                  mrope_sections: tuple[int, ...] | None = None,
                  cache: dict | None = None,
                  flash_block: int = 512):
    """Returns (out, new_cache).  cache = {"k","v": (B,S_max,Hkv,D),
    "pos": ()} for decode; None for train/prefill (full causal self-attn).
    """
    q = dense(p["q"], x)                       # (B,S,H,D)
    k = dense(p["k"], x)
    v = dense(p["v"], x)
    q = apply_rope(q, positions, theta=rope_theta,
                   mrope_sections=mrope_sections)
    k = apply_rope(k, positions, theta=rope_theta,
                   mrope_sections=mrope_sections)

    if cache is None:
        out = _flash_or_sdpa(q, k, v, q_offset=0, window=window,
                             flash_block=flash_block)
        new_cache = None
    else:
        pos = cache["pos"]                     # scalar int32: tokens so far
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        s_max = k_all.shape[1]
        q_pos = pos + jnp.arange(q.shape[1])
        k_pos = jnp.arange(s_max)
        mask = causal_window_mask(q_pos, k_pos, window)[None, None]
        out = sdpa(q, k_all, v_all, mask)
        new_cache = {"k": k_all, "v": v_all, "pos": pos + q.shape[1]}

    b, s = x.shape[:2]
    out = out.reshape(b, s, n_heads * head_dim)
    return dense(p["o"], out), new_cache


# ---------------------------------------------------------------------- MLA
def mla_init(key, d_model: int, n_heads: int, *, kv_lora: int,
             qk_nope_dim: int = 128, qk_rope_dim: int = 64,
             v_dim: int = 128):
    ks = jax.random.split(key, 6)
    return {
        "q": dense_init(ks[0], d_model, (n_heads, qk_nope_dim + qk_rope_dim)),
        "dkv": dense_init(ks[1], d_model, kv_lora),      # compress
        "kpe": dense_init(ks[2], d_model, qk_rope_dim),  # shared rope key
        "uk": dense_init(ks[3], kv_lora, (n_heads, qk_nope_dim)),
        "uv": dense_init(ks[4], kv_lora, (n_heads, v_dim)),
        "o": dense_init(ks[5], n_heads * v_dim, d_model),
    }


def mla_attention(p, x, positions, *, n_heads: int, kv_lora: int,
                  qk_nope_dim: int = 128, qk_rope_dim: int = 64,
                  v_dim: int = 128, rope_theta: float = 10000.0,
                  cache: dict | None = None, absorbed: bool = True):
    """Multi-head latent attention.  Cache holds only (c_kv, k_pe):
    (B, S_max, kv_lora) + (B, S_max, qk_rope_dim).

    absorbed=True computes decode attention in the latent space
    (q_nope·W_uk as a latent query; context re-expanded through W_uv),
    avoiding re-materialising K/V for the whole cache every step —
    the paper-facing §Perf optimization for deepseek decode.
    """
    b, s, _ = x.shape
    q = dense(p["q"], x)                                  # (B,S,H,nope+rope)
    q_nope, q_pe = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_pe = apply_rope(q_pe, positions, theta=rope_theta)
    c_kv = dense(p["dkv"], x)                             # (B,S,L)
    k_pe = dense(p["kpe"], x)[:, :, None, :]              # (B,S,1,R)
    k_pe = apply_rope(k_pe, positions, theta=rope_theta)[:, :, 0, :]

    scale = (qk_nope_dim + qk_rope_dim) ** -0.5

    if cache is not None:
        pos = cache["pos"]
        c_all = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        kpe_all = jax.lax.dynamic_update_slice(
            cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, pos, 0))
        new_cache = {"c_kv": c_all, "k_pe": kpe_all, "pos": pos + s}
        s_max = c_all.shape[1]
        q_pos = pos + jnp.arange(s)
        mask = (q_pos[:, None] >= jnp.arange(s_max)[None, :])[None, None]
        if absorbed:
            # latent query: (B,S,H,L);  logits from latent dot + rope dot
            q_lat = jnp.einsum("bshn,lhn->bshl", q_nope,
                               p["uk"]["w"].astype(q_nope.dtype))
            logits = (jnp.einsum("bshl,bkl->bhsk", q_lat, c_all,
                                 preferred_element_type=jnp.float32)
                      + jnp.einsum("bshr,bkr->bhsk", q_pe, kpe_all,
                                   preferred_element_type=jnp.float32))
            w = jax.nn.softmax(
                jnp.where(mask, logits * scale, -1e30), axis=-1)
            ctx_lat = jnp.einsum("bhsk,bkl->bshl", w.astype(c_all.dtype),
                                 c_all)
            out = jnp.einsum("bshl,lhv->bshv", ctx_lat,
                             p["uv"]["w"].astype(ctx_lat.dtype))
        else:
            k_nope = jnp.einsum("bkl,lhn->bkhn", c_all,
                                p["uk"]["w"].astype(c_all.dtype))
            val = jnp.einsum("bkl,lhv->bkhv", c_all,
                             p["uv"]["w"].astype(c_all.dtype))
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(
                    kpe_all[:, :, None, :],
                    (*kpe_all.shape[:2], n_heads, qk_rope_dim))], axis=-1)
            q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
            out = sdpa(q_full, k_full, val, mask, scale=scale)
    else:
        new_cache = None
        k_nope = jnp.einsum("bkl,lhn->bkhn", c_kv,
                            p["uk"]["w"].astype(c_kv.dtype))
        val = jnp.einsum("bkl,lhv->bkhv", c_kv,
                         p["uv"]["w"].astype(c_kv.dtype))
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_pe[:, :, None, :],
                (b, s, n_heads, qk_rope_dim))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
        q_pos = jnp.arange(s)
        mask = (q_pos[:, None] >= q_pos[None, :])[None, None]
        out = sdpa(q_full, k_full, val, mask, scale=scale)

    out = out.reshape(b, s, -1)
    return dense(p["o"], out), new_cache


# ------------------------------------------------------------- cross-attn
def cross_attention_init(key, d_model: int, n_heads: int, head_dim: int):
    ks = jax.random.split(key, 4)
    return {
        "q": dense_init(ks[0], d_model, (n_heads, head_dim), bias=True),
        "k": dense_init(ks[1], d_model, (n_heads, head_dim)),
        "v": dense_init(ks[2], d_model, (n_heads, head_dim), bias=True),
        "o": dense_init(ks[3], n_heads * head_dim, d_model, bias=True),
    }


def cross_attention(p, x, enc_kv, *, n_heads: int, head_dim: int):
    """enc_kv: dict with precomputed {"k","v"} (B, S_enc, H, D) — computed
    once at prefill and spatially reused by every decode step (the
    highest-RD tensor in the whisper transfer DFG; see planner)."""
    b, s, _ = x.shape
    q = dense(p["q"], x)
    mask = jnp.ones((1, 1, s, enc_kv["k"].shape[1]), bool)
    out = sdpa(q, enc_kv["k"], enc_kv["v"], mask)
    return dense(p["o"], out.reshape(b, s, n_heads * head_dim))


def encode_cross_kv(p, enc_out):
    return {"k": dense(p["k"], enc_out), "v": dense(p["v"], enc_out)}
