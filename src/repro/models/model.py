"""Unified model entrypoints: parameter init/specs, decode-cache specs,
loss, `train_step`, and `serve_step` — the two functions the launcher
lowers for every (arch × shape × mesh) cell.

Everything is pure-JAX over nested-dict pytrees; sharding enters only
through `launch.sharding` annotations, so the same code runs on one CPU
device (smoke tests) and on the 512-chip production mesh (dry-run).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import transformer as T
from .transformer import ModelConfig


# ------------------------------------------------------------------ params
def init_params(cfg: ModelConfig, seed: int = 0):
    return T.init_params(cfg, jax.random.PRNGKey(seed))


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


def count_params(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    import numpy as np
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(specs)))


# ------------------------------------------------------------------- cache
def cache_specs(cfg: ModelConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the decode cache for a batch of
    ``batch`` sequences with capacity ``s_max``."""
    L = cfg.n_layers
    i32 = jnp.int32

    def gqa_cache(lead):
        return {
            "k": jax.ShapeDtypeStruct(
                (*lead, batch, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jax.ShapeDtypeStruct(
                (*lead, batch, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
            "pos": jax.ShapeDtypeStruct(tuple(lead), i32),
        }

    if cfg.family in ("dense", "moe"):
        if cfg.attn_kind == "mla":
            layers = {
                "c_kv": jax.ShapeDtypeStruct((L, batch, s_max, cfg.kv_lora),
                                             dtype),
                "k_pe": jax.ShapeDtypeStruct(
                    (L, batch, s_max, cfg.qk_rope_dim), dtype),
                "pos": jax.ShapeDtypeStruct((L,), i32),
            }
        else:
            layers = gqa_cache((L,))
    elif cfg.family == "ssm":
        from .ssm import mamba2_cache_spec
        one = mamba2_cache_spec(batch, d_model=cfg.d_model,
                                d_state=cfg.d_state, expand=cfg.ssm_expand,
                                n_groups=cfg.ssm_groups,
                                head_dim=cfg.ssm_head_dim, dtype=dtype)
        layers = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((L, *s.shape), s.dtype), one)
    elif cfg.family == "hybrid":
        from .ssm import mamba2_cache_spec
        one = mamba2_cache_spec(batch, d_model=cfg.d_model,
                                d_state=cfg.d_state, expand=cfg.ssm_expand,
                                n_groups=cfg.ssm_groups,
                                head_dim=cfg.ssm_head_dim, dtype=dtype)
        n_inv = T.n_hybrid_attn_invocations(cfg)
        layers = {
            "mamba": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((L, *s.shape), s.dtype), one),
            "attn": gqa_cache((n_inv,)),
        }
    elif cfg.family == "encdec":
        layers = gqa_cache((L,))
        return {"layers": layers,
                "cross_kv": {
                    "k": jax.ShapeDtypeStruct(
                        (L, batch, cfg.enc_seq, cfg.n_heads, cfg.head_dim),
                        dtype),
                    "v": jax.ShapeDtypeStruct(
                        (L, batch, cfg.enc_seq, cfg.n_heads, cfg.head_dim),
                        dtype)},
                "pos": jax.ShapeDtypeStruct((), i32)}
    else:
        raise ValueError(cfg.family)
    return {"layers": layers, "pos": jax.ShapeDtypeStruct((), i32)}


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, s_max, dtype))


# -------------------------------------------------------------------- loss
def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01,
            z_weight: float = 1e-4):
    """Next-token CE (+ router aux loss + z-loss).  labels = -1 masked."""
    logits, aux, _ = T.forward(cfg, params, batch)
    logits = logits.astype(jnp.float32)   # CE reductions always in fp32
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # modality prefix (VLM stub): loss over the text suffix only
        logits = logits[:, -labels.shape[1]:]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - ll) * valid
    n = jnp.maximum(valid.sum(), 1)
    ce_mean = ce.sum() / n
    zloss = ((logz * valid) ** 2).sum() / n
    total = ce_mean + aux_weight * aux + z_weight * zloss
    return total, {"ce": ce_mean, "aux": aux, "zloss": zloss,
                   "ntokens": n}


# ------------------------------------------------------------- train step
def make_train_step(cfg: ModelConfig, optimizer):
    """optimizer: repro.optim object with init(params)/update(g, s, p)."""

    def train_step(state, batch):
        params, opt_state, step = state
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        gnorm = optax_global_norm(grads)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=step.astype(jnp.float32))
        return (params, opt_state, step + 1), metrics

    return train_step


def optax_global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ------------------------------------------------------------- serve step
def prefill_step(cfg: ModelConfig, params, batch, cache):
    """Run the prompt through the model, filling the cache; returns
    (last-token logits, cache)."""
    logits, _, cache = T.forward(cfg, params, batch, caches=cache)
    return logits[:, -1:], cache


def serve_step(cfg: ModelConfig, params, batch, cache):
    """One decode step: batch["tokens"]: (B, 1) int32.  Greedy next token.
    Returns (next_tokens (B,1), logits, new_cache)."""
    logits, _, cache = T.forward(cfg, params, batch, caches=cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    return nxt[:, None], logits, cache
