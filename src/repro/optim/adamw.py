"""AdamW with global-norm clipping and cosine LR schedule (pure JAX,
optax-free).  Moments are stored fp32; the update is returned as a delta so
`train_step` composes it with any parameter dtype.

Sharding note: moment tensors inherit the parameter sharding (same tree
structure — `params_shardings` applies transparently), so optimizer state
is fully sharded; the planner decides all-reduce vs reduce-scatter for the
gradients themselves (launch/train.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | object = 3e-4          # float or schedule(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        count = state["count"] + 1
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g),
            state["nu"], grads)
        c1 = 1 - self.b1 ** count.astype(jnp.float32)
        c2 = 1 - self.b2 ** count.astype(jnp.float32)
        lr = self.lr(count) if callable(self.lr) else self.lr

        def upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}
