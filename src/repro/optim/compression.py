"""Gradient compression for cross-pod traffic (distributed-optimization
trick for the 2×16×16 mesh): int8 block quantisation with error feedback.

The data-parallel all-reduce inside a pod rides the fast 2-D ICI torus; the
pod axis crosses the (slower) optical links, so the launcher can choose to
all-reduce int8-quantised gradients across pods and correct with local
error feedback.  `compress -> all-reduce -> decompress` with EF is unbiased
in the long run (error is replayed into the next step's gradient).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g):
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


def compress_grads(grads):
    """Pytree -> pytree of (q, scale) pairs (leaves become dicts)."""
    return jax.tree.map(lambda g: dict(zip(("q", "scale"), _quantize(g))),
                        grads)


def decompress_grads(comp, like):
    return jax.tree.map(
        lambda c, g: _dequantize(c["q"], c["scale"], g.shape),
        comp, like,
        is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "scale"})


def error_feedback_update(grads, errors):
    """Add carried quantisation error, quantise, and compute new error.

    Returns (compressed, decompressed_estimate, new_errors)."""
    if errors is None:
        errors = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(lambda g, e: g + e, grads, errors)
    comp = compress_grads(corrected)
    est = decompress_grads(comp, corrected)
    new_err = jax.tree.map(lambda c, d: c - d, corrected, est)
    return comp, est, new_err
