from .adamw import AdamW, cosine_schedule  # noqa: F401
from .compression import (compress_grads, decompress_grads,  # noqa: F401
                          error_feedback_update)
