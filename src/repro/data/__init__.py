from .pipeline import DataConfig, SyntheticLMData, make_pipeline  # noqa: F401
