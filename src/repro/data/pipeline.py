"""Deterministic, index-based data pipeline.

Design requirements for the multi-pod runtime:
- **stateless resume**: batch t is a pure function of (seed, t) — restart
  from a checkpoint replays exactly the same stream with no pipeline state
  to save (the checkpoint stores only the step counter);
- **shard-by-host**: each host materialises only its slice of the global
  batch (`host_slice`), so feeding 512 chips never funnels through one
  process;
- **synthetic + file-backed**: the default source is a seeded synthetic
  LM stream (zipfian tokens with locally-coherent repeats, so the CE loss
  has learnable structure); a memory-mapped token file can be dropped in
  with the same interface.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_vision_tokens: int = 0
    d_model: int = 0               # for modality stubs
    enc_seq: int = 0
    kind: str = "synthetic"        # synthetic | file
    path: str = ""


class SyntheticLMData:
    """batch(t) -> dict of numpy arrays; pure function of (seed, t)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, host_slice: slice | None = None) -> dict:
        cfg = self.cfg
        lo, hi = (host_slice.start, host_slice.stop) if host_slice \
            else (0, cfg.global_batch)
        rows = []
        for b in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, b]))
            # zipf-ish marginal + local repeats = learnable structure
            base = rng.zipf(1.3, size=cfg.seq_len + 1) % cfg.vocab
            rep = rng.random(cfg.seq_len + 1) < 0.3
            for i in range(1, cfg.seq_len + 1):
                if rep[i]:
                    base[i] = base[i - 1]
            rows.append(base)
        arr = np.stack(rows).astype(np.int32)
        out = {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
        if cfg.n_vision_tokens:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 7]))
            out["vision_embeds"] = rng.standard_normal(
                (hi - lo, cfg.n_vision_tokens, cfg.d_model),
                dtype=np.float32) * 0.02
            out["tokens"] = out["tokens"][:, cfg.n_vision_tokens:]
            out["labels"] = out["labels"][:, cfg.n_vision_tokens:]
        if cfg.enc_seq:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, 11]))
            out["audio_embeds"] = rng.standard_normal(
                (hi - lo, cfg.enc_seq, cfg.d_model),
                dtype=np.float32) * 0.02
        return out


class FileLMData:
    """Memory-mapped flat token file; same (seed, t)-pure interface —
    batch t reads deterministic offsets, so resume needs no state."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch(self, step: int, host_slice: slice | None = None) -> dict:
        cfg = self.cfg
        lo, hi = (host_slice.start, host_slice.stop) if host_slice \
            else (0, cfg.global_batch)
        n = len(self.tokens) - cfg.seq_len - 1
        rows = []
        for b in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, b]))
            off = int(rng.integers(0, n))
            rows.append(np.asarray(self.tokens[off:off + cfg.seq_len + 1]))
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_pipeline(cfg: DataConfig):
    return FileLMData(cfg) if cfg.kind == "file" else SyntheticLMData(cfg)
