"""Numpy oracle for the sbts_step conflict-count kernel."""

from __future__ import annotations

import numpy as np


def selection_counts_ref(rows32: np.ndarray,
                         sel32: np.ndarray) -> np.ndarray:
    """``int32 [K, n_pad]`` — |N(v) ∩ S_k| over packed uint32 words,
    the same contraction `kernel.selection_counts_pallas` tiles."""
    rows32 = np.asarray(rows32, dtype=np.uint32)
    sel32 = np.asarray(sel32, dtype=np.uint32)
    return np.bitwise_count(
        rows32[None, :, :] & sel32[:, None, :]).sum(
            axis=-1, dtype=np.int32)
