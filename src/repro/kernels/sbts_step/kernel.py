"""Pallas kernel for the device SBTS step's conflict-count evaluation.

The device portfolio (`repro.core.mis_device.DeviceSBTS`) advances K
tabu trajectories in lock-step; every step needs, for each trajectory
k and each vertex v, the count ``|N(v) ∩ S_k|`` of v's neighbours
inside some packed vertex set S_k (the current selection, the addable
set, the Luby sample).  With the adjacency as packed uint32 words
``rows32 [n_pad, W]`` (`BitsetGraph.rows_u32`) and the selections as
``sel32 [K, W]``, that is one AND + ``lax.population_count`` + word
reduction per (k, v) pair — the all-pairs popcount this kernel tiles
over a (seed-block, vertex-block) grid.

Tiling: ``block_k × block_n × W`` words are materialised per grid
cell, so the defaults (8 × 1024) keep the working set a few MiB even
at the 16x16-fabric |V_C| ~ 10^4 scale.  Block sizes that do not
divide the operand shapes fall back to a single block on that axis —
callers pad ``n_pad`` to a multiple of 128 (`mis_device._pad_n`), so
the fallback only triggers for small K.  Interpret mode is the
CI-validated path (this repo's runners are CPU-only); real-TPU
lane-width tuning of ``W`` (last-dim 128 alignment) is the standing
ROADMAP gap shared with `kernels.conflict_matrix`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _counts_kernel(rows_ref, sel_ref, out_ref):
    rows = rows_ref[...]                      # (block_n, W) uint32
    sel = sel_ref[...]                        # (block_k, W) uint32
    hits = jax.lax.population_count(rows[None, :, :] & sel[:, None, :])
    out_ref[...] = hits.astype(jnp.int32).sum(axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def selection_counts_pallas(rows32, sel32, *, block_n: int = 1024,
                            block_k: int = 8,
                            interpret: bool = False):
    """``int32 [K, n_pad]`` of ``popcount(rows32[v] & sel32[k])`` over
    the word axis — |N(v) ∩ S_k| for every (trajectory, vertex) pair."""
    n_pad, w = rows32.shape
    k, w2 = sel32.shape
    assert w == w2, (rows32.shape, sel32.shape)
    if n_pad % block_n:
        block_n = n_pad
    if k % block_k:
        block_k = k
    grid = (k // block_k, n_pad // block_n)
    return pl.pallas_call(
        _counts_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, w), lambda kk, i: (i, 0)),
                  pl.BlockSpec((block_k, w), lambda kk, i: (kk, 0))],
        out_specs=pl.BlockSpec((block_k, block_n),
                               lambda kk, i: (kk, i)),
        out_shape=jax.ShapeDtypeStruct((k, n_pad), jnp.int32),
        interpret=interpret,
    )(rows32, sel32)
