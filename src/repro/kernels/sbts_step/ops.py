"""Host-side dispatch for the sbts_step conflict-count primitive.

The device engine (`repro.core.mis_device`) traces
`kernel.selection_counts_pallas` directly inside its jitted step; this
module is the host-callable split the differential tests and benches
use — numpy reference by default, Pallas (interpret or compiled) on
request."""

from __future__ import annotations

import numpy as np

from . import ref


def selection_counts(rows32, sel32, *, use_pallas: bool = False,
                     interpret: bool = False, block_n: int = 1024,
                     block_k: int = 8) -> np.ndarray:
    """|N(v) ∩ S_k| as ``int32 [K, n_pad]`` — see `ref` / `kernel`."""
    if use_pallas:
        from . import kernel
        return np.asarray(kernel.selection_counts_pallas(
            np.ascontiguousarray(rows32, dtype=np.uint32),
            np.ascontiguousarray(sel32, dtype=np.uint32),
            block_n=block_n, block_k=block_k, interpret=interpret))
    return ref.selection_counts_ref(rows32, sel32)
