"""Device SBTS step primitives — see `kernel` (Pallas), `ref` (numpy
oracle) and `ops` (host dispatch)."""

from .ops import selection_counts

__all__ = ["selection_counts"]
