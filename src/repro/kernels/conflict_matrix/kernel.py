"""Pallas TPU kernel for tiled conflict-matrix construction.

Grid (nI, nJ) over (block × block) tiles of the n×n adjacency.  Each
program loads two (block, 8) int32 feature tiles into VMEM and evaluates
the occupancy/clique predicate with broadcast compares on the VPU —
8-lane int32 compares over a 256×256 tile are ~0.5 MiB of VMEM traffic
and no MXU work, so the kernel is VPU/bandwidth-bound; block=256 keeps
three tiles (two features + one output) < 1 MiB VMEM.

Output int8 (bool-like); the host MIS solver consumes it directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import N_FEATURES, QUAD, TIN, TOUT


def _cm_kernel(fi_ref, fj_ref, o_ref, *, block: int, n: int):
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    fi = fi_ref[...]                       # (block, 8)
    fj = fj_ref[...]

    def col(ref, k):
        return ref[:, k]

    ki, oi, mi, pi = col(fi, 0), col(fi, 1), col(fi, 2), col(fi, 3)
    ri, ci = col(fi, 4), col(fi, 5)
    kj, oj, mj, pj = col(fj, 0), col(fj, 1), col(fj, 2), col(fj, 3)
    rj, cj = col(fj, 4), col(fj, 5)

    def outer_eq(a, b):
        return a[:, None] == b[None, :]

    same_op = outer_eq(oi, oj)
    same_m = outer_eq(mi, mj)
    same_port = outer_eq(pi, pj)
    same_pe = outer_eq(ri, rj) & outer_eq(ci, cj)

    def both(k):
        return (ki[:, None] == k) & (kj[None, :] == k)

    adj = same_op
    adj |= both(TIN) & same_port & same_m
    adj |= both(TOUT) & same_port & same_m
    adj |= both(QUAD) & same_pe & same_m

    # mask diagonal and padding
    gi = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    gj = bj * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    adj &= gi != gj
    adj &= (gi < n) & (gj < n)
    o_ref[...] = adj.astype(jnp.int8)


def _cm_packed_kernel(fi_ref, fj_ref, o_ref, *, block_i: int,
                      block_j: int, n: int):
    """Packed variant: evaluate the predicate over a (block_i, block_j)
    tile and emit uint32 words (32 adjacency bits each, little-endian
    bit order), so the host can view pairs of words as the uint64 rows
    `BitsetGraph` consumes — no python pack step."""
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    fi = fi_ref[...]                       # (block_i, 8)
    fj = fj_ref[...]                       # (block_j, 8)

    def col(ref, k):
        return ref[:, k]

    ki, oi, mi, pi = col(fi, 0), col(fi, 1), col(fi, 2), col(fi, 3)
    ri, ci = col(fi, 4), col(fi, 5)
    kj, oj, mj, pj = col(fj, 0), col(fj, 1), col(fj, 2), col(fj, 3)
    rj, cj = col(fj, 4), col(fj, 5)

    def outer_eq(a, b):
        return a[:, None] == b[None, :]

    same_op = outer_eq(oi, oj)
    same_m = outer_eq(mi, mj)
    same_port = outer_eq(pi, pj)
    same_pe = outer_eq(ri, rj) & outer_eq(ci, cj)

    def both(k):
        return (ki[:, None] == k) & (kj[None, :] == k)

    adj = same_op
    adj |= both(TIN) & same_port & same_m
    adj |= both(TOUT) & same_port & same_m
    adj |= both(QUAD) & same_pe & same_m

    gi = bi * block_i + jax.lax.broadcasted_iota(
        jnp.int32, (block_i, block_j), 0)
    gj = bj * block_j + jax.lax.broadcasted_iota(
        jnp.int32, (block_i, block_j), 1)
    adj &= gi != gj
    adj &= (gi < n) & (gj < n)

    # Pack 32 adjacent j-bits per uint32 word: bit k of word w is
    # column w*32 + k (little-endian within the word, matching
    # bitset.pack_bool's layout once word pairs are viewed as uint64).
    w = block_j // 32
    bits = adj.reshape(block_i, w, 32).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 32), 2)
    weights = jnp.left_shift(jnp.uint32(1), shifts.astype(jnp.uint32))
    o_ref[...] = (bits * weights).sum(axis=-1).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j",
                                             "interpret"))
def conflict_matrix_packed_pallas(feat, *, block_i: int = 256,
                                  block_j: int = 2048,
                                  interpret: bool = False):
    """feat: (n, 8) int32 -> (n, ceil(n/block_j)*block_j/32) uint32
    packed adjacency words.  ``block_j`` must be a multiple of 64 so
    the host can reinterpret word pairs as uint64 rows; its default
    (2048 -> 64 uint32 lanes) keeps the packed output tile half a
    register wide while three live tiles stay ~2.5 MiB of VMEM."""
    assert block_j % 64 == 0
    n = feat.shape[0]
    npad_i = -(-n // block_i) * block_i
    npad_j = -(-n // block_j) * block_j
    fp_i = jnp.pad(feat, ((0, npad_i - n), (0, 0)), constant_values=-7)
    fp_j = jnp.pad(feat, ((0, npad_j - n), (0, 0)), constant_values=-7)

    return pl.pallas_call(
        functools.partial(_cm_packed_kernel, block_i=block_i,
                          block_j=block_j, n=n),
        grid=(npad_i // block_i, npad_j // block_j),
        in_specs=[
            pl.BlockSpec((block_i, N_FEATURES), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, N_FEATURES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j // 32),
                               lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad_i, npad_j // 32),
                                       jnp.uint32),
        interpret=interpret,
    )(fp_i, fp_j)[:n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def conflict_matrix_pallas(feat, *, block: int = 256,
                           interpret: bool = False):
    """feat: (n, 8) int32 -> (n, n) int8 adjacency."""
    n = feat.shape[0]
    npad = -(-n // block) * block
    fp = jnp.pad(feat, ((0, npad - n), (0, 0)), constant_values=-7)
    nb = npad // block

    out = pl.pallas_call(
        functools.partial(_cm_kernel, block=block, n=n),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, N_FEATURES), lambda i, j: (i, 0)),
            pl.BlockSpec((block, N_FEATURES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, npad), jnp.int8),
        interpret=interpret,
    )(fp, fp)
    return out[:n, :n]
