"""Pallas TPU kernel for tiled conflict-matrix construction.

Grid (nI, nJ) over (block × block) tiles of the n×n adjacency.  Each
program loads two (block, 8) int32 feature tiles into VMEM and evaluates
the occupancy/clique predicate with broadcast compares on the VPU —
8-lane int32 compares over a 256×256 tile are ~0.5 MiB of VMEM traffic
and no MXU work, so the kernel is VPU/bandwidth-bound; block=256 keeps
three tiles (two features + one output) < 1 MiB VMEM.

Output int8 (bool-like); the host MIS solver consumes it directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import N_FEATURES, QUAD, TIN, TOUT


def _cm_kernel(fi_ref, fj_ref, o_ref, *, block: int, n: int):
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    fi = fi_ref[...]                       # (block, 8)
    fj = fj_ref[...]

    def col(ref, k):
        return ref[:, k]

    ki, oi, mi, pi = col(fi, 0), col(fi, 1), col(fi, 2), col(fi, 3)
    ri, ci = col(fi, 4), col(fi, 5)
    kj, oj, mj, pj = col(fj, 0), col(fj, 1), col(fj, 2), col(fj, 3)
    rj, cj = col(fj, 4), col(fj, 5)

    def outer_eq(a, b):
        return a[:, None] == b[None, :]

    same_op = outer_eq(oi, oj)
    same_m = outer_eq(mi, mj)
    same_port = outer_eq(pi, pj)
    same_pe = outer_eq(ri, rj) & outer_eq(ci, cj)

    def both(k):
        return (ki[:, None] == k) & (kj[None, :] == k)

    adj = same_op
    adj |= both(TIN) & same_port & same_m
    adj |= both(TOUT) & same_port & same_m
    adj |= both(QUAD) & same_pe & same_m

    # mask diagonal and padding
    gi = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    gj = bj * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    adj &= gi != gj
    adj &= (gi < n) & (gj < n)
    o_ref[...] = adj.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def conflict_matrix_pallas(feat, *, block: int = 256,
                           interpret: bool = False):
    """feat: (n, 8) int32 -> (n, n) int8 adjacency."""
    n = feat.shape[0]
    npad = -(-n // block) * block
    fp = jnp.pad(feat, ((0, npad - n), (0, 0)), constant_values=-7)
    nb = npad // block

    out = pl.pallas_call(
        functools.partial(_cm_kernel, block=block, n=n),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, N_FEATURES), lambda i, j: (i, 0)),
            pl.BlockSpec((block, N_FEATURES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, npad), jnp.int8),
        interpret=interpret,
    )(fp, fp)
    return out[:n, :n]
