"""Oracle for the conflict-matrix construction kernel — the O(|V_C|²) hot
spot of the paper's own pipeline (phase 3a).

A candidate vertex is encoded as 8 int32 features (see ``encode`` /
core/conflict.py):

  kind   0=TIN 1=TOUT 2=QUAD
  op     op id (clique rule: one candidate per op)
  m      modulo slot
  port   tin: IPORT row / tout: OPORT col / quad: -1
  pe_r, pe_c                     (quad only, else -1)
  mode   tin: 0 bus, 1 grf       (else -1)
  drive  quad routing: 0 none, 1 row, 2 col

Pairwise conflict (the dense occupancy/clique part — dependency-edge
realizability is sparse and handled host-side):

  same_op:    op_i == op_j                                   (i != j)
  iport:      both TIN  & port equal & m equal
  oport:      both TOUT & port equal & m equal
  pe:         both QUAD & pe equal   & m equal

Vectorised numpy here; the Pallas kernel tiles the same predicate over
(block × block) int32 tiles.
"""

from __future__ import annotations

import numpy as np

TIN, TOUT, QUAD = 0, 1, 2
N_FEATURES = 8


def encode(vertices) -> np.ndarray:
    """core.conflict.Vertex list -> (n, 8) int32 feature matrix."""
    from repro.core.conflict import QUAD as QS
    from repro.core.conflict import TIN as TS
    from repro.core.conflict import TOUT as OS
    from repro.core.tec import ROW
    kind_map = {TS: TIN, OS: TOUT, QS: QUAD}
    out = np.full((len(vertices), N_FEATURES), -1, np.int32)
    for i, v in enumerate(vertices):
        drive = 0
        if v.drive is not None:
            drive = 1 if v.drive[0] == ROW else 2
        out[i] = (kind_map[v.kind], v.op, v.m, v.port,
                  v.pe[0], v.pe[1],
                  {"": -1, "bus": 0, "grf": 1}.get(v.mode, -1), drive)
    return out


def conflict_matrix_ref(feat: np.ndarray) -> np.ndarray:
    """(n, 8) int32 -> (n, n) bool adjacency (occupancy + clique rules)."""
    kind = feat[:, 0]
    op = feat[:, 1]
    m = feat[:, 2]
    port = feat[:, 3]
    pe_r, pe_c = feat[:, 4], feat[:, 5]

    same_op = op[:, None] == op[None, :]
    same_m = m[:, None] == m[None, :]
    both = lambda k: (kind[:, None] == k) & (kind[None, :] == k)  # noqa
    same_port = port[:, None] == port[None, :]
    same_pe = (pe_r[:, None] == pe_r[None, :]) & \
        (pe_c[:, None] == pe_c[None, :])

    adj = same_op.copy()
    adj |= both(TIN) & same_port & same_m
    adj |= both(TOUT) & same_port & same_m
    adj |= both(QUAD) & same_pe & same_m
    np.fill_diagonal(adj, False)
    return adj
