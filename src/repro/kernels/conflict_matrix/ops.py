"""Public conflict-matrix entrypoint: numpy-vectorised reference by
default (host-side mapping pipeline), Pallas kernel for TPU runs."""

from __future__ import annotations

import sys

import numpy as np

from . import ref


def conflict_matrix(vertices, *, use_pallas: bool = False,
                    interpret: bool = False) -> np.ndarray:
    """core.conflict.Vertex list -> (n, n) bool adjacency of the
    occupancy/clique rules (dense part; dependency edges added by the
    caller)."""
    feat = ref.encode(vertices)
    if use_pallas:
        from . import kernel
        adj = np.asarray(kernel.conflict_matrix_pallas(
            feat, interpret=interpret))
        return adj.astype(bool)
    return ref.conflict_matrix_ref(feat)


def conflict_matrix_packed(vertices, *, use_pallas: bool = False,
                           interpret: bool = False) -> np.ndarray:
    """core.conflict.Vertex list -> packed ``uint64 [n, ceil(n/64)]``
    adjacency rows, the layout `core.bitset.BitsetGraph` consumes.

    With ``use_pallas`` the TPU kernel emits uint32 words that are
    reinterpreted pairwise as uint64 on the host (little-endian bit
    order end to end), so the accelerator path feeds the bitset engine
    with no python pack step; the host path packs the dense-bool
    reference — which stays the oracle either way."""
    from repro.core.bitset import n_words, pack_bool_rows

    feat = ref.encode(vertices)
    n = feat.shape[0]
    if not use_pallas:
        return pack_bool_rows(ref.conflict_matrix_ref(feat))
    from . import kernel
    w32 = np.asarray(kernel.conflict_matrix_packed_pallas(
        feat, interpret=interpret))
    w32 = np.ascontiguousarray(w32)
    if sys.byteorder == "little":
        rows = w32.view(np.uint64)
    else:  # pragma: no cover - big-endian host
        rows = (w32[:, 0::2].astype(np.uint64)
                | (w32[:, 1::2].astype(np.uint64) << np.uint64(32)))
    return rows[:, :n_words(n)]
