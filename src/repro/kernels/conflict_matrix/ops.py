"""Public conflict-matrix entrypoint: numpy-vectorised reference by
default (host-side mapping pipeline), Pallas kernel for TPU runs."""

from __future__ import annotations

import numpy as np

from . import ref


def conflict_matrix(vertices, *, use_pallas: bool = False,
                    interpret: bool = False) -> np.ndarray:
    """core.conflict.Vertex list -> (n, n) bool adjacency of the
    occupancy/clique rules (dense part; dependency edges added by the
    caller)."""
    feat = ref.encode(vertices)
    if use_pallas:
        from . import kernel
        adj = np.asarray(kernel.conflict_matrix_pallas(
            feat, interpret=interpret))
        return adj.astype(bool)
    return ref.conflict_matrix_ref(feat)
