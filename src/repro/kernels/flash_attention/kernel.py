"""Pallas TPU flash attention (forward), GQA + causal + sliding window.

Tiling: grid (B, Hq, nQ, nK); each program holds one (block_q, D) query
tile and one (block_k, D) KV tile in VMEM.  The online-softmax carry
(m, l, acc) lives in VMEM scratch and is carried across the trailing
(sequential) k-block grid dimension; the output tile is written on the
last k iteration.  Block sizes default to 128 — MXU-aligned (128×128
systolic array) and small enough that the q/k/v/acc tiles
(≈4·128·128·4 B ≈ 256 KiB at D=128) fit comfortably in ~16 MiB VMEM.

Causal skip: k blocks strictly above the diagonal are skipped via
``pl.when`` (no MXU work issued) — for causal full attention that halves
issued FLOPs; with a sliding window only O(window/block_k) k blocks per
query tile do work.  The window is a *static* parameter, fused into the
same predication.

Validated in interpret mode against ``ref.flash_attention_ref``
(tests/test_kernels.py sweeps shapes × dtypes × window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               block_q: int, block_k: int, sk: int, q_offset: int,
               window: int | None, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + q_offset          # absolute first q position
    k_start = ki * block_k

    # tile-level visibility (causal diagonal and window band)
    q_last = q_start + block_q - 1
    visible = k_start <= q_last
    if window is not None:
        visible &= (k_start + block_k) > (q_start - window + 1)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = (q_pos >= k_pos) & (k_pos < sk)
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("q_offset", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention_pallas(q, k, v, *, q_offset: int = 0,
                           window: int | None = None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    if isinstance(window, int) and window <= 0:
        window = None
    scale = d ** -0.5

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, sk=sk,
        q_offset=q_offset, window=window, scale=scale)

    # layout: head axis ahead of seq so VMEM tiles are (block, D)
    qt = q.transpose(0, 2, 1, 3)          # (B, Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3)          # (B, Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
