"""Public flash-attention entrypoint with backend dispatch: Pallas TPU
kernel when requested (real-TPU runs / interpret-mode tests), the chunked
pure-jnp reference otherwise (CPU, dry-run lowering)."""

from __future__ import annotations

from functools import partial

import jax

from . import ref


@partial(jax.jit, static_argnames=("block_k", "use_pallas", "interpret"))
def flash_attention(q, k, v, *, q_offset=0, window=None, block_k: int = 512,
                    use_pallas: bool = False, interpret: bool = False):
    if use_pallas:
        from . import kernel
        return kernel.flash_attention_pallas(
            q, k, v, q_offset=q_offset, window=window, block_k=block_k,
            interpret=interpret)
    return ref.flash_attention_ref(q, k, v, q_offset=q_offset,
                                   window=window, block_k=block_k)
