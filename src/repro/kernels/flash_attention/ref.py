"""Pure-jnp chunked flash attention (online softmax) — the oracle for the
Pallas kernel and the default long-sequence path on all backends.

Memory O(S_q · block_k) instead of O(S_q · S_k): a `lax.scan` over KV
blocks carries running (max, sum, acc) per query — numerically identical
(up to fp assoc.) to full softmax attention.

Supports GQA head broadcasting, causal masking with a query offset (decode
against a long cache), and dynamic sliding windows (traced scalar; <= 0
means full causal).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, q_offset=0, window=None,
                        block_k: int = 512, scale: float | None = None):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D).

    q_offset: absolute position of q[0] (queries are assumed contiguous).
    window: None | scalar (traced ok); <= 0 means full causal.
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    nb = -(-sk // block_k)
    pad = nb * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qh = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, d)
    q_pos = q_offset + jnp.arange(sq)

    kb = k.reshape(b, nb, block_k, hkv, d).astype(jnp.float32)
    vb = v.reshape(b, nb, block_k, hkv, d).astype(jnp.float32)

    def body(carry, inp):
        m, s, acc = carry                    # (B,Sq,Hkv,G), .., (..,D)
        kblk, vblk, start = inp              # (B,L,Hkv,D)
        k_pos = start + jnp.arange(block_k)
        logits = jnp.einsum("bqhgd,bkhd->bqhgk", qh, kblk)   # (B,Sq,Hkv,G,L)
        mask = q_pos[:, None] >= k_pos[None, :]              # (Sq,L)
        mask &= k_pos[None, :] < sk                          # padding
        if window is not None:
            w = jnp.asarray(window)
            win_ok = (q_pos[:, None] - k_pos[None, :]) < w
            mask &= jnp.where(w > 0, win_ok, True)
        logits = jnp.where(mask[None, :, None, None, :], logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        s_new = s * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vblk)
        return (m_new, s_new, acc_new), None

    init = (jnp.full((b, sq, hkv, g), -jnp.inf, jnp.float32),
            jnp.zeros((b, sq, hkv, g), jnp.float32),
            jnp.zeros((b, sq, hkv, g, d), jnp.float32))
    starts = jnp.arange(nb) * block_k
    (m, s, acc), _ = jax.lax.scan(
        body, init, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), starts))
    out = acc / jnp.maximum(s, 1e-30)[..., None]
    return out.reshape(b, sq, hq, d).astype(q.dtype)
