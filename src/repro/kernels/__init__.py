"""Pallas TPU kernels for the framework's compute hot spots, each with
ops.py (jit'd dispatch wrapper) and ref.py (pure-jnp oracle), validated in
interpret mode on CPU:

- flash_attention/  block-tiled online-softmax attention
                    (GQA, causal, sliding window, decode offsets)
- ssd/              Mamba2 SSD chunked scan with VMEM state carry
- conflict_matrix/  tiled construction of the paper's dense conflict
                    rules (TPU-offload form of core/conflict.py)
"""
