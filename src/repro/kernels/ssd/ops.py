"""Jit'd public entrypoint for the SSD scan with backend dispatch:
Pallas TPU kernel when requested/available, pure-jnp chunked reference
otherwise (CPU/GPU and all dry-run lowering paths).
"""

from __future__ import annotations

from functools import partial

import jax

from . import ref


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd(x, dt, a_log, b, c, *, chunk: int = 64, use_pallas: bool = False,
        interpret: bool = False):
    """Chunked SSD scan; see ref.ssd_chunked for shapes."""
    if use_pallas:
        from . import kernel
        y, state = kernel.ssd_pallas(x, dt, a_log, b, c, chunk=chunk,
                                     interpret=interpret)
        return y, state
    return ref.ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
