"""Pallas TPU kernel for the Mamba2 SSD chunked scan (n_groups == 1).

Grid (B, H/hb, nC) with the chunk dimension trailing — TPU grids iterate
the last dimension sequentially per core, so the inter-chunk state carry
(hb, P, N) lives in VMEM scratch across chunk steps; no HBM round-trip for
the recurrence.  Per program:

  intra:  gates[h,i,j] = (C_i·B_j) · exp(cum_h[i]-cum_h[j]) · dt_j   (i>=j)
          y_intra[h]   = gates[h] @ x[h]                 (L×L @ L×P on MXU)
  inter:  y_inter[h]   = (C @ state[h]^T) · exp(cum_h)   (L×N @ N×P)
  state:  state[h]     = state[h]·exp(total_h)
                         + ((dt·decay·B)^T @ x[h])       (N×L @ L×P)

VMEM budget at L=chunk=128, hb=4, P=64, N=128:
x/y tiles 4·128·64·4 B ≈ 128 KiB, gates 4·128·128·4 ≈ 256 KiB,
state 4·64·128·4 ≈ 128 KiB — far under the ~16 MiB VMEM ceiling; L and hb
are the tuning knobs.

Validated in interpret mode against ref.ssd_chunked.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, fin_ref,
                state_ref, *, chunk: int, hb: int, p: int, n: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)             # (L, hb, P)
    dt = dt_ref[0].astype(jnp.float32)           # (L, hb)
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))   # (hb,)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)   # (L, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)   # (L, N)

    dta = dt * a[None, :]                        # (L, hb) log-decay
    cum = jnp.cumsum(dta, axis=0)                # inclusive
    total = cum[-1, :]                           # (hb,)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    for h in range(hb):                          # hb is small and static
        ss = cum[:, None, h] - cum[None, :, h]   # (L, L)
        gates = jnp.where(tri, scores * jnp.exp(ss) * dt[None, :, h], 0.0)
        y_intra = jax.lax.dot(gates, x[:, h, :],
                              preferred_element_type=jnp.float32)
        st = state_ref[h]                        # (P, N)
        y_inter = jax.lax.dot_general(
            cm, st, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * jnp.exp(cum[:, h:h + 1])
        y_ref[0, :, h, :] = (y_intra + y_inter).astype(y_ref.dtype)

        w = dt[:, h] * jnp.exp(total[h] - cum[:, h])          # (L,)
        upd = jax.lax.dot_general(
            x[:, h, :], bm * w[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (P, N)
        state_ref[h] = st * jnp.exp(total[h]) + upd

    @pl.when(ci == nc - 1)
    def _finish():
        fin_ref[0] = state_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "head_block", "interpret"))
def ssd_pallas(x, dt, a_log, b, c, *, chunk: int = 128,
               head_block: int = 4, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b, c: (B,S,1,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert b.shape[2] == 1, "pallas SSD kernel supports n_groups == 1"
    assert s % chunk == 0
    hb = min(head_block, h)
    assert h % hb == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, hb=hb, p=p, n=n)
    y, fin = pl.pallas_call(
        kernel,
        grid=(bsz, h // hb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hb, p),
                         lambda b_, hi, ci: (b_, ci, hi, 0)),
            pl.BlockSpec((1, chunk, hb),
                         lambda b_, hi, ci: (b_, ci, hi)),
            pl.BlockSpec((hb,), lambda b_, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, hi, ci: (b_, ci, 0, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, hi, ci: (b_, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hb, p),
                         lambda b_, hi, ci: (b_, ci, hi, 0)),
            pl.BlockSpec((1, hb, p, n), lambda b_, hi, ci: (b_, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c)
    return y, fin
