"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) chunked scan
(arXiv:2405.21060, Algorithm "SSD").

Selective state space recurrence, per head h with head dim P and state N:

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t ⊗ x_t         (P, N)
    y_t = h_t @ C_t + D * x_t

The chunked form splits the sequence into chunks of length L:
 - intra-chunk: a (masked, decay-weighted) attention-like quadratic term,
 - chunk states: decay-weighted sum of B⊗x within each chunk,
 - inter-chunk: a `lax.scan`/associative-scan over per-chunk states,
 - output: intra + C·(carried state) + skip.

This file is the reference the Pallas kernel (kernel.py) is verified
against, and the implementation used on non-TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(log_a):
    """(..., L) -> (..., L, L) lower-triangular pairwise decay sums:
    out[i, j] = sum_{k=j+1..i} log_a[k]  (i >= j), -inf above diagonal."""
    length = log_a.shape[-1]
    x = jnp.cumsum(log_a, axis=-1)
    diff = x[..., :, None] - x[..., None, :]
    mask = jnp.tril(jnp.ones((length, length), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, *, chunk: int = 64,
                initial_state=None):
    """Chunked SSD scan.

    x:  (B, S, H, P)   inputs (already gated/conv'd)
    dt: (B, S, H)      positive step sizes (softplus applied by caller)
    a_log: (H,)        A = -exp(a_log)
    b, c: (B, S, G, N) input/output projections (G groups broadcast to H)
    Returns y: (B, S, H, P), final_state: (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    s_orig = s
    if s % chunk:
        # pad with dt = 0 steps: decay exp(0·A) = 1 and zero B·x update,
        # so both outputs and the final state are unaffected.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)
    dta = dt.astype(jnp.float32) * a                         # (B,S,H) log-decay
    # chunk views
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    dtac = dta.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    # ---- intra-chunk (quadratic, attention-like) -------------------------
    ss = segsum(jnp.moveaxis(dtac, -1, -2))                  # (B,nc,H,L,L)
    decay = jnp.exp(ss)
    scores = jnp.einsum("bzihn,bzjhn->bzhij", cc, bc,
                        preferred_element_type=jnp.float32)
    dt_j = jnp.moveaxis(dtc, -1, -2)                         # (B,nc,H,L)
    gates = scores * decay * dt_j[..., None, :]              # dt on j axis
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", gates,
                         xc.astype(jnp.float32))

    # ---- chunk states -----------------------------------------------------
    cum = jnp.cumsum(dtac, axis=2)                           # (B,nc,L,H)
    total = cum[:, :, -1:, :]                                # (B,nc,1,H)
    state_decay = jnp.exp(total - cum)                       # decay j -> end
    sb = bc * (dtc * state_decay)[..., None]                 # weight B by dt
    states = jnp.einsum("bzjhn,bzjhp->bzhpn", sb,
                        xc.astype(jnp.float32))              # (B,nc,H,P,N)

    # ---- inter-chunk scan --------------------------------------------------
    chunk_decay = jnp.exp(total[:, :, 0, :])                 # (B,nc,H)
    init = (jnp.zeros((bsz, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                        # (B,H,P,N),(B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                    # emit PREVIOUS

    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    # ---- inter-chunk output contribution ----------------------------------
    in_decay = jnp.exp(cum)                                  # decay start->t
    y_inter = jnp.einsum("bzihn,bzhpn->bzihp", cc, prev_states) \
        * in_decay[..., None]                                # (B,nc,L,H,1)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def ssd_step(state, x_t, dt_t, a_log, b_t, c_t):
    """Single-token recurrent update (decode path).

    state: (B, H, P, N); x_t: (B, H, P); dt_t: (B, H);
    b_t, c_t: (B, G, N).  Returns (y_t, new_state).
    """
    bsz, h, p = x_t.shape
    g = b_t.shape[1]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dt_t.astype(jnp.float32) * a)               # (B,H)
    bh = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)    # (B,H,N)
    ch = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)
    upd = (dt_t.astype(jnp.float32)[..., None, None]
           * x_t.astype(jnp.float32)[..., None] * bh[..., None, :])
    new_state = state * da[..., None, None] + upd            # (B,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return y.astype(x_t.dtype), new_state
