"""Step-scoped checkpoint/restore for sharded training state.

Format: one directory per step, one ``.npz`` shard per host (each host
writes only the leaves it owns — addressable shards of globally-sharded
arrays), plus a small JSON manifest with the pytree structure, step, and
data-pipeline cursor.  Writes are atomic (tmp dir + rename) so a failure
mid-write never corrupts the latest checkpoint; `CheckpointManager`
retains the newest K checkpoints and garbage-collects the rest.

On restore the manifest's tree structure is validated against the
expected pytree, and each leaf is device_put against the *current* mesh's
sharding — which is what makes elastic restarts (restore onto a smaller
degraded mesh; see runtime/elastic.py) work: the on-disk format is
mesh-agnostic.
"""

from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = leaf
    return out


def save_checkpoint(path: str, state, step: int, *, host_id: int = 0,
                    extra: dict | None = None) -> str:
    """Atomically write ``state`` under ``path/step_<step>``."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    if host_id == 0:
        manifest = {
            "step": step, "time": time.time(),
            "keys": sorted(arrays.keys()),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else \
        _merge_tmp(tmp, final)
    return final


def _merge_tmp(tmp: str, final: str) -> None:
    for f in os.listdir(tmp):
        os.replace(os.path.join(tmp, f), os.path.join(final, f))
    shutil.rmtree(tmp, ignore_errors=True)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith("tmp0")]
    return max(steps) if steps else None


def load_checkpoint(path: str, like, step: int | None = None,
                    *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the *current* mesh."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: dict[str, np.ndarray] = {}
    for f_ in sorted(os.listdir(d)):
        if f_.startswith("shard_") and f_.endswith(".npz"):
            with np.load(os.path.join(d, f_)) as z:
                for k in z.files:
                    arrays[k] = z[k]
    want = _flatten_with_paths(like)
    missing = set(want) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")

    flat_sh = _flatten_with_paths(shardings) if shardings is not None \
        else {}
    restored = {}
    for k, spec in want.items():
        arr = arrays[k]
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{arr.shape} vs {spec.shape}")
        if k in flat_sh:
            restored[k] = jax.device_put(arr, flat_sh[k])
        else:
            restored[k] = arr
    # unflatten back into the reference structure
    treedef = jax.tree_util.tree_structure(like)
    keys = list(_flatten_with_paths(like).keys())
    leaves = [restored[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Retention + cadence policy around save/load."""

    def __init__(self, path: str, *, keep: int = 3, every: int = 100):
        self.path = path
        self.keep = keep
        self.every = every

    def maybe_save(self, state, step: int, **kw) -> str | None:
        if step % self.every:
            return None
        out = save_checkpoint(self.path, state, step, **kw)
        self._gc()
        return out

    def _gc(self) -> None:
        if not os.path.isdir(self.path):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.path)
                       if d.startswith("step_") and "tmp" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like, **kw):
        return load_checkpoint(self.path, like, **kw)
