"""Shared-scope arbitration and merged-binding replay for co-mapping.

Regions own their PEs exclusively, but the row/column infrastructure is
shared: two regions spanning the same global row contend for that row's
IPORT and its IBUS cells, regions sharing a column contend for the
OPORT/OBUS — and the PE-driven routing buses of every shared scope are a
common pool.  Per-region mappings are validated *locally* under the
assumption that their region owns its scopes outright, so the co-mapper
must re-establish soundness globally.  Two mechanisms:

- :func:`arbitrate` — cheap structural check over the regions' **fixed**
  claims (port instances and hardwired bus-0 drive cells, which no
  global reassignment can move), plus the pooled GRF budget.  A clash
  here dooms every global binding that keeps the per-region placements,
  so the implicated regions are re-mapped with fresh seeds before any
  merge is attempted.  Cross-region collisions between *flexible*
  (bus, cycle) assignments are collected as advisory conflicts only:
  the merged validator re-solves the global bus packing from scratch
  and may legally move them.
- :func:`merge_mappings` — disjoint-union of the per-region scheduled
  DFGs (ops renumbered, coordinates translated to global) into one
  ``ScheduledDFG`` + placement that `core.validate.validate_mapping`
  replays against the full-array config.  The existing validator is the
  single soundness authority: occupancy, global bus assignment, LRF and
  GRF capacity are all re-checked on the merged binding.
"""

from __future__ import annotations

import dataclasses

from repro.core.bandmap import MappingResult
from repro.core.cgra import CGRAConfig
from repro.core.conflict import TIN, TOUT, Vertex
from repro.core.dfg import DFG
from repro.core.schedule import ScheduledDFG
from repro.core.tec import COL, ROW

from .regions import Region


@dataclasses.dataclass
class ArbiterReport:
    ok: bool
    conflicts: list[str]            # hard: doom every merged binding
    advisory: list[str]             # flexible-cell overlaps (re-solvable)
    implicated: set[int]            # region indices to re-map (hard)
    advisory_implicated: set[int]   # fallback retry set after a merged
    #                                 validation failure

    def summary(self) -> str:
        return (f"arbiter: ok={self.ok}, {len(self.conflicts)} hard / "
                f"{len(self.advisory)} advisory conflicts")


def fixed_claims(region: Region, result: MappingResult,
                 ) -> dict[tuple, str]:
    """Global fixed resource cells a region's mapping occupies.

    Port instances and the hardwired bus-0 drives of VIO delivery / VOO
    export are pinned by the placement itself — they are the claims no
    global bus re-assignment can relocate."""
    claims: dict[tuple, str] = {}
    for oid, v in result.placement.items():
        if v.kind == TIN:
            row = v.port + region.r0
            claims[("iport", row, v.m)] = f"VIO {oid} on IPORT_{row}"
            if v.mode == "bus":
                claims[("bus", ROW, row, 0, v.m)] = \
                    f"VIO {oid} delivery on IBUS_{row}"
        elif v.kind == TOUT:
            col = v.port + region.c0
            claims[("oport", col, v.m)] = f"VOO {oid} on OPORT_{col}"
            claims[("bus", COL, col, 0, v.m)] = \
                f"VOO {oid} export on OBUS_{col}"
    return claims


def flexible_cells(region: Region, result: MappingResult,
                   ) -> dict[tuple, str]:
    """Global (scope, idx, bus, slot) cells of the region's *local* bus
    assignment for PE->PE transfers.  Advisory only — the merged replay
    re-solves these globally."""
    cells: dict[tuple, str] = {}
    if result.report is None:
        return cells
    for edge, (scope, idx, k, slot) in result.report.bus_assignment.items():
        g_idx = idx + (region.r0 if scope == ROW else region.c0)
        cells[(scope, g_idx, k, slot)] = f"transfer {edge}"
    return cells


def arbitrate(regions: list[Region], results: list[MappingResult],
              cgra: CGRAConfig) -> ArbiterReport:
    """Check the co-resident mappings' shared-scope claims.

    All results must be at one common II (the co-mapper's invariant —
    modulo slots of different IIs would not even be comparable)."""
    iis = {r.ii for r in results}
    assert len(iis) == 1, f"co-mapped kernels disagree on II: {iis}"
    conflicts: list[str] = []
    advisory: list[str] = []
    implicated: set[int] = set()
    advisory_implicated: set[int] = set()

    hard_owner: dict[tuple, tuple[int, str]] = {}
    for ri, (region, res) in enumerate(zip(regions, results)):
        for cell, what in fixed_claims(region, res).items():
            if cell in hard_owner:
                oi, owhat = hard_owner[cell]
                conflicts.append(
                    f"fixed claim clash on {cell}: region {oi} ({owhat}) "
                    f"vs region {ri} ({what})")
                implicated.update((oi, ri))
            else:
                hard_owner[cell] = (ri, what)

    flex_owner: dict[tuple, tuple[int, str]] = {}
    for ri, (region, res) in enumerate(zip(regions, results)):
        for cell, what in flexible_cells(region, res).items():
            hit = hard_owner.get(cell) or flex_owner.get(cell)
            if hit is not None and hit[0] != ri:
                advisory.append(
                    f"flexible cell overlap on {cell}: region {hit[0]} "
                    f"({hit[1]}) vs region {ri} ({what})")
                advisory_implicated.update((hit[0], ri))
            flex_owner.setdefault(cell, (ri, what))

    grf_total = sum(res.report.grf_peak for res in results
                    if res.report is not None)
    if grf_total > max(cgra.grf, 0):
        conflicts.append(f"pooled GRF overflow: {grf_total} > {cgra.grf}")
        implicated.update(ri for ri, res in enumerate(results)
                          if res.report is not None
                          and res.report.grf_peak > 0)

    return ArbiterReport(not conflicts, conflicts, advisory,
                         implicated, advisory_implicated)


def merge_mappings(regions: list[Region], results: list[MappingResult],
                   ) -> tuple[ScheduledDFG, dict[int, Vertex]]:
    """Disjoint-union the per-region scheduled DFGs and placements into
    one global binding (ops renumbered, coordinates translated).

    The returned pair is exactly what ``validate_mapping`` consumes, so
    the existing validator replays the merged binding unchanged."""
    iis = {r.ii for r in results}
    assert len(iis) == 1
    ii = iis.pop()
    merged = DFG()
    time: dict[int, int] = {}
    delivery: dict[int, str] = {}
    ports: dict[int, int] = {}
    placement: dict[int, Vertex] = {}
    for region, res in zip(regions, results):
        sched = res.sched
        assert sched is not None
        idmap: dict[int, int] = {}
        for oid in sorted(sched.dfg.ops):
            op = sched.dfg.ops[oid]
            idmap[oid] = merged.add_op(op.kind, name=op.name,
                                       latency=op.latency)
        # Clone groups renumber in a second pass: a group's anchor VIO
        # references itself, so its id may not precede it in the map.
        for oid, op in sched.dfg.ops.items():
            if op.clone_of >= 0:
                merged.ops[idmap[oid]].clone_of = idmap[op.clone_of]
        for e in sched.dfg.edges:
            merged.add_edge(idmap[e.src], idmap[e.dst], distance=e.distance)
        for oid, t in sched.time.items():
            time[idmap[oid]] = t
        for oid, d in sched.delivery.items():
            delivery[idmap[oid]] = d
        for oid, q in sched.ports_allocated.items():
            ports[idmap[oid]] = q
        for oid, v in res.placement.items():
            placement[idmap[oid]] = region.translate_vertex(
                v, op=idmap[oid])
    merged_sched = ScheduledDFG(
        merged, ii, max((r.mii for r in results), default=1),
        time, delivery, ports)
    return merged_sched, placement
