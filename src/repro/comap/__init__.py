"""Multi-kernel co-mapping: partition a PEA into rectangular regions,
map one DFG per region at a common II, arbitrate the bus scopes regions
share, and replay the merged binding through the global validator."""

from .arbiter import ArbiterReport, arbitrate, merge_mappings
from .comap import CoMapResult, co_map
from .regions import Region, partition

__all__ = [
    "ArbiterReport", "arbitrate", "merge_mappings",
    "CoMapResult", "co_map", "Region", "partition",
]
