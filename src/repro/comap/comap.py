"""Multi-kernel co-mapping driver.

``co_map`` places several DFGs on one PEA concurrently:

1. **Layout** — `regions.partition` slices the array into rectangular
   regions, area-proportional to the kernels' op counts (HeLEx-style
   spatial region layout, arXiv 2511.19366).
2. **Common-II region mapping** — every kernel is mapped inside its
   region view (``CGRAConfig.view``) at one shared II: modulo slots of
   co-resident kernels must mean the same cycle for the shared buses to
   be arbitrable at all.  The search starts at the largest per-region
   MII and escalates.  Each region run *is* a full `bandmap.map_dfg`
   pipeline — conflict-graph build, `certify` pre-pass,
   `PortfolioSBTS` harvest rounds — and yields a regular
   ``MappingResult``; a co-mapping round batches those engines over all
   regions before any global work happens.
3. **Arbitration** — `arbiter.arbitrate` cross-checks the regions'
   fixed port/bus-cell claims and the pooled GRF budget; clashing
   regions are re-mapped with diversified seeds (the co-mapping
   analogue of the validation-retry re-arm).
4. **Merged replay** — `arbiter.merge_mappings` disjoint-unions the
   region bindings into one global ``ScheduledDFG`` + placement and the
   existing `core.validate.validate_mapping` replays it against the
   full-array config.  Only a validator-accepted merged binding is
   reported ok.
"""

from __future__ import annotations

import dataclasses
import time as _time

from repro.core.bandmap import MappingResult, map_dfg
from repro.core.cgra import CGRAConfig
from repro.core.conflict import Vertex
from repro.core.dfg import DFG
from repro.core.options import MapOptions
from repro.core.schedule import ScheduledDFG, mii
from repro.core.validate import ValidationReport, validate_mapping
from repro.core.workloads import op_weight
from repro.obs.flight import recording
from repro.obs.trace import live

from .arbiter import ArbiterReport, arbitrate, merge_mappings
from .regions import Region, partition


@dataclasses.dataclass
class CoMapResult:
    ok: bool
    ii: int                          # common II (-1 when nothing mapped)
    regions: list[Region]
    # Region-view configs the per-kernel runs were mapped against
    # (region rows/cols + the GRF share granted to each region).
    region_cfgs: list[CGRAConfig]
    results: list[MappingResult | None]   # per-kernel region mappings
    sched: ScheduledDFG | None       # merged schedule (ok runs)
    placement: dict[int, Vertex]     # merged global placement
    report: ValidationReport | None  # merged validator replay
    arbiter: ArbiterReport | None
    attempts: int                    # co-mapping rounds spent
    wall_s: float
    # Flight-recorder dump (see `repro.obs.flight`) attached to failed
    # runs mapped under a live recorder — same contract as
    # `MappingResult.flight`.
    flight: tuple = ()

    @property
    def n_kernels(self) -> int:
        return len(self.regions)

    def summary(self) -> str:
        per = ", ".join(
            f"{r}→{'∅' if res is None else res.summary().split(':')[1].strip()}"
            for r, res in zip(self.regions, self.results))
        return (f"comap: ok={self.ok} II={self.ii} "
                f"kernels={self.n_kernels} rounds={self.attempts} "
                f"[{per}]")


def co_map(dfgs: list[DFG], cgra: CGRAConfig,
           options: "MapOptions | dict | None" = None, *,
           rounds: int = 4, grf_split: bool = True, tracer=None,
           record=None, **kwargs) -> CoMapResult:
    """Co-map ``dfgs`` onto ``cgra``; see the module docstring.

    Mapping knobs take the same `MapOptions` / dict / legacy-keyword
    forms as `map_dfg` (``mode``, ``max_ii``, ``mis_restarts``,
    ``certify``, ...); each region run is a full `map_dfg` under those
    options with its II pinned to the common-II cursor and a
    region-diversified seed.  ``rounds`` (arbitration/validation
    retries per II before escalating) and ``grf_split`` (divide the
    global register file evenly among regions for the local runs — the
    pooled budget is re-checked by the arbiter and the merged replay
    either way) are co-mapping knobs, not `MapOptions` fields, so they
    stay true keyword arguments.  ``min_ii`` floors the common-II
    search (a caller pacing the kernels to an external rate passes the
    same floor it would pass to `map_dfg`).  ``tracer`` (default None)
    records per-region "comap-region" spans, "arbitrate"/"merge-replay"
    spans and the ``comap.arbitration_retries`` counter; see
    `repro.obs`.  ``record`` (default None) is the flight-recorder
    twin: "comap-round"/"comap-arbitrate" events land in the shared
    ring (each region run also records its own engine events into it),
    and a failed run returns with ``result.flight`` attached."""
    opts = MapOptions.coerce(options, kwargs)
    seed = opts.seed
    max_ii, min_ii = opts.schedule.max_ii, opts.schedule.min_ii
    trc = live(tracer)
    rec = recording(record)
    t0 = _time.perf_counter()
    k = len(dfgs)
    if k == 0:
        raise ValueError("co_map needs at least one DFG")
    regions = partition(cgra, [op_weight(d) for d in dfgs])
    grf_share = (cgra.grf // k) if grf_split else cgra.grf
    cfgs = [reg.config(cgra, grf=grf_share) for reg in regions]
    start_ii = max(min_ii or 0,
                   max(mii(d, cfg) for d, cfg in zip(dfgs, cfgs)))

    results: list[MappingResult | None] = [None] * k
    attempts = 0
    last_arb: ArbiterReport | None = None
    last_report: ValidationReport | None = None
    last_merged: tuple[ScheduledDFG | None, dict] = (None, {})

    for ii_star in range(start_ii, max_ii + 1):
        results = [None] * k
        stale = set(range(k))
        for rnd in range(rounds):
            attempts += 1
            for i in sorted(stale):
                with trc.span("comap-region", region=i, round=rnd,
                              ii=ii_star) as sp:
                    results[i] = map_dfg(
                        dfgs[i], cfgs[i],
                        options=opts.replace(
                            min_ii=ii_star, max_ii=ii_star,
                            seed=seed + 131 * rnd + 17 * i),
                        tracer=tracer, record=record)
                    sp.set(ok=results[i].ok)
            rec.emit("comap-round", ii=ii_star, round=rnd,
                     ok_regions=sum(1 for r in results
                                    if r is not None and r.ok))
            if not all(r is not None and r.ok for r in results):
                # Some region cannot bind at this common II at all —
                # re-seeding the others cannot fix that; escalate.
                break
            with trc.span("arbitrate", round=rnd, ii=ii_star) as asp:
                arb = arbitrate(regions, results, cgra)
                asp.set(ok=arb.ok)
            rec.emit("comap-arbitrate", ii=ii_star, round=rnd,
                     ok=arb.ok)
            last_arb = arb
            if not arb.ok:
                trc.count("comap.arbitration_retries")
                stale = set(arb.implicated)
                continue
            with trc.span("merge-replay", ii=ii_star):
                merged_sched, placement = merge_mappings(regions,
                                                         results)
                report = validate_mapping(merged_sched, cgra, placement)
            last_report = report
            last_merged = (merged_sched, placement)
            if report.ok:
                return CoMapResult(
                    ok=True, ii=ii_star, regions=regions,
                    region_cfgs=cfgs, results=results,
                    sched=merged_sched, placement=placement,
                    report=report, arbiter=arb, attempts=attempts,
                    wall_s=_time.perf_counter() - t0)
            # Merged validation failed on capacity the fixed claims
            # could not see (global bus packing): re-map the regions the
            # advisory overlaps implicate, or everyone as a last resort.
            stale = set(arb.advisory_implicated) or set(range(k))

    merged_sched, placement = last_merged
    flight: tuple = ()
    if record is not None:
        flight = record.dump()
    return CoMapResult(
        ok=False,
        ii=next((r.ii for r in results if r is not None), -1),
        regions=regions, region_cfgs=cfgs, results=results,
        sched=merged_sched, placement=placement, report=last_report,
        arbiter=last_arb, attempts=attempts,
        wall_s=_time.perf_counter() - t0, flight=flight)
