"""Rectangular region layout for multi-kernel co-mapping.

A :class:`Region` is an axis-aligned block of the PEA.  Each co-resident
kernel is mapped inside one region as if the region were a standalone
CGRA (``CGRAConfig.view``), then every placement coordinate is translated
back to the global array:

- a region's local row ``r`` is global row ``r0 + r`` — so a local IPORT
  tuple claims the *global* input port (and IBUS) of that row;
- local column ``c`` is global column ``c0 + c`` — local OPORT/OBUS
  claims translate the same way;
- a local PE ``(r, c)`` is the global PE ``(r0 + r, c0 + c)``.

Because regions are contiguous blocks, every single-hop relation the
conflict rules reason about is preserved by translation: same-PE stays
same-PE, same-local-row is same-global-row, and a local NSEW neighbour
is a global neighbour.  What translation does NOT preserve is
*exclusivity* of row/column buses and ports — two regions side by side
share the rows they span (one above the other share columns).  Those
shared scopes are exactly what `comap.arbiter` arbitrates and what the
merged replay through `core.validate` re-checks globally.

The partitioner is a deterministic guillotine split: the kernel list is
divided into two weight-balanced halves, the rectangle is cut across its
longer axis proportionally to the halves' weights, and each half recurses
into its sub-rectangle.  Weights are op counts (see
`core.workloads.op_weight`), clamped so every kernel receives at least a
1x1 region.
"""

from __future__ import annotations

import dataclasses

from repro.core.cgra import CGRAConfig
from repro.core.conflict import QUAD, TIN, TOUT, Vertex
from repro.core.tec import COL, ROW


@dataclasses.dataclass(frozen=True)
class Region:
    """An axis-aligned ``rows x cols`` block anchored at ``(r0, c0)``."""
    r0: int
    c0: int
    rows: int
    cols: int

    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    @property
    def row_span(self) -> range:
        return range(self.r0, self.r0 + self.rows)

    @property
    def col_span(self) -> range:
        return range(self.c0, self.c0 + self.cols)

    def config(self, base: CGRAConfig, *,
               grf: int | None = None) -> CGRAConfig:
        """Local CGRA view of this region (see ``CGRAConfig.view``)."""
        return base.view(self.rows, self.cols, grf=grf)

    def overlaps(self, other: "Region") -> bool:
        return not (self.r0 + self.rows <= other.r0
                    or other.r0 + other.rows <= self.r0
                    or self.c0 + self.cols <= other.c0
                    or other.c0 + other.cols <= self.c0)

    # ------------------------------------------------------- translation
    def to_global_pe(self, pe: tuple[int, int]) -> tuple[int, int]:
        return (pe[0] + self.r0, pe[1] + self.c0)

    def translate_vertex(self, v: Vertex, op: int | None = None) -> Vertex:
        """Local placement vertex -> global coordinates.

        ``op`` optionally renumbers the op id (the merged DFG re-ids ops
        so kernels stay disjoint).  The vertex ``idx`` is meaningless
        outside its local conflict graph and is dropped to -1."""
        kw = dict(idx=-1, op=v.op if op is None else op)
        if v.kind == TIN:
            kw["port"] = v.port + self.r0
        elif v.kind == TOUT:
            kw["port"] = v.port + self.c0
        elif v.kind == QUAD:
            kw["pe"] = self.to_global_pe(v.pe)
            if v.drive is not None:
                scope, idx = v.drive
                kw["drive"] = (scope, idx + self.r0 if scope == ROW
                               else idx + self.c0)
        return dataclasses.replace(v, **kw)

    def __str__(self) -> str:
        return (f"[{self.r0}:{self.r0 + self.rows}, "
                f"{self.c0}:{self.c0 + self.cols}]")


def partition(cgra: CGRAConfig, weights: list[float]) -> list[Region]:
    """Deterministic guillotine partition of the PEA into one region per
    weight, areas roughly proportional to the weights.

    Returns regions in the same order as ``weights``.  Raises when the
    array cannot give every kernel at least one PE."""
    k = len(weights)
    if k == 0:
        return []
    if k > cgra.n_pes:
        raise ValueError(f"{k} kernels cannot share {cgra.n_pes} PEs")
    weights = [max(float(w), 1.0) for w in weights]
    out: list[Region | None] = [None] * k

    def split(r0: int, c0: int, rows: int, cols: int,
              items: list[tuple[int, float]]) -> None:
        if len(items) == 1:
            out[items[0][0]] = Region(r0, c0, rows, cols)
            return
        # Weight-balanced bipartition of the (order-preserved) item list.
        total = sum(w for _, w in items)
        acc, cut = 0.0, 1
        for i, (_, w) in enumerate(items[:-1]):
            acc += w
            cut = i + 1
            if acc >= total / 2:
                break
        left, right = items[:cut], items[cut:]
        frac = sum(w for _, w in left) / total
        if rows >= cols:
            # Cut across rows, proportional to the halves' weights but
            # clamped so each side can still host its kernel count.
            lo = -(-len(left) // cols)
            hi = rows - (-(-len(right) // cols))
            if lo > hi:
                raise ValueError("partition: kernels outnumber rows")
            r_left = min(max(int(round(rows * frac)), lo, 1),
                         max(hi, 1), rows - 1)
            split(r0, c0, r_left, cols, left)
            split(r0 + r_left, c0, rows - r_left, cols, right)
        else:
            lo = -(-len(left) // rows)
            hi = cols - (-(-len(right) // rows))
            if lo > hi:
                raise ValueError("partition: kernels outnumber columns")
            c_left = min(max(int(round(cols * frac)), lo, 1),
                         max(hi, 1), cols - 1)
            split(r0, c0, rows, c_left, left)
            split(r0, c0 + c_left, rows, cols - c_left, right)

    split(0, 0, cgra.rows, cgra.cols, list(enumerate(weights)))
    regions = [r for r in out if r is not None]
    assert len(regions) == k
    return regions
