"""Admission + batching of concurrent mapping requests.

A batch of `MapRequest`s is served in four stages:

1. **Admission** — requests are ordered by (deadline, arrival): the
   earliest deadline is looked up, deduped and dispatched first, so
   under a loaded worker pool tight-deadline requests start earliest.
2. **Cache** — each request's canonical form is looked up in the
   `MappingCache`; hits (positive, validator-replayed, or soundly
   negative) resolve immediately.  Cache misses then pass the *static
   admission check* (`repro.analysis.static_infeasibility`): a request
   whose (DFG, fabric, options) is statically proven unmappable over
   its whole II range resolves right here with
   ``source="static_reject"`` — a certificate-backed negative that is
   also stored, so every later isomorphic request is a negative cache
   hit.  The check runs on the calling thread (it is microseconds) and
   the worker pool is never touched.  Tenant-tagged requests skip the
   cache and dedupe: co-residency asks for a *joint* placement with
   the batch's co-tenants, which no cached solo placement satisfies,
   and two isomorphic kernels of one tenant are distinct co-resident
   instances, not duplicates.
3. **Dedupe + grouping** — missing requests with the same cache key
   collapse onto one *leader* computation (followers resolve from the
   cache right after the leader lands — each follower still gets its
   own relabeled, validator-replayed copy).  Requests sharing a
   non-``None`` ``tenant``, the same fabric and the same options are
   co-tenants: groups of two or more are batched into one
   `comap.co_map` region run and placed on the array *together* (each
   kernel in its own rectangular region at one common II); a tenant
   alone in its batch is effectively solo, so it is cache-looked-up
   and mapped like any other request.  Co-mapped region results are
   not cached (their region shape depends on the whole group; a failed
   group run falls everyone back to cached solo maps, since
   region-locally-ok placements of a failed run still clash on shared
   scopes).
4. **Dispatch** — remaining independent leaders run `map_dfg` across a
   thread pool, with per-request seed diversification (two identical
   budgets don't retrace the same portfolio trajectories).  Workers
   only run the pure mapper; all cache traffic stays on the calling
   thread, so the cache needs no locking.  Options flow to `map_dfg`
   verbatim, so ``options={"backend": "race"}`` races the exact prover
   against the portfolio per request (`repro.exact.race`): exact SAT
   winners land in the cache as proven-``optimal`` positives, and
   exact UNSAT winners (``proved_infeasible``) are admitted as
   certificate-backed negative entries that short-circuit every
   isomorphic request from then on (`serve.cache`).

The scheduler is synchronous per batch — `run` returns when every
request has an outcome — which is what the benchmark loop and the
`MappingService` facade want; a long-lived server loops over batches.

Observability: a scheduler-level flight recorder (``record=``)
receives the serve-admit / serve-reject / serve-crash event stream;
every dispatched worker additionally runs under its *own* per-request
`FlightRecorder`, so a failed or crashed map returns with
``result.flight`` attached without interleaving batch-mates.  A
digest-keyed head sampler (``sample=``, a ``digest -> tracer-or-None``
callable) attaches live tracers to a deterministic subset of requests;
both default to ``None``/off, keeping dispatch outcomes bit-identical.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time as _time
from concurrent.futures import ThreadPoolExecutor, as_completed

from repro.analysis import static_infeasibility
from repro.core.bandmap import MappingResult, map_dfg
from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG
from repro.core.options import MapOptions
from repro.core.validate import validate_mapping
from repro.obs.flight import FlightRecorder, recording

from .cache import MappingCache
from .canon import (CanonicalForm, canonical_dfg, canonical_form,
                    relabel_result)


@dataclasses.dataclass
class MapRequest:
    """One mapping request.  ``options`` is forwarded to `map_dfg`
    verbatim (mode, budgets, knobs) and participates in the cache key.
    ``deadline`` orders admission (smaller = sooner; same unit as the
    caller likes — only the order matters).  Requests sharing a
    ``tenant`` ask to be co-resident on the fabric and are batched into
    one co-mapping run."""
    dfg: DFG
    cgra: CGRAConfig
    options: dict = dataclasses.field(default_factory=dict)
    deadline: float = math.inf
    tenant: str | None = None
    seed: int | None = None
    req_id: str = ""


@dataclasses.dataclass
class ServeOutcome:
    req_id: str
    result: MappingResult
    hit: bool
    source: str          # memory | disk | negative-* | dedupe | computed
    #                    # | comap | static_reject | crash
    # Serve-side latency: batch admission -> this request resolved,
    # queue wait included (NOT just the mapper's internal wall time).
    wall_s: float
    canon_digest: str

    @property
    def ok(self) -> bool:
        return self.result.ok


class RequestScheduler:
    """See module docstring."""

    def __init__(self, cache: MappingCache, *,
                 max_workers: int | None = None,
                 base_seed: int = 0,
                 record=None, sample=None,
                 flight_capacity: int = 256) -> None:
        self.cache = cache
        # Scheduler-level flight recorder (``None`` = off): receives the
        # serve-admit / serve-reject / serve-crash stream for every
        # batch this scheduler runs.  Distinct from the *per-request*
        # recorders `run` creates for dispatched workers — a request's
        # failure dump must not interleave with its batch-mates'.
        self.record = record
        # Head sampler: callable ``digest -> tracer-or-None`` (the
        # service wires `obs.expo.head_sample` through this).  ``None``
        # keeps dispatch bit-identical to the unsampled scheduler.
        self.sample = sample
        self.flight_capacity = flight_capacity
        # The numpy portfolio is GIL-heavy python+numpy: oversubscribing
        # cores slows every in-flight map and inflates tail latency, so
        # the default pool matches the machine.  Requests running the
        # device engine (``engine="device"``) spend their portfolio wall
        # inside XLA dispatches that release the GIL but contend for the
        # same cores (interpret mode) or the one accelerator — a
        # device-heavy deployment should size the pool toward 1-2
        # workers and lean on the engine's K-way internal parallelism
        # instead of pool-level concurrency.
        self.max_workers = max_workers if max_workers is not None \
            else max(1, min(os.cpu_count() or 1, 8))
        self.base_seed = base_seed

    # ------------------------------------------------------------- run
    def run(self, requests: list[MapRequest]) -> list[ServeOutcome]:
        n = len(requests)
        canons: list[CanonicalForm] = [None] * n
        effs: list[MapOptions] = [None] * n
        outcomes: list[ServeOutcome | None] = [None] * n
        order = sorted(range(n),
                       key=lambda i: (requests[i].deadline, i))
        # Serve-side latency = batch admission -> this request resolved
        # (queue wait included — a fast map behind a long queue is a
        # slow request).
        t_batch = _time.perf_counter()
        rec = recording(self.record)

        def resolve(i: int, result, *, hit: bool, source: str) -> None:
            outcomes[i] = ServeOutcome(
                requests[i].req_id, result, hit=hit, source=source,
                wall_s=_time.perf_counter() - t_batch,
                canon_digest=canons[i].digest)

        def resolve_hit(i: int, cache_hit, *, dedupe: bool) -> None:
            src = "dedupe" if dedupe else cache_hit.source
            if cache_hit.negative:
                src = f"negative-{src}"
                rec.emit("serve-reject", digest=canons[i].digest,
                         reason="negative-cache")
            resolve(i, cache_hit.result, hit=True, source=src)

        def resolve_static(i: int, neg) -> None:
            rec.emit("serve-reject", digest=canons[i].digest,
                     reason="static")
            resolve(i, neg, hit=False, source="static_reject")

        # Stage 2: cache lookups in admission order.  Tenant-tagged
        # requests skip the cache *and* dedupe here: co-residency asks
        # for a joint placement with the batch's co-tenants — a cached
        # solo full-array placement would overlap theirs, and two
        # isomorphic kernels of one tenant are distinct co-resident
        # instances, not duplicates.  (A tenant that turns out to be
        # alone in the batch is looked up in stage 3b instead.)
        pending: list[int] = []
        for i in order:
            canons[i] = canonical_form(requests[i].dfg)
            # Effective options — the seed resolved (pinned or digest-
            # derived) — are what the mapper will actually run under,
            # so they are also what the cache must key on: a negative
            # entry proven under seed 7 must never answer a request
            # that would have run under another seed.
            effs[i] = self._solo_options(requests[i], canons[i])
            if requests[i].tenant is not None:
                pending.append(i)
                continue
            hit = self.cache.lookup(canons[i], requests[i].cgra,
                                    effs[i])
            if hit is not None:
                resolve_hit(i, hit, dedupe=False)
                continue
            neg = self._static_reject(requests[i], canons[i], effs[i])
            if neg is not None:
                resolve_static(i, neg)
            else:
                pending.append(i)

        # Stage 3a: in-flight dedupe by cache key (leader = earliest
        # deadline, since ``pending`` is in admission order) — distinct
        # pinned seeds mean distinct keys, so they never collapse.
        # Tenant requests are not deduped (see above) — they route
        # straight to the co-tenant buckets (grouped by raw options:
        # co-residency should not split on seed).
        by_key: dict[str, list[int]] = {}
        by_tenant: dict[tuple, list[int]] = {}
        for i in pending:
            r = requests[i]
            if r.tenant is not None:
                # Canonical digest excluded: co-tenancy is about
                # sharing the fabric, not about being isomorphic.  The
                # seed is excluded too — `_co_run` runs the group under
                # one seed anyway, and a pinned seed must not split a
                # tenant's kernels into overlapping solo placements.
                tkey = (r.tenant, self.cache.key(
                    _FABRIC_ONLY, r.cgra,
                    {k: v for k, v in r.options.items() if k != "seed"}))
                by_tenant.setdefault(tkey, []).append(i)
                continue
            key = self.cache.key(canons[i], r.cgra, effs[i])
            by_key.setdefault(key, []).append(i)
        leaders = [idxs[0] for idxs in by_key.values()]
        followers = {idxs[0]: idxs[1:] for idxs in by_key.values()}

        # Stage 3b: co-tenant groups of >= 2 become `co_map` runs.  A
        # tenant alone in its batch has nothing to be co-resident with,
        # so it is effectively solo — which also makes a cached solo
        # placement sound to reuse; look it up now (stage 2 skipped it).
        co_groups: list[list[int]] = []
        solo: list[int] = list(leaders)
        for idxs in by_tenant.values():
            if len(idxs) >= 2:
                co_groups.append(idxs)
                continue
            i = idxs[0]
            hit = self.cache.lookup(canons[i], requests[i].cgra,
                                    effs[i])
            if hit is not None:
                resolve_hit(i, hit, dedupe=False)
                continue
            neg = self._static_reject(requests[i], canons[i], effs[i])
            if neg is not None:
                resolve_static(i, neg)
            else:
                solo.append(i)
        solo.sort(key=lambda i: (requests[i].deadline, i))

        # Stage 4: dispatch.  Futures are submitted in deadline order
        # and collected as they complete, so a request never waits on
        # unrelated work: each dedupe follower resolves (replay of the
        # leader's entry — relabeled onto its own DFG and validator-
        # replayed) the moment its leader lands, not when the whole
        # pool drains.  When the leader's result was uncacheable
        # (heuristic failure) or rejected on replay, followers share
        # the leader's in-hand result directly — identical key means
        # identical canonical input and options, so a rerun would
        # reproduce it bit-for-bit.
        with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            crash_ctx: dict[object, tuple[int, FlightRecorder]] = {}

            def submit_solo(i: int):
                # Map the *canonical* copy: bit-identical input and a
                # digest-derived seed make the whole run a function of
                # structure + options — see `canon.canonical_dfg`.
                # Every dispatched worker runs under its own flight
                # recorder (a request's failure dump must not
                # interleave with its batch-mates'); the per-digest
                # head sampler decides whether it also gets a tracer.
                rec.emit("serve-admit", digest=canons[i].digest,
                         tenant=requests[i].tenant)
                req_rec = FlightRecorder(self.flight_capacity)
                tracer = self.sample(canons[i].digest) \
                    if self.sample is not None else None
                fut = pool.submit(
                    map_dfg, canonical_dfg(requests[i].dfg, canons[i]),
                    requests[i].cgra, effs[i],
                    tracer=tracer, record=req_rec)
                crash_ctx[fut] = (i, req_rec)
                return fut

            futs = {submit_solo(i): ("solo", i) for i in solo}
            futs.update(
                (pool.submit(self._co_run, requests, idxs),
                 ("comap", idxs)) for idxs in co_groups)
            fallback_futs: dict[object, int] = {}

            def resolve_computed(i: int, res) -> None:
                """``res`` is canonically-labeled: store as-is, then
                relabel onto the request's own ids (re-validated, so
                the released binding is validator-accepted under the
                ids the caller sees)."""
                self.cache.store(canons[i], requests[i].cgra,
                                 effs[i], res, canonical=True)
                inv = {ci: oid
                       for oid, ci in canons[i].canon_of.items()}
                out = relabel_result(res, inv)
                if out.ok and out.sched is not None:
                    out = dataclasses.replace(out, report=validate_mapping(
                        out.sched, requests[i].cgra, out.placement))
                resolve(i, out, hit=False, source="computed")
                for j in followers.pop(i, ()):
                    hit = self.cache.lookup(canons[j], requests[j].cgra,
                                            effs[j])
                    if hit is not None:
                        resolve_hit(j, hit, dedupe=True)
                        continue
                    # Leader's entry was uncacheable (heuristic
                    # failure) or rejected on replay.  The follower
                    # shares the leader's key — identical canonical
                    # input and effective options — so a rerun would
                    # reproduce ``res`` bit-for-bit; share the in-hand
                    # result instead of burning another full map.
                    inv_j = {ci: oid
                             for oid, ci in canons[j].canon_of.items()}
                    out_j = relabel_result(res, inv_j)
                    if out_j.ok and out_j.sched is not None:
                        out_j = dataclasses.replace(
                            out_j, report=validate_mapping(
                                out_j.sched, requests[j].cgra,
                                out_j.placement))
                    resolve(j, out_j, hit=False, source="dedupe")

            for fut in as_completed(list(futs)):
                tag, payload = futs[fut]
                if tag == "solo":
                    try:
                        res = fut.result()
                    except Exception as exc:
                        i, req_rec = crash_ctx[fut]
                        res = self._crash_result(requests[i], effs[i],
                                                 req_rec, exc)
                        rec.emit("serve-crash", digest=canons[i].digest,
                                 error=type(exc).__name__)
                        resolve(i, res, hit=False, source="crash")
                        # Followers share the crashed leader's result:
                        # an identical canonical input and options
                        # would reproduce the crash, not dodge it.
                        for j in followers.pop(i, ()):
                            resolve(j, res, hit=False, source="crash")
                        continue
                    resolve_computed(payload, res)
                    continue
                try:
                    pairs = fut.result()
                except Exception as exc:
                    # A crashed co-map run takes no kernel down with
                    # it: every group member falls back to a solo map
                    # (the same degradation path as an unplaced
                    # kernel).
                    rec.emit("serve-crash", digest="co-tenant",
                             error=type(exc).__name__)
                    for i in payload:
                        fallback_futs[submit_solo(i)] = i
                    continue
                for i, res in pairs:
                    if res is not None:
                        # Successful region results are NOT cached:
                        # they bind a region view whose shape depends
                        # on the whole group, and no lookup path
                        # carries a region config — a repeated group
                        # re-runs `co_map`.
                        resolve(i, res, hit=False, source="comap")
                    else:
                        # Unplaced kernel: its fallback solo map goes
                        # through the pool like any other computation.
                        fallback_futs[submit_solo(i)] = i
            for fut in as_completed(list(fallback_futs)):
                i = fallback_futs[fut]
                try:
                    res = fut.result()
                except Exception as exc:
                    _, req_rec = crash_ctx[fut]
                    rec.emit("serve-crash", digest=canons[i].digest,
                             error=type(exc).__name__)
                    resolve(i, self._crash_result(requests[i], effs[i],
                                                  req_rec, exc),
                            hit=False, source="crash")
                    continue
                resolve_computed(i, res)
        return outcomes

    # --------------------------------------------------------- helpers
    def _crash_result(self, req: MapRequest, eff: MapOptions,
                      req_rec: FlightRecorder,
                      exc: BaseException) -> MappingResult:
        """Synthetic ``ok=False`` outcome for a worker that raised.

        Carries the request's flight dump (postmortem, capped with a
        terminal "serve-crash" event) and ``attempts=1`` with no
        certificates — deliberately failing the cache's sound-negative
        admission rule, so a crash is never stored as a proof and an
        isomorphic retry gets a fresh run."""
        req_rec.emit("serve-crash", error=type(exc).__name__,
                     detail=str(exc)[:200])
        return MappingResult(
            ok=False, mode=eff.mode, ii=-1, mii=0, n_routing_pes=0,
            ports_per_vio={}, placement={}, sched=None, report=None,
            cg_size=(0, 0), mis_size=0, n_ops=len(req.dfg.ops),
            attempts=1, wall_s=0.0, flight=req_rec.dump())

    def _static_reject(self, req: MapRequest, canon: "CanonicalForm",
                       eff: MapOptions) -> MappingResult | None:
        """Static admission check on a cache miss (calling thread —
        the analyzer is schedule-free structure scanning).  A verdict
        is stored under the canonical key first — the sound negative
        `cache.store` admits (``attempts == 0`` + certificates +
        ``proved_infeasible``) — then relabeled onto the request's own
        ids for the outcome."""
        res = static_infeasibility(
            canonical_dfg(req.dfg, canon), req.cgra,
            mode=eff.mode,
            max_ii=eff.schedule.max_ii,
            min_ii=eff.schedule.min_ii,
            max_bus_fanout=eff.schedule.max_bus_fanout)
        if res is None:
            return None
        self.cache.store(canon, req.cgra, eff, res, canonical=True)
        inv = {ci: oid for oid, ci in canon.canon_of.items()}
        return relabel_result(res, inv)

    def _solo_options(self, req: MapRequest,
                      canon: CanonicalForm) -> MapOptions:
        """Per-request seed diversification: a pinned seed (in options
        or on the request) wins; otherwise the seed derives from the
        canonical digest — distinct problems explore distinct portfolio
        trajectories, while isomorphic requests reproduce the same run
        (which is what lets their results be shared soundly)."""
        if isinstance(req.options, MapOptions):
            # Structured options carry an explicit seed — pinned.
            return req.options
        eff = MapOptions.coerce(req.options)
        if "seed" not in req.options:
            eff = eff.replace(
                seed=req.seed if req.seed is not None else
                (self.base_seed + int(canon.digest[:8], 16)) % (1 << 31))
        return eff

    def _co_run(self, requests: list[MapRequest], idxs: list[int]
                ) -> list[tuple[int, MappingResult | None]]:
        """One co-tenant group -> one `co_map` region run.  Returns
        (request idx, result-or-None) pairs: a result binds the
        kernel's *region view* (`CoMapResult.region_cfgs`) in global
        fabric coordinates; ``None`` means the kernel was not jointly
        placed and the caller submits its fallback solo map through the
        pool (workers here only run the co-mapper itself)."""
        from repro.comap import co_map

        lead = requests[idxs[0]]
        cgra = lead.cgra
        raw = dict(lead.options)
        # ``rounds`` / ``grf_split`` are co-mapping knobs, not
        # `MapOptions` fields — they ride the option dict on the wire
        # and peel off here ("rounds" is not a mapping knob name, so
        # the single-source lint rule does not apply).
        co_kw = {k: raw.pop(k) for k in ("rounds", "grf_split")
                 if k in raw}
        eff = MapOptions.coerce(raw)
        if "seed" not in raw:
            # Same precedence as solo requests: options seed, then the
            # request-level pinned seed, then the scheduler default.
            eff = eff.replace(seed=lead.seed if lead.seed is not None
                              else self.base_seed)
        cm = co_map([requests[i].dfg for i in idxs], cgra,
                    options=eff, **co_kw)
        out: list[tuple[int, MappingResult | None]] = []
        for j, i in enumerate(idxs):
            # A region result is only a *joint* placement when the whole
            # co-map succeeded (arbitration + merged validator replay);
            # after a failed run, region-locally-ok results still clash
            # on shared scopes, so every kernel falls back.
            res = cm.results[j] if cm.ok else None
            if res is None or not res.ok:
                out.append((i, None))
            else:
                # Region runs place in region-local coordinates;
                # translate to the shared fabric so co-resident
                # outcomes are directly comparable (disjoint PEs,
                # global ports).
                out.append((i, dataclasses.replace(res, placement={
                    oid: cm.regions[j].translate_vertex(v)
                    for oid, v in res.placement.items()})))
        return out


class _FabricSentinel:
    """Stands in for a canonical form in co-tenant group keys (only the
    fabric + options fingerprints matter there)."""
    digest = "co-tenant"
    blob = b""
    canon_of: dict[int, int] = {}
    op_of: list[int] = []


_FABRIC_ONLY = _FabricSentinel()
