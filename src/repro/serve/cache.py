"""Two-tier mapping cache: in-memory LRU over an on-disk artifact store.

Entries are keyed by (canonical DFG digest, `CGRAConfig` fingerprint,
mapping-option fingerprint) — a mapping is only reusable for the exact
fabric and the exact `map_dfg` knob set it was produced under (search
budgets change what an ``ok=False`` result means, `max_bus_fanout`
changes the schedule itself).  Nothing is reused across different
`CGRAConfig`s: even a row/column-swapped fabric yields a different
fingerprint and therefore a different entry.

Stored values are full `MappingResult`s *relabeled into canonical op
ids* (`serve.canon.relabel_result`), positive or negative:

- **positive** — a validated binding.  On a hit the placement is
  relabeled onto the requesting DFG's op ids and **replayed through
  `core.validate.validate_mapping` before release**: the validator
  stays the single soundness authority, the cache never vouches for a
  binding itself.  A replay rejection evicts the entry and reports a
  miss (the service then maps from scratch).
- **negative** — an ``ok=False`` result, stored **only when it is a
  proof**: either ``attempts == 0`` with certificates attached (every
  (II, jitter) schedule explored was *proven* unbindable by
  `core.certify` before any stochastic search ran) or
  ``proved_infeasible`` (the exact backend, `repro.exact`, certified
  every combination up to ``max_ii`` — the race path's UNSAT winners
  carry this flag even though the losing portfolio spent attempts in
  parallel).  A heuristic failure (portfolio budget exhausted under
  one seed) is never stored: a different seed might succeed, so
  caching it would mask feasible mappings.  Negative hits
  short-circuit the whole pipeline.  Their
  guarantee: a hit requires byte-equal canonical ``blob``s (request
  isomorphic to the cached problem), and the serving scheduler maps
  the *canonical* DFG copy with a digest-derived seed
  (`serve.canon.canonical_dfg`), so an isomorphic request would
  deterministically reproduce the exact schedules the certificates
  cover — jittered schedules are seed- and labeling-dependent, which
  is why determinism, not the certificates alone, carries the
  cross-request claim.

The disk tier (``art_dir``; `serve.service.DEFAULT_ART_DIR` =
``artifacts/serve/`` is the conventional location, used by
``launch/serve.py --map-trace``) holds one pickle per entry via the
`MappingResult.to_bytes` hooks; in-memory evictions never delete disk
artifacts, so a warm restart repopulates from disk.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import time as _time
from collections import OrderedDict

from repro.core.bandmap import MappingResult
from repro.core.cgra import CGRAConfig
from repro.core.options import MapOptions
from repro.core.validate import validate_mapping

from .canon import CanonicalForm, relabel_result

ENTRY_VERSION = 1


def config_fingerprint(cgra: CGRAConfig) -> str:
    """Stable short fingerprint of every `CGRAConfig` field."""
    return hashlib.sha256(
        repr(dataclasses.astuple(cgra)).encode()).hexdigest()[:12]


def options_fingerprint(options) -> str:
    """Stable short fingerprint of the `map_dfg` options — a
    `MapOptions` instance or a legacy option dict.

    Delegates to `MapOptions.fingerprint`, whose sparse legacy-kwarg
    rendering reproduces this function's historical
    ``sha256(repr(sorted(dict.items())))[:12]`` byte-for-byte on every
    option dict the serving scheduler produced (request options + a
    resolved seed), so on-disk entries written before the `MapOptions`
    migration still hit."""
    return MapOptions.coerce(options).fingerprint()


@dataclasses.dataclass
class CacheEntry:
    blob: bytes               # canonical form bytes (collision guard)
    result: MappingResult     # relabeled into canonical op ids
    negative: bool

    def to_bytes(self) -> bytes:
        return pickle.dumps(
            (ENTRY_VERSION, self.blob, self.negative,
             self.result.to_bytes()), protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(data: bytes) -> "CacheEntry":
        version, blob, negative, res = pickle.loads(data)
        if version != ENTRY_VERSION:
            raise ValueError(f"cache entry version {version} != "
                             f"{ENTRY_VERSION}")
        return CacheEntry(blob, MappingResult.from_bytes(res), negative)


@dataclasses.dataclass
class CacheStats:
    mem_hits: int = 0
    disk_hits: int = 0
    neg_hits: int = 0
    misses: int = 0
    replay_rejects: int = 0
    blob_mismatches: int = 0
    neg_uncacheable: int = 0   # heuristic failures refused by store()
    puts: int = 0
    evictions: int = 0
    replay_wall_s: float = 0.0

    @property
    def hits(self) -> int:
        return self.mem_hits + self.disk_hits

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return dict(vars(self), hits=self.hits, lookups=self.lookups,
                    hit_rate=round(self.hit_rate, 4))


@dataclasses.dataclass
class CacheHit:
    result: MappingResult     # relabeled onto the requesting DFG
    source: str               # 'memory' | 'disk'
    negative: bool


class MappingCache:
    """See module docstring.  ``capacity`` bounds the in-memory tier;
    ``art_dir=None`` disables the disk tier entirely."""

    def __init__(self, capacity: int = 256,
                 art_dir: str | None = None) -> None:
        self.capacity = capacity
        self.art_dir = art_dir
        self._mem: OrderedDict[str, CacheEntry] = OrderedDict()
        self.stats = CacheStats()
        if art_dir:
            os.makedirs(art_dir, exist_ok=True)

    # ------------------------------------------------------------- keys
    @staticmethod
    def key(canon: CanonicalForm, cgra: CGRAConfig,
            options: "MapOptions | dict") -> str:
        return (f"{canon.digest[:32]}-{config_fingerprint(cgra)}-"
                f"{options_fingerprint(options)}")

    def _path(self, key: str) -> str:
        return os.path.join(self.art_dir, f"{key}.pkl")

    # ---------------------------------------------------------- lookup
    def lookup(self, canon: CanonicalForm, cgra: CGRAConfig,
               options: "MapOptions | dict") -> CacheHit | None:
        """Return a validated (or soundly-negative) hit, else None.

        Every positive hit is replayed through the validator before
        release; a rejected replay evicts the entry and counts as a
        miss."""
        key = self.key(canon, cgra, options)
        source = "memory"
        entry = self._mem.get(key)
        if entry is not None:
            self._mem.move_to_end(key)
        elif self.art_dir and os.path.exists(self._path(key)):
            try:
                with open(self._path(key), "rb") as f:
                    entry = CacheEntry.from_bytes(f.read())
            except Exception:
                # Unreadable artifact (version skew, torn concurrent
                # write, plain corruption — unpickling garbage can
                # raise nearly anything): a miss, never a crash.
                entry = None
            if entry is not None:
                source = "disk"
                self._insert_mem(key, entry)
        if entry is None:
            self.stats.misses += 1
            return None
        if entry.blob != canon.blob:
            # Digest collision or a non-automorphic WL tie: the request
            # is NOT isomorphic to the stored problem.  Never reuse.
            self.stats.blob_mismatches += 1
            self.stats.misses += 1
            return None
        inv = {ci: oid for oid, ci in canon.canon_of.items()}
        res = relabel_result(entry.result, inv)
        if entry.negative:
            self.stats.neg_hits += 1
            if source == "memory":
                self.stats.mem_hits += 1
            else:
                self.stats.disk_hits += 1
            return CacheHit(res, source, negative=True)
        t0 = _time.perf_counter()
        report = validate_mapping(res.sched, cgra, res.placement)
        self.stats.replay_wall_s += _time.perf_counter() - t0
        if not report.ok:
            self.evict(key)
            self.stats.replay_rejects += 1
            self.stats.misses += 1
            return None
        if source == "memory":
            self.stats.mem_hits += 1
        else:
            self.stats.disk_hits += 1
        return CacheHit(dataclasses.replace(res, report=report), source,
                        negative=False)

    # ----------------------------------------------------------- store
    def store(self, canon: CanonicalForm, cgra: CGRAConfig,
              options: "MapOptions | dict", result: MappingResult, *,
              canonical: bool = False) -> str | None:
        """Store ``result`` under its canonical key; returns the key.

        ``canonical=True`` means the result was produced by mapping the
        canonically-relabeled DFG (`canon.canonical_dfg`) — the serving
        scheduler's path — and needs no relabeling on the way in;
        otherwise the result is for the request's own labeling and is
        relabeled through ``canon.canon_of``.

        Failed results are stored only when they are *proofs*: either
        certificate-backed fast-fails (``attempts == 0`` and
        certificates present — no stochastic search ever ran) or exact
        UNSAT results (``proved_infeasible`` — every (II, jitter)
        combination in range certified by the exact backend, which may
        well have spent validation attempts along the way; the race
        path, where the portfolio ran in parallel, lands here too).
        Heuristic failures are refused (returns None) and will be
        recomputed, possibly under a luckier seed."""
        if not result.ok and not result.proved_infeasible \
                and not (result.attempts == 0 and result.certificates):
            self.stats.neg_uncacheable += 1
            return None
        key = self.key(canon, cgra, options)
        id_map = {ci: ci for ci in range(canon.n)} if canonical \
            else canon.canon_of
        entry = CacheEntry(
            blob=canon.blob,
            result=relabel_result(result, id_map),
            negative=not result.ok)
        self._insert_mem(key, entry)
        if self.art_dir:
            # Per-process tmp name: concurrent services sharing an
            # art_dir must not truncate each other's in-flight writes;
            # os.replace keeps the install itself atomic.
            tmp = f"{self._path(key)}.{os.getpid()}.tmp"
            with open(tmp, "wb") as f:
                f.write(entry.to_bytes())
            os.replace(tmp, self._path(key))
        self.stats.puts += 1
        return key

    def evict(self, key: str) -> None:
        """Drop an entry from both tiers (replay rejection path)."""
        self._mem.pop(key, None)
        if self.art_dir:
            try:
                os.remove(self._path(key))
            except FileNotFoundError:
                pass

    def _insert_mem(self, key: str, entry: CacheEntry) -> None:
        self._mem[key] = entry
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self._mem)
