"""Isomorphism-invariant canonical DFG form for the serving cache.

Two mapping requests whose DFGs differ only by a vertex relabeling are
the *same* mapping problem: the conflict graph, the certificates and the
portfolio search all depend on structure alone.  This module turns a
`core.dfg.DFG` into a :class:`CanonicalForm` — a canonical vertex order,
a serialized canonical graph (``blob``) and its SHA-256 ``digest`` — so
the cache (`serve.cache`) can key mappings by structure and replay a
cached placement onto any isomorphic request.

Algorithm: Weisfeiler-Lehman colour refinement with individualization.

1. Initial colours from permutation-invariant op features: (kind,
   latency, clone-group flag).  VIO/VOO roles are part of ``kind``.
2. Refinement: each round, a vertex's signature is (own colour, sorted
   multiset of (predecessor colour, edge distance), sorted multiset of
   (successor colour, edge distance)); new colours are the ranks of the
   *sorted* distinct signatures, so colour values are themselves
   canonical and rounds compose permutation-invariantly.  Iterate until
   the partition stops splitting.
3. Individualization: while some colour class has > 1 member, give one
   member a fresh unique colour and re-refine.  WL ties in the DFG
   families served here are automorphisms (symmetric chains, stencil
   lanes, reduction subtrees), and individualizing *any* member of an
   automorphic class yields the identical canonical serialization — so
   the choice (lowest op id) does not leak the input labeling into the
   blob.  Should a tie ever be a non-automorphism (WL is incomplete),
   two permutations of one DFG could canonicalize differently: that
   costs a cache miss, never a wrong hit, because the cache compares
   full ``blob`` bytes before reusing an entry.

Equal ``blob`` bytes mean the two canonical forms are identical *as
labeled graphs*, so composing their relabeling maps is a true DFG
isomorphism — which is what makes negative (II-infeasibility) cache
hits sound, not just heuristic.
"""

from __future__ import annotations

import dataclasses
import hashlib

from repro.core.bandmap import MappingResult
from repro.core.dfg import DFG, Edge
from repro.core.schedule import ScheduledDFG

CANON_VERSION = 1


@dataclasses.dataclass
class CanonicalForm:
    """Canonical view of one DFG."""
    digest: str                 # sha256 hex of ``blob``
    blob: bytes                 # serialized canonical graph
    canon_of: dict[int, int]    # op id -> canonical index
    op_of: list[int]            # canonical index -> op id

    @property
    def n(self) -> int:
        return len(self.op_of)


def _refine(n: int, colors: list[int], in_adj: list[list[tuple[int, int]]],
            out_adj: list[list[tuple[int, int]]]) -> list[int]:
    """WL refinement to a stable partition.  Adjacency lists hold vertex
    *positions*; colours are read at signature time.  New colour values
    are ranks of the sorted distinct signatures, hence permutation-
    invariant at every round."""
    while True:
        sigs = []
        for v in range(n):
            sigs.append((
                colors[v],
                tuple(sorted((colors[u], d) for u, d in in_adj[v])),
                tuple(sorted((colors[u], d) for u, d in out_adj[v])),
            ))
        rank = {s: i for i, s in enumerate(sorted(set(sigs)))}
        new = [rank[s] for s in sigs]
        if len(rank) == len(set(colors)):
            return new
        colors = new


def canonical_form(dfg: DFG) -> CanonicalForm:
    """Compute the canonical form of ``dfg`` (see module docstring)."""
    ids = sorted(dfg.ops)
    n = len(ids)
    pos = {oid: i for i, oid in enumerate(ids)}
    in_adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    out_adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for e in dfg.edges:
        out_adj[pos[e.src]].append((pos[e.dst], e.distance))
        in_adj[pos[e.dst]].append((pos[e.src], e.distance))
    feats = sorted({(dfg.ops[o].kind.value, dfg.ops[o].latency,
                     dfg.ops[o].clone_of >= 0) for o in ids})
    frank = {f: i for i, f in enumerate(feats)}
    colors = [frank[(dfg.ops[o].kind.value, dfg.ops[o].latency,
                     dfg.ops[o].clone_of >= 0)] for o in ids]

    colors = _refine(n, colors, in_adj, out_adj)
    n_indiv = 0
    while len(set(colors)) < n:
        # Smallest tied colour; lowest-op-id member (automorphic ties
        # make the resulting blob independent of this choice).
        classes: dict[int, list[int]] = {}
        for v, c in enumerate(colors):
            classes.setdefault(c, []).append(v)
        c = min(k for k, vs in classes.items() if len(vs) > 1)
        v = min(classes[c], key=lambda w: ids[w])
        colors[v] = n + n_indiv      # fresh colour, unique by construction
        n_indiv += 1
        colors = _refine(n, colors, in_adj, out_adj)

    canon_of = {ids[v]: colors[v] for v in range(n)}
    op_of = [0] * n
    for oid, ci in canon_of.items():
        op_of[ci] = oid

    ops_part = []
    for ci in range(n):
        op = dfg.ops[op_of[ci]]
        clone = canon_of[op.clone_of] if op.clone_of in canon_of else -1
        ops_part.append((op.kind.value, op.latency, clone))
    edges_part = sorted((canon_of[e.src], canon_of[e.dst], e.distance)
                        for e in dfg.edges)
    blob = repr((CANON_VERSION, tuple(ops_part),
                 tuple(edges_part))).encode()
    return CanonicalForm(digest=hashlib.sha256(blob).hexdigest(),
                         blob=blob, canon_of=canon_of, op_of=op_of)


def canonical_hash(dfg: DFG) -> str:
    """Hex digest of the canonical form (convenience)."""
    return canonical_form(dfg).digest


def canonical_dfg(dfg: DFG, canon: CanonicalForm) -> DFG:
    """The canonically-relabeled copy of ``dfg``: op id = canonical
    index, ops inserted in canonical order, edges sorted.

    Two isomorphic requests with equal canonical ``blob``s produce
    *bit-identical* copies — same ids, same dict insertion order, same
    edge order — so every downstream stage (scheduling tie-breaks, RNG
    draws, certificate search) behaves identically.  The serving
    scheduler maps this copy instead of the request's own labeling:
    that determinism is what makes cached negative results sound for
    any isomorphic request (`serve.cache`)."""
    out = DFG()
    for ci in range(canon.n):
        op = dfg.ops[canon.op_of[ci]]
        out.ops[ci] = dataclasses.replace(
            op, op_id=ci,
            clone_of=canon.canon_of[op.clone_of]
            if op.clone_of in canon.canon_of else -1)
    out.edges = [Edge(*t) for t in sorted(
        (canon.canon_of[e.src], canon.canon_of[e.dst], e.distance)
        for e in dfg.edges)]
    out._next_id = canon.n
    return out


# --------------------------------------------------------------- relabel
def relabel_result(res: MappingResult, id_map: dict[int, int]
                   ) -> MappingResult:
    """Relabel a mapping result's op ids through ``id_map``.

    ``id_map`` must cover the ops of the *request* DFG the result was
    produced for; ops the scheduler added on top (VIO clones, routing
    ops) are assigned fresh ids past ``max(id_map.values())``, in sorted
    source-id order, so the relabeling is deterministic.  Everything op-
    keyed is remapped: the scheduled DFG (ops, clone groups, edges),
    schedule times, delivery modes, allocated ports, the placement (and
    each `Vertex.op`).  The stale `report` is dropped — the cache
    revalidates every replayed placement (`serve.cache` docstring)."""
    assert len(set(id_map.values())) == len(id_map), "id_map not injective"
    if res.sched is None:
        return dataclasses.replace(
            res, placement={}, report=None,
            ports_per_vio={id_map.get(k, k): v
                           for k, v in res.ports_per_vio.items()})
    full = dict(id_map)
    nxt = max(full.values(), default=-1) + 1
    for oid in sorted(res.sched.dfg.ops):
        if oid not in full:
            full[oid] = nxt
            nxt += 1
    d = DFG()
    for oid in sorted(res.sched.dfg.ops, key=lambda o: full[o]):
        op = res.sched.dfg.ops[oid]
        d.ops[full[oid]] = dataclasses.replace(
            op, op_id=full[oid],
            clone_of=full[op.clone_of] if op.clone_of >= 0 else -1)
    d.edges = [Edge(full[e.src], full[e.dst], e.distance)
               for e in res.sched.dfg.edges]
    d._next_id = nxt
    sched = ScheduledDFG(
        d, res.sched.ii, res.sched.mii,
        {full[k]: v for k, v in res.sched.time.items()},
        {full[k]: v for k, v in res.sched.delivery.items()},
        {full[k]: v for k, v in res.sched.ports_allocated.items()})
    placement = {full[k]: dataclasses.replace(v, op=full[k])
                 for k, v in res.placement.items()}
    return dataclasses.replace(
        res, sched=sched, placement=placement, report=None,
        ports_per_vio={full[k]: v for k, v in res.ports_per_vio.items()})
