"""Mapping-as-a-service layer over the BandMap engine.

`canon` — isomorphism-invariant canonical DFG hashing + relabel maps;
`cache` — two-tier (LRU + disk) mapping cache, validator-replayed hits;
`scheduler` — admission, dedupe, co-tenant batching, worker pool;
`service` — the `MappingService` facade + metrics.
"""

from .cache import CacheHit, CacheStats, MappingCache
from .canon import CanonicalForm, canonical_form, canonical_hash, \
    relabel_result
from .scheduler import MapRequest, RequestScheduler, ServeOutcome
from .service import DEFAULT_ART_DIR, MappingService

__all__ = [
    "CacheHit", "CacheStats", "MappingCache",
    "CanonicalForm", "canonical_form", "canonical_hash",
    "relabel_result",
    "MapRequest", "RequestScheduler", "ServeOutcome",
    "DEFAULT_ART_DIR", "MappingService",
]
