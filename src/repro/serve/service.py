"""`MappingService` — the mapping-as-a-service facade.

One object owns the canonical-form cache (`serve.cache`) and the
batching scheduler (`serve.scheduler`) and exposes two calls:

- ``map(dfg, cgra, **options)`` — one request, one outcome;
- ``map_batch(requests)``       — a wave of `MapRequest`s, outcomes in
  request order.

Invariant (inherited from the cache, restated here because callers see
this module): **every positive cache hit is replayed through
`core.validate.validate_mapping` before it is released** — the service
never returns a binding the validator has not accepted against the
requesting DFG's own op ids.  Negative hits short-circuit only when the
canonical blobs are byte-equal, i.e. when the request is provably
isomorphic to the DFG the infeasibility was established for.

The service keeps running metrics — per-request latency percentiles,
hit sources, throughput — which `launch/serve.py`,
`examples/serve_batch.py` and the ``serve`` benchmark section report.
"""

from __future__ import annotations

import threading
import time as _time
from collections import Counter

import numpy as np

from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG

from .cache import MappingCache
from .scheduler import MapRequest, RequestScheduler, ServeOutcome

DEFAULT_ART_DIR = "artifacts/serve"


class MappingService:
    """See module docstring.  ``art_dir=None`` keeps the cache purely
    in-memory (benchmarks, tests); pass `DEFAULT_ART_DIR` (or any path)
    to persist mappings across processes."""

    # Shared mutable metrics state: concurrent `map_batch` callers (the
    # facade is the natural thing to share across server threads) must
    # not interleave counter updates.  The tuple is the contract the
    # `lock-guarded-state` astlint rule enforces: these attributes are
    # only mutated under ``self._lock``.
    _lock_guarded = ("_latencies", "_sources", "_requests", "_hits",
                     "_ok", "_batch_wall_s")

    def __init__(self, *, cache: MappingCache | None = None,
                 capacity: int = 256, art_dir: str | None = None,
                 max_workers: int | None = None,
                 base_seed: int = 0) -> None:
        self.cache = cache if cache is not None else \
            MappingCache(capacity=capacity, art_dir=art_dir)
        self.scheduler = RequestScheduler(self.cache,
                                          max_workers=max_workers,
                                          base_seed=base_seed)
        self._lock = threading.Lock()
        self._latencies: list[float] = []
        self._sources: Counter[str] = Counter()
        self._requests = 0
        self._hits = 0
        self._ok = 0
        self._batch_wall_s = 0.0

    # -------------------------------------------------------------- api
    def map(self, dfg: DFG, cgra: CGRAConfig, *, deadline: float = 0.0,
            tenant: str | None = None, req_id: str = "",
            **options) -> ServeOutcome:
        return self.map_batch([MapRequest(
            dfg=dfg, cgra=cgra, options=options, deadline=deadline,
            tenant=tenant, req_id=req_id)])[0]

    def map_batch(self, requests: list[MapRequest]
                  ) -> list[ServeOutcome]:
        t0 = _time.perf_counter()
        outcomes = self.scheduler.run(requests)
        wall = _time.perf_counter() - t0
        with self._lock:
            self._batch_wall_s += wall
            for out in outcomes:
                self._requests += 1
                self._hits += int(out.hit)
                self._ok += int(out.result is not None
                                and out.result.ok)
                self._sources[out.source] += 1
                self._latencies.append(out.wall_s)
        return outcomes

    # ---------------------------------------------------------- metrics
    def metrics(self) -> dict:
        with self._lock:         # consistent snapshot vs map_batch
            lat = np.asarray(self._latencies, dtype=float)
            n_req, n_ok, n_hits = self._requests, self._ok, self._hits
            wall = self._batch_wall_s
            sources = dict(self._sources)
        p50, p95 = (float(np.percentile(lat, 50)),
                    float(np.percentile(lat, 95))) if lat.size else (0., 0.)
        return dict(
            requests=n_req,
            ok=n_ok,
            hits=n_hits,
            hit_rate=round(n_hits / n_req, 4) if n_req else 0.0,
            p50_ms=round(p50 * 1e3, 3),
            p95_ms=round(p95 * 1e3, 3),
            wall_s=round(wall, 3),
            throughput_rps=round(n_req / wall, 2) if wall else 0.0,
            sources=sources,
            static_rejects=sources.get("static_reject", 0),
            cache=self.cache.stats.as_dict(),
        )

    def summary(self) -> str:
        m = self.metrics()
        return (f"serve: {m['requests']} requests "
                f"({m['ok']} ok), hit-rate {m['hit_rate']:.0%}, "
                f"p50 {m['p50_ms']:.1f} ms, p95 {m['p95_ms']:.1f} ms, "
                f"{m['throughput_rps']:.1f} req/s")
