"""`MappingService` — the mapping-as-a-service facade.

One object owns the canonical-form cache (`serve.cache`) and the
batching scheduler (`serve.scheduler`) and exposes two calls:

- ``map(dfg, cgra, **options)`` — one request, one outcome;
- ``map_batch(requests)``       — a wave of `MapRequest`s, outcomes in
  request order.

Invariant (inherited from the cache, restated here because callers see
this module): **every positive cache hit is replayed through
`core.validate.validate_mapping` before it is released** — the service
never returns a binding the validator has not accepted against the
requesting DFG's own op ids.  Negative hits short-circuit only when the
canonical blobs are byte-equal, i.e. when the request is provably
isomorphic to the DFG the infeasibility was established for.

The service keeps running metrics — per-request latency percentiles,
hit sources, throughput — which `launch/serve.py`,
`examples/serve_batch.py` and the ``serve`` benchmark section report.
Three always-on exposition surfaces ride on top (`repro.obs`):
`prometheus()` renders the registry in Prometheus text format with a
shard label, every request appends one line to a JSONL access log
(`obs.expo.AccessLog`), and ``trace_sample`` head-samples requests by
canonical digest for full tracing at bounded cost — sampling is a pure
function of (digest, rate), so the sampled set is stable across
shards and replays.
"""

from __future__ import annotations

import time as _time
from collections import deque

from repro.core.cgra import CGRAConfig
from repro.core.dfg import DFG
from repro.obs.expo import AccessLog, head_sample, render_prometheus
from repro.obs.flight import FlightRecorder
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer

from .cache import MappingCache
from .scheduler import MapRequest, RequestScheduler, ServeOutcome

DEFAULT_ART_DIR = "artifacts/serve"


class MappingService:
    """See module docstring.  ``art_dir=None`` keeps the cache purely
    in-memory (benchmarks, tests); pass `DEFAULT_ART_DIR` (or any path)
    to persist mappings across processes."""

    # Shared mutable metrics state lives in one `obs.MetricsRegistry`:
    # concurrent `map_batch` callers (the facade is the natural thing
    # to share across server threads) publish each batch as a single
    # `record()` — one lock acquisition, no interleaved counter
    # updates.  The lock-guarded contract the hand-rolled counters used
    # to carry now lives on the registry itself (its ``_lock_guarded``
    # tuple, enforced by the same astlint rule).

    def __init__(self, *, cache: MappingCache | None = None,
                 capacity: int = 256, art_dir: str | None = None,
                 max_workers: int | None = None,
                 base_seed: int = 0,
                 registry: MetricsRegistry | None = None,
                 shard: str | None = None,
                 trace_sample: float = 0.0,
                 access_log: AccessLog | None = None,
                 flight: FlightRecorder | None = None) -> None:
        self.cache = cache if cache is not None else \
            MappingCache(capacity=capacity, art_dir=art_dir)
        # ``shard`` names this service instance in Prometheus labels
        # (a multi-process deployment scrapes one endpoint per shard
        # and aggregates by label).  ``trace_sample`` is the head-
        # sampling rate in [0, 1]: requests whose canonical digest is
        # picked by `obs.expo.head_sample` run under a live tracer,
        # collected in ``self.traces``; 0.0 (the default) keeps serve
        # runs bit-identical to the untraced service.
        self.shard = shard
        self.trace_sample = float(trace_sample)
        self.access_log = access_log if access_log is not None \
            else AccessLog()
        # Service-level flight recorder: the scheduler's admit/reject/
        # crash stream.  Always on (near-zero cost) — ``flight=None``
        # gets a default ring, not a null recorder.
        self.flight = flight if flight is not None \
            else FlightRecorder()
        self.traces: deque = deque(maxlen=64)
        self.scheduler = RequestScheduler(self.cache,
                                          max_workers=max_workers,
                                          base_seed=base_seed,
                                          record=self.flight,
                                          sample=self._sample_tracer)
        self.registry = registry if registry is not None \
            else MetricsRegistry()

    def _sample_tracer(self, digest: str):
        """Digest-keyed head sampler handed to the scheduler: returns
        a fresh `Tracer` for sampled digests (retained in
        ``self.traces`` as ``(digest, tracer)``, newest-64 ring) and
        ``None`` otherwise.  Pure in (digest, rate) — see
        `obs.expo.head_sample`."""
        if not head_sample(digest, self.trace_sample):
            return None
        tracer = Tracer()
        self.traces.append((digest, tracer))
        return tracer

    # -------------------------------------------------------------- api
    def map(self, dfg: DFG, cgra: CGRAConfig, *, deadline: float = 0.0,
            tenant: str | None = None, req_id: str = "",
            **options) -> ServeOutcome:
        return self.map_batch([MapRequest(
            dfg=dfg, cgra=cgra, options=options, deadline=deadline,
            tenant=tenant, req_id=req_id)])[0]

    def map_batch(self, requests: list[MapRequest]
                  ) -> list[ServeOutcome]:
        t0 = _time.perf_counter()
        outcomes = self.scheduler.run(requests)
        wall = _time.perf_counter() - t0
        counters: dict = {"requests": len(outcomes),
                          "batch_wall_s": wall}
        hits = ok = 0
        for out in outcomes:
            hits += int(out.hit)
            ok += int(out.result is not None and out.result.ok)
            key = f"source.{out.source}"
            counters[key] = counters.get(key, 0) + 1
        counters["hits"] = hits
        counters["ok"] = ok
        # One batched record = one lock acquisition = one consistent
        # snapshot boundary for a concurrent metrics() reader.  The
        # queue-depth gauge samples admission pressure: how many
        # requests this batch put in front of the scheduler.
        self.registry.record(
            counters=counters,
            gauges={"queue_depth": len(requests)},
            observations={"latency_s": [o.wall_s for o in outcomes]})
        # One access-log line per request (schema pinned in
        # `obs.expo.ACCESS_LOG_FIELDS`); ``wall_s`` is the serve-side
        # queue-inclusive latency, not the mapper's internal wall.
        for req, out in zip(requests, outcomes):
            self.access_log.log(
                req_id=out.req_id, digest=out.canon_digest,
                tenant=req.tenant, ok=out.result.ok, hit=out.hit,
                source=out.source, wall_s=round(out.wall_s, 6),
                ii=out.result.ii, backend=out.result.backend)
        return outcomes

    # ---------------------------------------------------------- metrics
    def metrics(self, reset: bool = False) -> dict:
        """Running metrics snapshot.  ``reset=True`` atomically clears
        the registry after reading, so a nightly scrape can report
        interval deltas without clobbering a concurrent reader's view
        mid-snapshot; the default keeps cumulative totals (cache stats
        are lifetime either way)."""
        snap = self.registry.snapshot(reset=reset)
        c, h = snap["counters"], snap["histograms"]
        lat = h.get("latency_s", {})
        n_req = c.get("requests", 0)
        n_hits = c.get("hits", 0)
        wall = c.get("batch_wall_s", 0.0)
        sources = {k[len("source."):]: v for k, v in c.items()
                   if k.startswith("source.")}
        qd = snap["gauges"].get("queue_depth",
                                dict(last=0, min=0, max=0, count=0,
                                     mean=0.0))
        return dict(
            requests=n_req,
            ok=c.get("ok", 0),
            hits=n_hits,
            hit_rate=round(n_hits / n_req, 4) if n_req else 0.0,
            p50_ms=round(lat.get("p50", 0.0) * 1e3, 3),
            p95_ms=round(lat.get("p95", 0.0) * 1e3, 3),
            p99_ms=round(lat.get("p99", 0.0) * 1e3, 3),
            wall_s=round(wall, 3),
            throughput_rps=round(n_req / wall, 2) if wall else 0.0,
            sources=sources,
            static_rejects=sources.get("static_reject", 0),
            queue_depth=qd,
            cache=self.cache.stats.as_dict(),
        )

    def prometheus(self, *, labels: dict | None = None,
                   namespace: str = "bandmap") -> str:
        """Prometheus text-format exposition of the registry's
        *cumulative* view (never drains: a scrape must not race a
        `metrics(reset=True)` consumer) plus a derived ``hit_rate``
        gauge.  ``labels`` defaults to ``{"shard": self.shard}`` when
        this service was given a shard name."""
        snap = self.registry.snapshot()
        c = snap["counters"]
        n_req = c.get("requests", 0)
        gauges = dict(snap["gauges"])
        gauges["hit_rate"] = dict(
            last=round(c.get("hits", 0) / n_req, 6) if n_req else 0.0)
        snap = dict(snap, gauges=gauges)
        if labels is None and self.shard is not None:
            labels = {"shard": self.shard}
        return render_prometheus(snap, labels=labels,
                                 namespace=namespace)

    def summary(self) -> str:
        m = self.metrics()
        return (f"serve: {m['requests']} requests "
                f"({m['ok']} ok), hit-rate {m['hit_rate']:.0%}, "
                f"p50 {m['p50_ms']:.1f} ms, p95 {m['p95_ms']:.1f} ms, "
                f"p99 {m['p99_ms']:.1f} ms, "
                f"{m['throughput_rps']:.1f} req/s")
