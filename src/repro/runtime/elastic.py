"""Elastic re-mesh: continue training on a degraded device set.

When hosts die, the coordinator computes the largest rectangular mesh
that fits the survivors, re-plans sharding with the SAME planner the
dry-run uses (checkpoints are mesh-agnostic; see checkpoint/ckpt.py), and
resumes from the latest checkpoint.  Scale-UP (recovered hosts) is the
same path with a larger target mesh.

Keeping the mesh rectangular and the model axis intact is deliberate:
TP (model axis) collectives are latency-critical and sized to the
divisibility of heads/d_ff, while the data axis only changes the FSDP
shard count and the per-host batch slice — so we always shrink the
data/pod axes first and never the model axis.
"""

from __future__ import annotations

import math


def degraded_mesh_shape(shape: dict, n_failed_hosts: int,
                        chips_per_host: int = 4) -> dict:
    """Largest viable mesh after losing hosts (shrink pod, then data)."""
    out = dict(shape)
    lost_chips = n_failed_hosts * chips_per_host
    total = math.prod(shape.values())
    remaining = total - lost_chips
    if remaining <= 0:
        raise ValueError("no devices left")
    # shrink pod axis first (whole pods), then the data axis.
    while "pod" in out and out["pod"] > 1 and \
            math.prod(out.values()) > remaining:
        out["pod"] -= 1
    while out.get("data", 1) > 1 and math.prod(out.values()) > remaining:
        out["data"] -= 1
    if math.prod(out.values()) > remaining:
        raise ValueError(f"cannot fit a mesh into {remaining} chips")
    return out


def plan_elastic_restart(cfg, kind: str, seq: int, global_batch: int,
                         old_shape: dict, n_failed_hosts: int,
                         chips_per_host: int = 4):
    """Returns (new_shape, new_batch, notes).  The global batch is kept
    whenever the new data axis still divides it, else reduced to the
    nearest multiple (recorded so the trainer can rescale LR)."""
    new_shape = degraded_mesh_shape(old_shape, n_failed_hosts,
                                    chips_per_host)
    dp = new_shape.get("data", 1) * new_shape.get("pod", 1)
    new_batch = global_batch
    notes = []
    if global_batch % dp:
        new_batch = max(dp, (global_batch // dp) * dp)
        notes.append(f"global_batch {global_batch} -> {new_batch} "
                     f"(data axis {dp})")
    if new_shape != old_shape:
        notes.append(f"mesh {old_shape} -> {new_shape}")
    return new_shape, new_batch, notes
