from .elastic import degraded_mesh_shape, plan_elastic_restart  # noqa: F401
from .fault import FailureInjector, SimulatedFailure, run_with_recovery  # noqa: F401
from .straggler import StragglerMitigator  # noqa: F401
