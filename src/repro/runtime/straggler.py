"""Straggler mitigation.

Synchronous SPMD training runs at the speed of the slowest participant.
The mitigator tracks an EWMA of per-host step durations and applies, in
order of escalation:

1. **rebalance** — shrink the slow host's batch slice (the data pipeline
   is index-sliced per host, so this is a pure bookkeeping change) and
   grow the fastest hosts' slices to conserve the global batch;
2. **exclude**  — a host slower than ``exclude_ratio``× median for
   ``patience`` windows is reported to the coordinator for an elastic
   restart without it (runtime/elastic.py).

This is control-plane logic (no jax): unit-tested directly, driven by the
trainer loop on real deployments.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class HostStat:
    ewma_s: float = 0.0
    n: int = 0
    slow_windows: int = 0


class StragglerMitigator:
    def __init__(self, n_hosts: int, global_batch: int, *,
                 alpha: float = 0.3, rebalance_ratio: float = 1.15,
                 exclude_ratio: float = 1.6, patience: int = 3,
                 min_rows: int = 1):
        self.n_hosts = n_hosts
        self.global_batch = global_batch
        self.alpha = alpha
        self.rebalance_ratio = rebalance_ratio
        self.exclude_ratio = exclude_ratio
        self.patience = patience
        self.min_rows = min_rows
        self.stats = [HostStat() for _ in range(n_hosts)]
        base = global_batch // n_hosts
        self.rows = [base] * n_hosts
        for i in range(global_batch - base * n_hosts):
            self.rows[i] += 1

    # ------------------------------------------------------------- update
    def observe(self, host: int, step_seconds: float) -> None:
        st = self.stats[host]
        st.ewma_s = (step_seconds if st.n == 0 else
                     (1 - self.alpha) * st.ewma_s
                     + self.alpha * step_seconds)
        st.n += 1

    def _median(self) -> float:
        xs = sorted(s.ewma_s for s in self.stats if s.n)
        return xs[len(xs) // 2] if xs else 0.0

    # ------------------------------------------------------------- policy
    def rebalance(self) -> list[int]:
        """Adjust per-host row counts; returns the new slice sizes."""
        med = self._median()
        if med <= 0:
            return self.rows
        for h, st in enumerate(self.stats):
            if not st.n:
                continue
            ratio = st.ewma_s / med
            if ratio > self.rebalance_ratio and \
                    self.rows[h] > self.min_rows:
                give = max(1, int(self.rows[h] * (1 - 1 / ratio)))
                give = min(give, self.rows[h] - self.min_rows)
                fastest = min(
                    (i for i in range(self.n_hosts) if self.stats[i].n),
                    key=lambda i: self.stats[i].ewma_s)
                self.rows[h] -= give
                self.rows[fastest] += give
            st.slow_windows = st.slow_windows + 1 \
                if ratio > self.exclude_ratio else 0
        assert sum(self.rows) == self.global_batch
        return self.rows

    def to_exclude(self) -> list[int]:
        return [h for h, st in enumerate(self.stats)
                if st.slow_windows >= self.patience]

    def host_slices(self) -> list[slice]:
        out, lo = [], 0
        for r in self.rows:
            out.append(slice(lo, lo + r))
            lo += r
        return out
