"""Fault tolerance: checkpoint-scoped recovery loop with failure
injection.

On a real multi-pod deployment the failure signal comes from the
coordinator (missed heartbeats / ICI timeout); in this container the same
control flow is exercised through `FailureInjector`, a deterministic
schedule of simulated failures that unit/integration tests drive.

The recovery contract (tested in tests/test_runtime.py):
- a failure at step t never loses more than `ckpt_every` steps;
- the data pipeline replays exactly (batch = f(seed, step) — stateless);
- recovery re-enters through the SAME jitted step function (no recompile
  when the mesh is unchanged) or through an elastic re-plan
  (runtime/elastic.py) when hosts were lost.
"""

from __future__ import annotations

import dataclasses
import logging
import time

log = logging.getLogger("repro.runtime")


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, host: int, kind: str = "host_down"):
        super().__init__(f"simulated {kind} on host {host} at step {step}")
        self.step = step
        self.host = host
        self.kind = kind


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: (host, kind)}."""
    schedule: dict
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            host, kind = self.schedule[step]
            raise SimulatedFailure(step, host, kind)


def run_with_recovery(*, train_step, init_state, data, ckpt_manager,
                      n_steps: int, injector: FailureInjector | None = None,
                      on_failure=None, max_restarts: int = 8):
    """Run `n_steps`, checkpointing via ckpt_manager, recovering from
    (simulated) failures by restoring the latest checkpoint.

    train_step(state, batch) -> (state, metrics).
    on_failure(failure, state_like) -> (state, start_step) | None —
    hook for elastic re-planning; default restores same-mesh.
    Returns (final_state, history, n_restarts)."""
    state = init_state
    step = 0
    history = []
    restarts = 0
    while step < n_steps:
        try:
            while step < n_steps:
                if injector is not None:
                    injector.check(step)
                batch = data.batch(step)
                state, metrics = train_step(state, batch)
                history.append({k: float(v) for k, v in metrics.items()})
                step += 1
                ckpt_manager.maybe_save(state, step)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("failure: %s — restoring", e)
            if on_failure is not None:
                out = on_failure(e, state)
                if out is not None:
                    state, step = out
                    continue
            try:
                state, manifest = ckpt_manager.restore_latest(state)
                step = manifest["step"]
            except FileNotFoundError:
                state, step = init_state, 0
    return state, history, restarts


@dataclasses.dataclass
class Heartbeat:
    host: int
    step: int
    t: float


class HeartbeatMonitor:
    """Tracks per-host liveness; a host missing for > timeout heartbeats
    is declared failed (drives the coordinator on real deployments)."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.last: dict[int, float] = {}

    def beat(self, host: int, step: int, t: float | None = None) -> None:
        self.last[host] = t if t is not None else time.time()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h in range(self.n_hosts)
                if now - self.last.get(h, 0.0) > self.timeout_s]
