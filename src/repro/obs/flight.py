"""Flight recorder: a bounded ring buffer of structured engine events.

Spans (`obs.trace`) answer *where the time went* on an opt-in traced
run; the flight recorder answers *what just happened* on every run —
it is cheap enough to leave on in production serving, and `dump()` of
the last-N events is attached to failed results so a postmortem never
needs a re-run under a live tracer.

`FlightRecorder.emit(kind, **attrs)` appends one `FlightEvent` to a
lock-guarded ``deque(maxlen=capacity)``: O(1), no percentile math, no
span stack, and the ring bound means a week-long serve process holds a
constant-size buffer.  Event kinds are the pinned ``EVENTS``
vocabulary in `repro.obs` (the flight analogue of ``PHASES``).

The ``record=None`` contract (the flight analogue of ``tracer=None``,
enforced by the ``recorder-default-none`` AST-lint rule): engine entry
points accept ``record=None``, convert it exactly once via
:func:`recording`, and only ever test ``record is None`` /
``is not None`` — recording is observation only, so a ``record=None``
run stays bit-identical and allocation-free (`NullFlightRecorder` is a
shared no-op singleton, like `NULL_TRACER`).

Usage::

    rec = FlightRecorder(capacity=256)
    res = map_dfg(dfg, cgra, record=rec)
    if not res.ok:
        print(res.flight)        # the recorder's dump, attached by
                                 # map_dfg on every failed result
"""

from __future__ import annotations

import dataclasses
import threading
import time as _time
from collections import deque

#: Default ring capacity — enough to hold a full II escalation's
#: attempt/certificate/harvest narrative for the paper kernels while
#: keeping a failed result's ``flight`` payload small.
DEFAULT_CAPACITY = 256


@dataclasses.dataclass(frozen=True)
class FlightEvent:
    """One recorded event.  ``t`` is seconds on the monotonic clock
    since the recorder's epoch (its construction instant) — never the
    wall clock, so canonical paths may hold a recorder."""
    seq: int            # global emission index (survives ring eviction)
    t: float
    kind: str           # one of `repro.obs.EVENTS`
    attrs: dict

    def as_dict(self) -> dict:
        """JSON-able flat dict (the shape `dump()` returns and
        `MappingResult.flight` carries)."""
        return dict(seq=self.seq, t=round(self.t, 6), kind=self.kind,
                    **self.attrs)


class FlightRecorder:
    """See module docstring."""

    # The ring and its emission counter are appended to by every
    # recording thread (serve workers, the race's two sides); the
    # `lock-guarded-state` astlint rule pins the mutation to the lock.
    _lock_guarded = ("_events", "_seq")

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        self.capacity = capacity
        self.epoch = _time.perf_counter()
        self._lock = threading.Lock()
        self._events: deque[FlightEvent] = deque(maxlen=capacity)
        self._seq = 0

    def emit(self, kind: str, **attrs) -> None:
        t = _time.perf_counter() - self.epoch
        with self._lock:
            self._events.append(FlightEvent(self._seq, t, kind, attrs))
            self._seq += 1

    def dump(self) -> tuple[dict, ...]:
        """The last-``capacity`` events, oldest first, as JSON-able
        dicts — the payload failed results carry in their ``flight``
        field.  A dropped prefix is visible as a gap before the first
        ``seq``."""
        with self._lock:
            events = tuple(self._events)
        return tuple(ev.as_dict() for ev in events)

    @property
    def total(self) -> int:
        """Events emitted over the recorder's lifetime (>= len)."""
        with self._lock:
            return self._seq

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


class NullFlightRecorder:
    """The ``record=None`` default behind :func:`recording`:
    structurally a `FlightRecorder`, behaviourally nothing — no
    allocation, no lock, no clock read.  Engine paths hold exactly one
    per process (`NULL_RECORDER`)."""

    capacity = 0
    epoch = 0.0
    total = 0

    def emit(self, kind: str, **attrs) -> None:
        pass

    def dump(self) -> tuple:
        return ()

    def __len__(self) -> int:
        return 0


NULL_RECORDER = NullFlightRecorder()


def recording(record: "FlightRecorder | NullFlightRecorder | None"
              ) -> "FlightRecorder | NullFlightRecorder":
    """The one conversion engine entry points perform on their
    ``record=None`` parameter: None becomes the shared `NULL_RECORDER`,
    anything else passes through (mirror of `trace.live`)."""
    return NULL_RECORDER if record is None else record
