"""Serve-tier exposition: Prometheus text format, access log, sampling.

Three pieces, all consumed by `serve.MappingService`:

- :func:`render_prometheus` / :func:`parse_prometheus` — render a
  `MetricsRegistry.snapshot()` dict in the Prometheus text exposition
  format (version 0.0.4), with an optional label dimension
  (``{shard="0"}``) so replicated serve processes scrape into one
  aggregatable namespace — the "replication-friendly metrics" half of
  the ROADMAP's distributed-serving item.  Counters render as
  ``counter``, gauges as their last value (``gauge``), histograms as a
  ``summary`` (p50/p95/p99 quantile samples plus ``_count``/``_sum``).
  The parser exists for round-trip tests and scrape tooling; it reads
  exactly what the renderer writes.
- :class:`AccessLog` — a lock-guarded JSONL per-request log with the
  pinned ``ACCESS_LOG_FIELDS`` schema (one line per `ServeOutcome`),
  kept in a bounded in-memory ring and optionally mirrored to a file.
  ``redact_digests=True`` truncates canonical digests to 12 hex chars,
  for logs that leave the trust boundary (the digest is derived from
  the request's DFG structure).
- :func:`head_sample` — deterministic digest-keyed head sampling: the
  decision is a pure function of (digest, rate), so the *same* request
  is sampled on every replica and every retry — bounded-cost tracing
  that stays reproducible, unlike a coin flip per request.
"""

from __future__ import annotations

import json
import threading
import time as _time
from collections import deque

#: Pinned access-log schema (STABLE — `tests/test_obs_expo.py` asserts
#: every emitted line carries exactly these keys, in this order).
ACCESS_LOG_FIELDS = ("ts", "req_id", "digest", "tenant", "ok", "hit",
                     "source", "wall_s", "ii", "backend")

_NAME_OK = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


# ------------------------------------------------------------ prometheus
def _metric_name(namespace: str, name: str) -> str:
    full = f"{namespace}_{name}" if namespace else name
    return "".join(c if c in _NAME_OK else "_" for c in full)


def _labels_str(labels: dict | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def render_prometheus(snapshot: dict, *, labels: dict | None = None,
                      namespace: str = "bandmap") -> str:
    """Render a `MetricsRegistry.snapshot()` dict (``counters`` /
    ``gauges`` / ``histograms``) as Prometheus text exposition.
    ``labels`` (e.g. ``{"shard": "0"}``) are attached to every sample;
    metric names are ``<namespace>_<name>`` with non-identifier chars
    mapped to ``_`` (``latency_s`` stays, ``source.computed`` becomes
    ``source_computed``)."""
    lines: list[str] = []
    base = _labels_str(labels)
    for name, value in sorted(snapshot.get("counters", {}).items()):
        m = _metric_name(namespace, name)
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m}{base} {float(value):g}")
    for name, g in sorted(snapshot.get("gauges", {}).items()):
        m = _metric_name(namespace, name)
        last = g.get("last", 0.0) if isinstance(g, dict) else float(g)
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m}{base} {float(last):g}")
    for name, h in sorted(snapshot.get("histograms", {}).items()):
        m = _metric_name(namespace, name)
        lines.append(f"# TYPE {m} summary")
        for q, key in (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")):
            ql = dict(labels or {}, quantile=q)
            lines.append(
                f"{m}{_labels_str(ql)} {float(h.get(key, 0.0)):g}")
        count = int(h.get("count", 0))
        total = float(h.get("mean", 0.0)) * count
        lines.append(f"{m}_count{base} {count:g}")
        lines.append(f"{m}_sum{base} {total:g}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict[str, list[tuple[dict, float]]]:
    """Parse text produced by :func:`render_prometheus` back into
    ``{metric_name: [(labels, value), ...]}`` — the round-trip half of
    the exposition tests.  Comment/TYPE lines are skipped."""
    out: dict[str, list[tuple[dict, float]]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, value = line.rsplit(" ", 1)
        labels: dict = {}
        if head.endswith("}"):
            name, inner = head[:-1].split("{", 1)
            for pair in inner.split(","):
                if not pair:
                    continue
                k, v = pair.split("=", 1)
                labels[k] = v.strip('"')
        else:
            name = head
        out.setdefault(name, []).append((labels, float(value)))
    return out


# ------------------------------------------------------------- sampling
def head_sample(digest: str, rate: float) -> bool:
    """Deterministic head-sampling decision for one canonical digest.
    ``rate`` is the sampled fraction in [0, 1]; the decision hashes the
    digest's leading 8 hex chars into [0, 10000) and compares, so it is
    a pure function of (digest, rate) — stable across replicas,
    retries and processes."""
    if rate <= 0.0:
        return False
    if rate >= 1.0:
        return True
    return int(digest[:8] or "0", 16) % 10_000 < int(rate * 10_000)


# ------------------------------------------------------------ access log
class AccessLog:
    """Per-request JSONL log with the pinned `ACCESS_LOG_FIELDS` schema.

    Lines land in a bounded in-memory ring (``capacity`` newest lines,
    so a long-lived service never grows unboundedly) and, when ``path``
    is given, are appended to the file as they arrive.  All writes go
    through one lock — serve batches may resolve outcomes from pool
    callbacks on several threads."""

    _lock_guarded = ("_lines", "_count")

    def __init__(self, path: str | None = None, *,
                 capacity: int = 4096,
                 redact_digests: bool = False) -> None:
        self.path = path
        self.redact_digests = redact_digests
        self._lock = threading.Lock()
        self._lines: deque[str] = deque(maxlen=capacity)
        self._count = 0
        if path:
            import os
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            # Touch (append mode) so an empty log is still a file.
            with open(path, "a"):
                pass

    def log(self, **fields) -> str:
        """Emit one line.  Unknown keys are dropped and missing keys
        are filled with None, so the line schema is exactly
        `ACCESS_LOG_FIELDS` regardless of the caller; ``ts`` defaults
        to the wall clock (this is an operational log, not a canonical
        path)."""
        entry = {k: fields.get(k) for k in ACCESS_LOG_FIELDS}
        if entry["ts"] is None:
            entry["ts"] = round(_time.time(), 3)
        if self.redact_digests and entry["digest"]:
            entry["digest"] = str(entry["digest"])[:12]
        line = json.dumps(entry, sort_keys=False, default=str)
        with self._lock:
            self._lines.append(line)
            self._count += 1
            if self.path:
                with open(self.path, "a") as fh:
                    fh.write(line + "\n")
        return line

    def tail(self, n: int | None = None) -> list[dict]:
        """The newest ``n`` (default: all retained) lines, parsed."""
        with self._lock:
            lines = list(self._lines)
        if n is not None:
            lines = lines[-n:]
        return [json.loads(ln) for ln in lines]

    @property
    def total(self) -> int:
        """Lines emitted over the log's lifetime (>= len)."""
        with self._lock:
            return self._count

    def __len__(self) -> int:
        with self._lock:
            return len(self._lines)
