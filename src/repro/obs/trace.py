"""Nestable spans over a monotonic clock, plus the NullTracer contract.

`Tracer` records *spans* — named, attributed intervals on the
monotonic clock (`time.perf_counter`, never the wall clock) — and
forwards counters/gauges to a `MetricsRegistry`.  Spans nest through a
per-thread stack, so one tracer can be shared by concurrent engine
threads (the mapping race, serve workers): each finished span carries
its thread id and its parent span's id, which is exactly what the
Chrome trace-event export (`obs.export`) needs to lay out per-thread
timelines in Perfetto.

The tracer-threading rule (enforced by the ``tracer-default-none``
AST-lint rule on the engine modules): every engine entry point accepts
``tracer=None``, converts it once via :func:`live` and never branches
on trace *content* — tracing must be observation only, so a
``tracer=None`` run stays bit-identical to a traced one.  `NullTracer`
is that default: every method is a no-op returning a shared singleton
(`NULL_SPAN`, `NULL_COUNTER`), so the untraced hot path allocates
nothing and never touches an RNG stream or a lock.

Usage::

    tracer = Tracer()
    with tracer.span("certify", ii=ii, jitter=j) as sp:
        ...
        sp.set(stage="exhausted", nodes=nodes)
    tracer.count("certify.csp_nodes", nodes)
"""

from __future__ import annotations

import dataclasses
import threading
import time as _time

from .registry import NULL_COUNTER, Counter, MetricsRegistry


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span.  Times are seconds on the monotonic clock,
    relative to the tracer's epoch (its construction instant)."""
    sid: int            # unique per tracer, assigned at span start
    parent: int         # enclosing span's sid, -1 at top level
    name: str
    t0: float
    t1: float
    tid: int            # OS thread ident of the recording thread
    depth: int          # nesting depth within its thread (0 = root)
    attrs: dict

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0


class _LiveSpan:
    """Context-manager handle for an open span."""

    __slots__ = ("_tracer", "sid", "parent", "name", "t0", "depth",
                 "attrs")

    def __init__(self, tracer: "Tracer", sid: int, parent: int,
                 name: str, depth: int, attrs: dict) -> None:
        self._tracer = tracer
        self.sid = sid
        self.parent = parent
        self.name = name
        self.depth = depth
        self.attrs = attrs
        self.t0 = _time.perf_counter()

    def set(self, **attrs) -> "_LiveSpan":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_LiveSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._finish(self)
        return False


class _NullSpan:
    """Shared no-op span — `NullTracer.span` returns this singleton, so
    the untraced path allocates nothing per call."""

    __slots__ = ()
    name = ""
    attrs: dict = {}

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """See module docstring."""

    # Finished-span list is appended to by every traced thread; the
    # `lock-guarded-state` astlint rule pins the mutation to the lock.
    _lock_guarded = ("_finished",)

    def __init__(self, registry: MetricsRegistry | None = None) -> None:
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.epoch = _time.perf_counter()
        self._lock = threading.Lock()
        self._finished: list[SpanRecord] = []
        self._next_sid = 0
        self._tls = threading.local()

    # ------------------------------------------------------------- spans
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, **attrs) -> _LiveSpan:
        stack = self._stack()
        parent = stack[-1].sid if stack else -1
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
        sp = _LiveSpan(self, sid, parent, name, len(stack), attrs)
        stack.append(sp)
        return sp

    def _finish(self, sp: _LiveSpan) -> None:
        t1 = _time.perf_counter()
        stack = self._stack()
        # Tolerate out-of-order exits (a caller holding the handle past
        # an enclosing span): pop through to this span if present.
        if sp in stack:
            del stack[stack.index(sp):]
        rec = SpanRecord(sid=sp.sid, parent=sp.parent, name=sp.name,
                         t0=sp.t0 - self.epoch, t1=t1 - self.epoch,
                         tid=threading.get_ident(), depth=sp.depth,
                         attrs=dict(sp.attrs))
        with self._lock:
            self._finished.append(rec)

    @property
    def finished(self) -> list[SpanRecord]:
        with self._lock:
            return list(self._finished)

    # ----------------------------------------------------------- metrics
    def count(self, name: str, n: int | float = 1) -> None:
        self.registry.inc(name, n)

    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def counter_value(self, name: str) -> int | float:
        return self.registry.counter_value(name)

    def gauge(self, name: str, value: int | float) -> None:
        self.registry.gauge(name, value)

    # ----------------------------------------------------------- summary
    def phase_breakdown(self) -> dict[str, dict]:
        """Aggregate finished spans by name: ``{name: {"count": n,
        "total_s": wall}}``, sorted by descending total.  Nested spans
        each contribute their own full duration (attribution, not a
        partition of wall time)."""
        agg: dict[str, dict] = {}
        for rec in self.finished:
            slot = agg.setdefault(rec.name, {"count": 0, "total_s": 0.0})
            slot["count"] += 1
            slot["total_s"] += rec.dur_s
        return dict(sorted(agg.items(),
                           key=lambda kv: -kv[1]["total_s"]))


class NullTracer:
    """The ``tracer=None`` default behind :func:`live`: structurally a
    `Tracer`, behaviourally nothing — no allocation, no lock, no RNG,
    no state.  Engine paths hold exactly one of these per process
    (`NULL_TRACER`)."""

    registry = None
    epoch = 0.0
    finished: tuple = ()

    def span(self, name: str, **attrs) -> _NullSpan:
        return NULL_SPAN

    def count(self, name: str, n: int | float = 1) -> None:
        pass

    def counter(self, name: str):
        return NULL_COUNTER

    def counter_value(self, name: str) -> int:
        return 0

    def gauge(self, name: str, value: int | float) -> None:
        pass

    def phase_breakdown(self) -> dict:
        return {}


NULL_TRACER = NullTracer()


def live(tracer: "Tracer | NullTracer | None") -> "Tracer | NullTracer":
    """The one conversion engine entry points perform on their
    ``tracer=None`` parameter: None becomes the shared `NULL_TRACER`,
    anything else passes through."""
    return NULL_TRACER if tracer is None else tracer
