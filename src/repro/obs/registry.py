"""Unified metrics store: counters, gauges and histograms behind one lock.

`MetricsRegistry` is the single backing store for every numeric the
engine and the serving tier emit:

- **counters** — monotonically accumulated numbers (requests served,
  portfolio iterations, CSP nodes expanded).  Increments are
  lock-guarded, so concurrent writers (serve worker threads, the two
  sides of a mapping race) never lose counts.
- **gauges** — point-in-time samples (queue depth at batch admission,
  per-seed portfolio coverage).  The registry keeps last/min/max plus
  the running count/sum, so a snapshot can report the latest value and
  the envelope without retaining every sample.
- **histograms** — full sample lists summarised to p50/p95/p99 (via
  ``numpy.percentile``, linear interpolation) at snapshot time; the
  serving tier's request-latency percentiles live here.

``snapshot(reset=False)`` returns a plain-dict view; ``reset=True``
returns the current *window* and then folds it into a cumulative
drained store before clearing — so periodic scrapes get interval
deltas while every other reader's default (cumulative) view keeps the
lifetime totals.  One consumer draining the window can therefore never
silently zero another's view: ``snapshot()`` after ``snapshot(
reset=True)`` still reports everything ever recorded (drained
histogram samples are retained up to ``_DRAIN_SAMPLE_CAP`` newest
samples per name, so a long-lived service stays bounded; percentiles
over a drained-and-capped history are over that retained suffix).
``counter_value`` and ``percentiles`` read the same cumulative view.

Thread-safety contract: the backing dicts (including the drained
store) are declared in ``_lock_guarded`` and only ever mutated under
``self._lock`` — the repo's ``lock-guarded-state`` AST-lint rule
enforces exactly that.
"""

from __future__ import annotations

import threading

import numpy as np


class Counter:
    """Handle bound to one named counter — hot loops hold the handle so
    the per-increment cost is one lock acquire, no dict lookup churn in
    the caller."""

    __slots__ = ("_reg", "name")

    def __init__(self, reg: "MetricsRegistry", name: str) -> None:
        self._reg = reg
        self.name = name

    def inc(self, n: int | float = 1) -> None:
        self._reg.inc(self.name, n)

    @property
    def value(self) -> int | float:
        return self._reg.counter_value(self.name)


class NullCounter:
    """Allocation-free no-op twin of `Counter` (the NullTracer hands
    these out so untraced hot loops pay one no-op call per increment)."""

    __slots__ = ()
    name = ""
    value = 0

    def inc(self, n: int | float = 1) -> None:
        pass


NULL_COUNTER = NullCounter()

#: Newest histogram samples retained per name in the drained store — a
#: week of reset-scrapes must not accumulate unbounded latency samples.
_DRAIN_SAMPLE_CAP = 65536


class MetricsRegistry:
    """See module docstring."""

    # Shared mutable state: serve workers, the race's two sides and any
    # metrics() reader hit this concurrently.  Enforced by the
    # `lock-guarded-state` astlint rule.
    _lock_guarded = ("_counters", "_gauges", "_hists", "_drained")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, int | float] = {}
        # name -> [last, min, max, count, total]
        self._gauges: dict[str, list] = {}
        self._hists: dict[str, list[float]] = {}
        # Prior windows folded in by snapshot(reset=True): same shapes
        # as the live stores (histogram samples capped, newest kept).
        self._drained: dict = dict(counters={}, gauges={}, hists={})

    # ------------------------------------------------------------ write
    def inc(self, name: str, n: int | float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def counter(self, name: str) -> Counter:
        with self._lock:
            self._counters.setdefault(name, 0)
        return Counter(self, name)

    def gauge(self, name: str, value: int | float) -> None:
        value = float(value)
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                self._gauges[name] = [value, value, value, 1, value]
            else:
                g[0] = value
                g[1] = min(g[1], value)
                g[2] = max(g[2], value)
                g[3] += 1
                g[4] += value

    def observe(self, name: str, value: int | float) -> None:
        with self._lock:
            self._hists.setdefault(name, []).append(float(value))

    def record(self, counters: dict | None = None,
               gauges: dict | None = None,
               observations: dict | None = None) -> None:
        """Apply a batch of updates under one lock acquisition — the
        consistent-snapshot path for callers that publish several
        metrics per event (e.g. one serve batch).  ``observations``
        values may be a scalar or an iterable of samples."""
        with self._lock:
            for name, n in (counters or {}).items():
                self._counters[name] = self._counters.get(name, 0) + n
            for name, value in (gauges or {}).items():
                value = float(value)
                g = self._gauges.get(name)
                if g is None:
                    self._gauges[name] = [value, value, value, 1, value]
                else:
                    g[0] = value
                    g[1] = min(g[1], value)
                    g[2] = max(g[2], value)
                    g[3] += 1
                    g[4] += value
            for name, values in (observations or {}).items():
                if np.isscalar(values):
                    values = [values]
                self._hists.setdefault(name, []).extend(
                    float(v) for v in values)

    # ------------------------------------------------------------- read
    def counter_value(self, name: str) -> int | float:
        """Lifetime value — drained windows included, so a concurrent
        ``snapshot(reset=True)`` never makes a counter appear to move
        backwards."""
        with self._lock:
            return self._drained["counters"].get(name, 0) + \
                self._counters.get(name, 0)

    def percentiles(self, name: str,
                    qs: tuple = (50, 95, 99)) -> tuple[float, ...]:
        with self._lock:
            samples = list(self._drained["hists"].get(name, ())) + \
                list(self._hists.get(name, ()))
        if not samples:
            return tuple(0.0 for _ in qs)
        arr = np.asarray(samples, dtype=float)
        return tuple(float(np.percentile(arr, q)) for q in qs)

    def snapshot(self, reset: bool = False) -> dict:
        """Plain-dict view: ``{"counters": {...}, "gauges": {...},
        "histograms": {...}}``.  Gauges report last/min/max/count/mean;
        histograms report count/mean/max plus p50/p95/p99.

        The default view is *cumulative* (drained windows merged back
        in).  ``reset=True`` returns only the current window and folds
        it into the drained store before clearing (one atomic
        read-and-fold-and-reset — no updates can fall between), so an
        interval scraper and a lifetime reader can share the registry
        without the scrape zeroing the reader (the double-drain
        hazard)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = {k: list(v) for k, v in self._gauges.items()}
            hists = {k: list(v) for k, v in self._hists.items()}
            d = self._drained
            if reset:
                for k, v in counters.items():
                    d["counters"][k] = d["counters"].get(k, 0) + v
                for k, g in gauges.items():
                    dg = d["gauges"].get(k)
                    if dg is None:
                        d["gauges"][k] = list(g)
                    else:
                        dg[0] = g[0]
                        dg[1] = min(dg[1], g[1])
                        dg[2] = max(dg[2], g[2])
                        dg[3] += g[3]
                        dg[4] += g[4]
                for k, samples in hists.items():
                    pool = d["hists"].setdefault(k, [])
                    pool.extend(samples)
                    if len(pool) > _DRAIN_SAMPLE_CAP:
                        del pool[:len(pool) - _DRAIN_SAMPLE_CAP]
                self._counters.clear()
                self._gauges.clear()
                self._hists.clear()
            else:
                for k, v in d["counters"].items():
                    counters[k] = counters.get(k, 0) + v
                for k, dg in d["gauges"].items():
                    g = gauges.get(k)
                    if g is None:
                        gauges[k] = list(dg)
                    else:
                        # The live window's last is the newest sample;
                        # envelope and count/total fold across windows.
                        g[1] = min(g[1], dg[1])
                        g[2] = max(g[2], dg[2])
                        g[3] += dg[3]
                        g[4] += dg[4]
                for k, samples in d["hists"].items():
                    hists[k] = list(samples) + hists.get(k, [])
        out_g = {}
        for name, (last, lo, hi, count, total) in gauges.items():
            out_g[name] = dict(last=last, min=lo, max=hi, count=count,
                               mean=total / count if count else 0.0)
        out_h = {}
        for name, samples in hists.items():
            arr = np.asarray(samples, dtype=float)
            p50, p95, p99 = (np.percentile(arr, (50, 95, 99))
                             if arr.size else (0.0, 0.0, 0.0))
            out_h[name] = dict(
                count=int(arr.size),
                mean=float(arr.mean()) if arr.size else 0.0,
                max=float(arr.max()) if arr.size else 0.0,
                p50=float(p50), p95=float(p95), p99=float(p99))
        return dict(counters=counters, gauges=out_g, histograms=out_h)
