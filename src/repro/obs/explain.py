"""Explain reports: narrate *why* a mapping landed where it did.

A `MappingResult` already carries the verdict structure — per-(II,
jitter) infeasibility certificates, the static demand floor, attempt
counts, the race winner tag — but nothing renders it as a narrative.
:func:`explain_result` turns a result (plus, optionally, the `Tracer`
and flight-recorder data from the same run) into a structured
`ExplainReport`:

- **II escalation path** — one entry per II from MII to the landing
  II, each naming its cause: static-demand floor, certificate stage(s)
  per jitter, or portfolio exhaustion.  A ``proved_infeasible`` result
  reads as a full-range UNSAT narrative.
- **Routing-PE accounting** — routing PEs and delivery ports per
  multi-consumer VIO, against the paper's BandMap-vs-BusMap framing
  (BusMap broadcasts one port per datum; BandMap's allocation is what
  the routing-PE count measures).
- **Portfolio coverage curve** — harvest-round coverage from
  "portfolio"/"portfolio-device" spans or "harvest-round" flight
  events, plus the group-move kick count.
- **Race outcome** — winner side, cancel→exit latency and the loser's
  post-cancel iterations, from the "race" span or flight events.

Exposed as ``MappingResult.explain()`` and as a CLI over serialized
results (`MappingResult.to_bytes` files, e.g. a serve artifact)::

    python -m repro.obs.explain artifacts/result.bin [--json]

This module deliberately never imports ``repro.core`` at module level
(`repro.core.bandmap` imports `repro.obs`): results are duck-typed.
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass
class ExplainReport:
    """Structured narrative for one mapping result; `as_dict()` is the
    machine shape, `render()` the human one."""
    ok: bool
    mode: str
    ii: int
    mii: int
    backend: str
    attempts: int
    proved_infeasible: bool
    optimal: bool
    escalation: list[dict]      # per-II: ii / outcome / cause / stages
    routing: dict               # n_routing_pes / n_vios / ports / note
    coverage: list[dict]        # harvest rounds: round / coverage / best
    kicks: int                  # group-move kicks observed (traced runs)
    race: dict | None           # winner / cancel_latency_s / ...
    n_flight_events: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        ratio = self.mii / self.ii if self.ii else 0.0
        head = "ok" if self.ok else (
            "proved infeasible" if self.proved_infeasible else "failed")
        lines = [
            f"explain: {self.mode} — {head}, II={self.ii} "
            f"(MII={self.mii}, ratio={ratio:.2f}), "
            f"backend={self.backend}"
            + (", proven optimal" if self.optimal else "")]
        lines.append("II escalation:")
        for e in self.escalation:
            lines.append(f"  II={e['ii']}: {e['outcome']} — {e['cause']}")
        r = self.routing
        lines.append(f"routing: {r['note']}")
        if self.coverage:
            last = self.coverage[-1]
            lines.append(
                f"portfolio: {len(self.coverage)} harvest round(s), "
                f"final coverage {last['coverage']:.0%} "
                f"(best {last['best']}); group-move kicks: {self.kicks}")
        elif self.kicks:
            lines.append(f"portfolio: group-move kicks: {self.kicks}")
        if self.race is not None:
            rc = self.race
            extra = ""
            if rc.get("cancel_latency_s") is not None:
                extra += (f", cancel→exit "
                          f"{rc['cancel_latency_s'] * 1e3:.1f} ms")
            if rc.get("loser_iters_after_cancel") is not None:
                extra += (f", loser iters after cancel "
                          f"{rc['loser_iters_after_cancel']}")
            lines.append(f"race: winner={rc.get('winner')}{extra}")
        if self.n_flight_events:
            lines.append(
                f"flight: {self.n_flight_events} event(s) attached")
        return "\n".join(lines)


def _escalation(result, certs) -> list[dict]:
    """One entry per II from MII up to the landing (or last proven) II,
    each with a definite cause."""
    by_ii: dict[int, list] = {}
    for c in certs:
        by_ii.setdefault(int(c.ii), []).append(c)
    mii = int(getattr(result, "mii", 0) or 0)
    top = max([int(result.ii)] + list(by_ii), default=mii)
    out: list[dict] = []
    for ii in range(mii, max(top, mii) + 1):
        cs = by_ii.get(ii, [])
        stages = sorted({c.stage for c in cs})
        jitters = sorted({int(c.jitter) for c in cs})
        if result.ok and ii == int(result.ii):
            cause = (f"validated placement "
                     f"(after {int(result.attempts)} attempt(s)")
            if cs:
                cause += (f"; jitter(s) {jitters} certified first: "
                          f"{', '.join(stages)}")
            cause += ")"
            entry = dict(ii=ii, outcome="mapped", cause=cause)
        elif any(c.stage == "static-demand" for c in cs):
            detail = next((c.detail for c in cs
                           if c.stage == "static-demand"), "")
            cause = "static demand floor"
            if detail:
                cause += f": {detail}"
            entry = dict(ii=ii, outcome="skipped", cause=cause)
        elif cs:
            cause = (f"certified infeasible at jitter(s) {jitters} "
                     f"(stage(s): {', '.join(stages)})")
            if len(jitters) < 4:
                cause += "; remaining jitters exhausted the portfolio"
            entry = dict(ii=ii, outcome="skipped", cause=cause)
        else:
            entry = dict(
                ii=ii, outcome="exhausted",
                cause="no certificate — portfolio budget exhausted "
                      "without a validated placement (or no schedule "
                      "exists at this II)")
        entry["stages"] = stages
        entry["certified_jitters"] = jitters
        out.append(entry)
    return out


def _routing(result) -> dict:
    ports = getattr(result, "ports_per_vio", None) or {}
    n_vios = len(ports)
    total = int(sum(ports.values()))
    n_route = int(getattr(result, "n_routing_pes", 0))
    mode = getattr(result, "mode", "")
    if mode == "busmap":
        note = (f"{n_route} routing PE(s) under the BusMap baseline "
                f"(one port per datum, routing-PE broadcast; "
                f"{n_vios} multi-consumer VIO(s))")
    else:
        note = (f"{n_route} routing PE(s) with bandwidth allocation "
                f"({total} delivery port(s) across {n_vios} "
                f"multi-consumer VIO(s); BusMap would broadcast "
                f"through routing PEs instead)")
    return dict(n_routing_pes=n_route, n_vios=n_vios,
                total_ports=total, note=note)


def _coverage(spans, flight) -> list[dict]:
    """Harvest-round curve; spans carrying per-round coverage attrs
    (exact timings) win over flight events when both exist."""
    rounds: list[dict] = []
    for rec in spans:
        if rec.name in ("portfolio", "portfolio-device") \
                and "coverage" in rec.attrs:
            rounds.append(dict(
                ii=rec.attrs.get("ii"), round=rec.attrs.get("round"),
                coverage=float(rec.attrs["coverage"]),
                best=rec.attrs.get("best"), t=rec.t1))
    if rounds:
        rounds.sort(key=lambda r: r["t"])
        return rounds
    for ev in flight:
        if ev.get("kind") == "harvest-round":
            rounds.append(dict(
                ii=ev.get("ii"), round=ev.get("round"),
                coverage=float(ev.get("coverage", 0.0)),
                best=ev.get("best"), t=ev.get("t")))
    return rounds


def _race(result, spans, flight) -> dict | None:
    backend = getattr(result, "backend", "")
    info: dict = {}
    for rec in spans:
        if rec.name == "race":
            info.update({k: rec.attrs[k] for k in
                         ("winner", "cancel_latency_s",
                          "loser_iters_after_cancel")
                         if k in rec.attrs})
    for ev in flight:
        if ev.get("kind") == "race-winner":
            info.setdefault("winner", ev.get("winner"))
            if ev.get("cancel_latency_s") is not None:
                info.setdefault("cancel_latency_s",
                                ev["cancel_latency_s"])
    if backend.startswith("race:"):
        info.setdefault("winner", backend.split(":", 1)[1])
    return info or None


def explain_result(result, *, tracer=None, flight=None) -> ExplainReport:
    """Build an `ExplainReport` from a `MappingResult`-shaped object.
    ``tracer`` is the (optional) live `Tracer` the run was recorded
    under; ``flight`` overrides the result's own attached ``flight``
    dump (dicts as produced by `FlightRecorder.dump`)."""
    if flight is None:
        flight = tuple(getattr(result, "flight", ()) or ())
    spans = list(tracer.finished) if tracer is not None else []
    certs = list(getattr(result, "certificates", ()) or ())
    kicks = int(tracer.counter_value("portfolio.kicks")) \
        if tracer is not None else 0
    return ExplainReport(
        ok=bool(result.ok), mode=result.mode, ii=int(result.ii),
        mii=int(result.mii), backend=getattr(result, "backend", ""),
        attempts=int(getattr(result, "attempts", 0)),
        proved_infeasible=bool(getattr(result, "proved_infeasible",
                                       False)),
        optimal=bool(getattr(result, "optimal", False)),
        escalation=_escalation(result, certs),
        routing=_routing(result),
        coverage=_coverage(spans, flight),
        kicks=kicks,
        race=_race(result, spans, flight),
        n_flight_events=len(flight))


# ------------------------------------------------------------------- cli
def main(argv: list[str] | None = None) -> int:
    """``python -m repro.obs.explain <result.bin> [--json]`` — explain
    a serialized result (`MappingResult.to_bytes` written to a file,
    e.g. by the serve tier's artifact store)."""
    import argparse

    from repro.core.bandmap import MappingResult

    ap = argparse.ArgumentParser(
        description="Explain a serialized MappingResult")
    ap.add_argument("path", help="file holding MappingResult.to_bytes")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report as JSON")
    args = ap.parse_args(argv)
    with open(args.path, "rb") as fh:
        res = MappingResult.from_bytes(fh.read())
    report = explain_result(res)
    if args.json:
        print(json.dumps(report.as_dict(), indent=1, default=str))
    else:
        print(report.render())
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
