"""Observability layer: spans over every mapping phase, one metrics store.

Three modules:

- `trace` — `Tracer` (nestable spans on the monotonic clock, structured
  attributes, counters) and `NullTracer` / `live()` — the ``tracer=None``
  contract that keeps untraced engine runs bit-identical and
  allocation-free.
- `registry` — `MetricsRegistry`: counters, gauges and histograms
  (p50/p95/p99) behind one lock; the backing store for
  `serve.MappingService.metrics()`.
- `export` — plain-JSON dump and Chrome trace-event serialization; a
  traced `map_dfg` run opens directly in Perfetto / chrome://tracing.

Span taxonomy (STABLE PUBLIC VOCABULARY)
----------------------------------------

These phase names are an interface: `benchmarks/bench_mis.py` records
per-row phase breakdowns keyed on them and `check_regression.py` gates
counters derived from traced runs, so renaming one is a breaking change
to the bench baseline.  The engine emits:

===============  =====================================================
span name        emitted by / attributes
===============  =====================================================
``map-dfg``      `core.bandmap.map_dfg` root span — ``mode``, ``n_ops``
``static-prepass``  demand-bound II floor pass — ``floor``, ``skipped``
``schedule``     per-(II, jitter) modulo schedule — ``ii``, ``jitter``
``conflict-build``  `conflict.build_conflict_graph` — ``n_vertices``,
                 ``n_edges``
``certify``      `certify.certify_ii_infeasible` — ``ii``, ``jitter``,
                 ``stage``, ``nodes``, ``orbit_skips``
``portfolio-init``  constructive warm-starts + `PortfolioSBTS` build
                 (on big graphs this is the dominant pre-search cost) —
                 ``ii``, ``jitter``, ``seeds``
``portfolio``    one `PortfolioSBTS` harvest round — ``ii``, ``round``,
                 ``coverage``, ``best``
``portfolio-device``  one `mis_device.DeviceSBTS` harvest round (the
                 accelerator-resident engine, ``engine="device"``) —
                 same attrs as ``portfolio``
``repair``       ejection-chain repair of a near-complete solution
                 (includes the lazy row-cache unpack) — ``shortfall``
``validate``     `validate_mapping` replay of a candidate solution
``exact-csp``    `exact.backend` per-(II, jitter) complete search —
                 ``ii``, ``jitter``, ``verdict``, ``nodes``
``race``         `exact.race` arbitration — ``winner``,
                 ``cancel_latency_s``, ``loser_iters_after_cancel``
``race-side``    one side of the race — ``side``, ``wall_s``
``comap-region``  `comap.co_map` per-region mapping — ``region``,
                 ``round``, ``ii``
``arbitrate``    cross-region bus arbitration — ``retries``
``merge-replay``  merged-binding validation in `co_map`
===============  =====================================================

Counters (deterministic, gated by ``check_regression.py``):
``portfolio.iters``, ``certify.csp_nodes``, ``certify.orbit_skips``,
``exact.validations``, ``comap.arbitration_retries``.
Gauges: ``portfolio.coverage``, ``portfolio.best``, serve's
``queue_depth``.

Tracer-threading rule (for future engine code)
----------------------------------------------

Every engine entry point takes ``tracer=None`` (keyword-only), converts
it exactly once via ``live(tracer)``, and passes the live handle down.
Code may check ``tracer is None`` / ``is not None`` but must NEVER
branch on trace *content* (span timings, counter values) — tracing is
observation only, and the ``tracer-default-none`` rule in
`repro.analysis.astlint` enforces both halves on the engine modules.
"""

from .registry import NULL_COUNTER, Counter, MetricsRegistry, NullCounter
from .trace import NULL_TRACER, NullTracer, SpanRecord, Tracer, live
from .export import (from_json, to_chrome_trace, to_json,
                     write_chrome_trace, write_json)

#: The stable span-name vocabulary documented above.
PHASES = (
    "map-dfg", "static-prepass", "schedule", "conflict-build", "certify",
    "portfolio-init", "portfolio", "portfolio-device", "repair",
    "validate", "exact-csp",
    "race", "race-side", "comap-region", "arbitrate", "merge-replay",
)

__all__ = [
    "Counter", "MetricsRegistry", "NullCounter", "NULL_COUNTER",
    "Tracer", "NullTracer", "NULL_TRACER", "SpanRecord", "live",
    "to_json", "from_json", "to_chrome_trace", "write_chrome_trace",
    "write_json", "PHASES",
]
