"""Observability layer: spans, metrics, flight events, exposition.

Six modules:

- `trace` — `Tracer` (nestable spans on the monotonic clock, structured
  attributes, counters) and `NullTracer` / `live()` — the ``tracer=None``
  contract that keeps untraced engine runs bit-identical and
  allocation-free.
- `registry` — `MetricsRegistry`: counters, gauges and histograms
  (p50/p95/p99) behind one lock; the backing store for
  `serve.MappingService.metrics()`.  Drained windows
  (``snapshot(reset=True)``) fold into a cumulative store, so one
  consumer's interval scrape never zeroes another's lifetime view.
- `export` — plain-JSON dump and Chrome trace-event serialization; a
  traced `map_dfg` run opens directly in Perfetto / chrome://tracing.
- `flight` — `FlightRecorder`: a bounded lock-guarded ring of
  structured events, cheap enough to stay on in production; failed
  results carry its `dump()` (``MappingResult.flight``).  The
  ``record=None`` contract mirrors ``tracer=None``.
- `explain` — `explain_result` / `ExplainReport`: narrate a
  `MappingResult` (II escalation causes, routing-PE accounting,
  coverage curve, race outcome); also ``MappingResult.explain()`` and
  the ``python -m repro.obs.explain`` CLI.
- `expo` — serve-tier exposition: Prometheus text rendering of
  registry snapshots (with a shard/worker label dimension), the JSONL
  `AccessLog`, and digest-keyed deterministic `head_sample`.

Span taxonomy (STABLE PUBLIC VOCABULARY)
----------------------------------------

These phase names are an interface: `benchmarks/bench_mis.py` records
per-row phase breakdowns keyed on them and `check_regression.py` gates
counters derived from traced runs, so renaming one is a breaking change
to the bench baseline.  The engine emits:

===============  =====================================================
span name        emitted by / attributes
===============  =====================================================
``map-dfg``      `core.bandmap.map_dfg` root span — ``mode``, ``n_ops``
``static-prepass``  demand-bound II floor pass — ``floor``, ``skipped``
``schedule``     per-(II, jitter) modulo schedule — ``ii``, ``jitter``
``conflict-build``  `conflict.build_conflict_graph` — ``n_vertices``,
                 ``n_edges``
``certify``      `certify.certify_ii_infeasible` — ``ii``, ``jitter``,
                 ``stage``, ``nodes``, ``orbit_skips``
``portfolio-init``  constructive warm-starts + `PortfolioSBTS` build
                 (on big graphs this is the dominant pre-search cost) —
                 ``ii``, ``jitter``, ``seeds``
``portfolio``    one `PortfolioSBTS` harvest round — ``ii``, ``round``,
                 ``coverage``, ``best``
``portfolio-device``  one `mis_device.DeviceSBTS` harvest round (the
                 accelerator-resident engine, ``engine="device"``) —
                 same attrs as ``portfolio``
``repair``       ejection-chain repair of a near-complete solution
                 (includes the lazy row-cache unpack) — ``shortfall``
``validate``     `validate_mapping` replay of a candidate solution
``exact-csp``    `exact.backend` per-(II, jitter) complete search —
                 ``ii``, ``jitter``, ``verdict``, ``nodes``
``race``         `exact.race` arbitration — ``winner``,
                 ``cancel_latency_s``, ``loser_iters_after_cancel``
``race-side``    one side of the race — ``side``, ``wall_s``
``comap-region``  `comap.co_map` per-region mapping — ``region``,
                 ``round``, ``ii``
``arbitrate``    cross-region bus arbitration — ``retries``
``merge-replay``  merged-binding validation in `co_map`
===============  =====================================================

Counters (deterministic, gated by ``check_regression.py``):
``portfolio.iters``, ``portfolio.kicks``, ``certify.csp_nodes``,
``certify.orbit_skips``, ``exact.validations``,
``comap.arbitration_retries``.
Gauges: ``portfolio.coverage``, ``portfolio.best``, serve's
``queue_depth``.

Flight-event taxonomy (STABLE PUBLIC VOCABULARY)
------------------------------------------------

The flight recorder's event kinds are pinned like ``PHASES`` — the
explain reports and the serve postmortem tooling key on them, so
renaming one is a breaking change to every stored ``flight`` dump:

===============  =====================================================
event kind       emitted by / attributes
===============  =====================================================
``phase-begin``  `map_dfg` major-phase entry — ``phase`` (``map-dfg``,
                 ``static-prepass``), plus the phase's identity attrs
``phase-end``    matching exit — ``phase``, outcome attrs (``ok``,
                 ``ii``, ``floor``, ...)
``attempt``      one (II, jitter) combination entered — ``ii``,
                 ``jitter``
``static-skip``  II below the static demand floor — ``ii``, ``floor``
``certificate``  (II, jitter) proven unbindable — ``ii``, ``jitter``,
                 ``stage``, ``nodes``
``harvest-round``  one portfolio harvest round — ``ii``, ``jitter``,
                 ``round``, ``coverage``, ``best``
``validate-reject``  validator rejected a complete candidate — ``ii``,
                 ``source`` (``csp`` | ``portfolio``)
``cancelled``    cooperative cancel observed — ``ii``
``race-cancel``  `exact.race` cancel request issued — ``winner``
``race-winner``  race arbitration settled — ``winner``,
                 ``cancel_latency_s``
``comap-round``  one co-mapping round finished — ``ii``, ``round``,
                 ``ok_regions``
``comap-arbitrate``  arbitration verdict — ``ii``, ``round``, ``ok``
``serve-admit``  request dispatched to a mapping worker — ``digest``,
                 ``tenant``
``serve-reject``  request resolved without mapping — ``digest``,
                 ``reason`` (``static`` | ``negative-cache``)
``serve-crash``  mapping worker raised — ``digest``, ``error``
===============  =====================================================

Tracer-threading rule (for future engine code)
----------------------------------------------

Every engine entry point takes ``tracer=None`` (keyword-only), converts
it exactly once via ``live(tracer)``, and passes the live handle down.
Code may check ``tracer is None`` / ``is not None`` but must NEVER
branch on trace *content* (span timings, counter values) — tracing is
observation only, and the ``tracer-default-none`` rule in
`repro.analysis.astlint` enforces both halves on the engine modules.
The flight recorder carries the identical contract on its ``record``
parameter (``recording(record)``, ``record is None`` checks only),
enforced by the twin ``recorder-default-none`` rule.
"""

from .registry import NULL_COUNTER, Counter, MetricsRegistry, NullCounter
from .trace import NULL_TRACER, NullTracer, SpanRecord, Tracer, live
from .export import (from_json, to_chrome_trace, to_json,
                     write_chrome_trace, write_json)
from .flight import (NULL_RECORDER, FlightEvent, FlightRecorder,
                     NullFlightRecorder, recording)
from .explain import ExplainReport, explain_result
from .expo import (ACCESS_LOG_FIELDS, AccessLog, head_sample,
                   parse_prometheus, render_prometheus)

#: The stable span-name vocabulary documented above.
PHASES = (
    "map-dfg", "static-prepass", "schedule", "conflict-build", "certify",
    "portfolio-init", "portfolio", "portfolio-device", "repair",
    "validate", "exact-csp",
    "race", "race-side", "comap-region", "arbitrate", "merge-replay",
)

#: The stable flight-event vocabulary documented above (the flight
#: analogue of ``PHASES`` — every `FlightRecorder.emit` kind in the
#: engine and serve tier is one of these).
EVENTS = (
    "phase-begin", "phase-end", "attempt", "static-skip", "certificate",
    "harvest-round", "validate-reject", "cancelled",
    "race-cancel", "race-winner", "comap-round", "comap-arbitrate",
    "serve-admit", "serve-reject", "serve-crash",
)

__all__ = [
    "Counter", "MetricsRegistry", "NullCounter", "NULL_COUNTER",
    "Tracer", "NullTracer", "NULL_TRACER", "SpanRecord", "live",
    "to_json", "from_json", "to_chrome_trace", "write_chrome_trace",
    "write_json", "PHASES",
    "FlightRecorder", "NullFlightRecorder", "NULL_RECORDER",
    "FlightEvent", "recording", "EVENTS",
    "ExplainReport", "explain_result",
    "AccessLog", "ACCESS_LOG_FIELDS", "head_sample",
    "render_prometheus", "parse_prometheus",
]
