"""Trace serialization: plain JSON and Chrome trace-event format.

Two shapes out of one `Tracer`:

- :func:`to_json` / :func:`from_json` — a lossless plain-dict dump of
  the finished spans plus the registry snapshot (counters/gauges/
  histograms), suitable for bench artifacts and round-trip tests.
- :func:`to_chrome_trace` — the Chrome trace-event JSON array format
  (``{"traceEvents": [...]}`` with complete events, ``ph: "X"``),
  which opens directly in Perfetto (https://ui.perfetto.dev) or
  chrome://tracing.  Timestamps and durations are microseconds from
  the tracer epoch; each recording thread becomes one Perfetto track.

`write_chrome_trace` / `write_json` are the one-call file writers the
demo and bench harness use.
"""

from __future__ import annotations

import json
import os

from .trace import SpanRecord, Tracer


def to_json(tracer: Tracer) -> dict:
    """Lossless plain-dict dump: spans in finish order plus the metrics
    snapshot.  Round-trips through :func:`from_json`."""
    return {
        "spans": [
            dict(sid=r.sid, parent=r.parent, name=r.name, t0=r.t0,
                 t1=r.t1, tid=r.tid, depth=r.depth, attrs=r.attrs)
            for r in tracer.finished
        ],
        "metrics": tracer.registry.snapshot(),
    }


def from_json(payload: dict) -> list[SpanRecord]:
    """Rebuild the span records from a :func:`to_json` payload."""
    return [SpanRecord(**span) for span in payload["spans"]]


def to_chrome_trace(tracer: Tracer, process_name: str = "repro") -> dict:
    """Chrome trace-event JSON object format.  Complete ("X") events,
    microsecond timestamps; counters become one final "C" event so the
    totals show as a Perfetto counter track."""
    events = []
    tids = {}
    for rec in tracer.finished:
        # Perfetto wants small stable tids; remap OS idents in order of
        # first appearance so track 0 is the main thread.
        tid = tids.setdefault(rec.tid, len(tids))
        events.append({
            "name": rec.name,
            "ph": "X",
            "ts": rec.t0 * 1e6,
            "dur": (rec.t1 - rec.t0) * 1e6,
            "pid": 0,
            "tid": tid,
            "args": {k: _jsonable(v) for k, v in rec.attrs.items()},
        })
    counters = tracer.registry.snapshot()["counters"]
    if counters:
        t_end = max((e["ts"] + e["dur"] for e in events), default=0.0)
        events.append({
            "name": "counters", "ph": "C", "ts": t_end,
            "pid": 0, "tid": 0,
            "args": {k: _jsonable(v) for k, v in counters.items()},
        })
    events.append({
        "name": "process_name", "ph": "M", "ts": 0, "pid": 0, "tid": 0,
        "args": {"name": process_name},
    })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _jsonable(value):
    """Coerce span-attribute values to JSON-safe scalars (numpy ints
    and floats appear in engine attrs; anything exotic becomes repr)."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    try:
        import numpy as np
        if isinstance(value, np.integer):
            return int(value)
        if isinstance(value, np.floating):
            return float(value)
    except ImportError:  # pragma: no cover - numpy is a hard dep here
        pass
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    return repr(value)


def write_chrome_trace(tracer: Tracer, path: str,
                       process_name: str = "repro") -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(to_chrome_trace(tracer, process_name), fh)
    return path


def write_json(tracer: Tracer, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(to_json(tracer), fh)
    return path
