"""End-to-end training driver.

Wires together: config registry → planner (bandwidth-allocating sharding)
→ data pipeline → AdamW → jitted train_step → checkpoint manager →
fault-recovery loop.  On this container it runs real training for smoke/
small configs on CPU; on a TPU fleet the same file is the per-host entry
(`jax.distributed.initialize` + the production mesh).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
      --steps 20 --batch 8 --seq 128

``--preset lm100m`` trains the ~100M-param example model (examples/
train_lm100m.py wraps this).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, make_pipeline
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models import model as M
from repro.models.transformer import ModelConfig
from repro.optim import AdamW, cosine_schedule
from repro.runtime import FailureInjector, run_with_recovery
from repro.core import planner as planner_mod

LM100M = ModelConfig(
    name="lm100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, head_dim=64, d_ff=3072, vocab=32000, tie_embeddings=True)


def build(cfg: ModelConfig, *, batch: int, seq: int, lr: float,
          steps: int, mesh=None, seed: int = 0):
    mesh = mesh or make_smoke_mesh()
    plan = planner_mod.plan(cfg, "train", seq, batch, mesh)
    rules = sh.Rules(plan.rules, mesh)
    optimizer = AdamW(lr=cosine_schedule(lr, max(steps // 20, 1), steps))
    params = M.init_params(cfg, seed)
    opt_state = optimizer.init(params)
    state = (params, opt_state, jnp.zeros((), jnp.int32))
    raw_step = M.make_train_step(cfg, optimizer)

    @jax.jit
    def train_step(state, batch):
        with sh.use_rules(rules):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            return raw_step(state, batch)

    data = make_pipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed,
        n_vision_tokens=cfg.n_vision_tokens, d_model=cfg.d_model,
        enc_seq=cfg.enc_seq))
    return state, train_step, data, plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced SMOKE_CONFIG")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.arch == "lm100m":
        cfg = LM100M
    elif args.smoke:
        cfg = get_smoke_config(args.arch)
    else:
        cfg = get_config(args.arch)

    state, train_step, data, plan = build(
        cfg, batch=args.batch, seq=args.seq, lr=args.lr, steps=args.steps)
    n = M.count_params(cfg)
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")
    print(plan.summary())

    ckpt = CheckpointManager(args.ckpt, every=args.ckpt_every)
    injector = None
    if args.inject_failure_at >= 0:
        injector = FailureInjector({args.inject_failure_at: (0, "host")})

    t0 = time.time()
    state, history, restarts = run_with_recovery(
        train_step=train_step, init_state=state, data=data,
        ckpt_manager=ckpt, n_steps=args.steps, injector=injector)
    dt = time.time() - t0

    for i, h in enumerate(history):
        if i % args.log_every == 0 or i == len(history) - 1:
            print(f"step {i:5d} loss={h['loss']:.4f} ce={h['ce']:.4f} "
                  f"gnorm={h['grad_norm']:.2f}")
    tok_s = args.batch * args.seq * len(history) / dt
    print(f"done: {len(history)} steps in {dt:.1f}s "
          f"({tok_s:,.0f} tok/s), restarts={restarts}, "
          f"final loss {history[-1]['loss']:.4f} "
          f"(first {history[0]['loss']:.4f})")
    return history


if __name__ == "__main__":
    main()
