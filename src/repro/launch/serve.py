"""Batched serving driver: wave-scheduled batching — a wave of requests is
admitted together, prefilled in one fused call, then decoded in lockstep;
the next wave starts when the wave completes.  (Slot-level continuous
batching needs per-slot cache positions — noted as future work in
DESIGN.md; the dense shared-position cache is what the decode_32k dry-run
cells lower.)

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --requests 8 --gen 32

The BandMap framing: model weights are the highest-RD data at serving
time (reused by every request every step), so throughput is
weight-bandwidth-bound until the batch is large — the planner's multicast
allocation (TP-resident shards) is what amortises them.  Before serving,
the driver prints the plan's **bandwidth rounds**
(`planner.schedule_transfer_rounds`): which per-step collectives can
overlap and which contend for the same mesh axis — the serialization
depth of the serving step.

The CGRA mapping analogue of this loop lives behind ``--map-trace N``:
instead of LLM requests, serve ``N`` kernel-mapping requests through the
`repro.serve.MappingService` (canonical-hash cache + batched scheduler
over the portfolio engine) and report hit-rate and latency percentiles.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model as M


class WaveServer:
    """Admit `slots` requests at a time; one prefill + N decode ticks."""

    def __init__(self, cfg, params, *, slots: int = 4, s_max: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.s_max = s_max
        self._prefill = jax.jit(
            lambda p, b, c: M.prefill_step(cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, b, c: M.serve_step(cfg, p, b, c))

    def run_wave(self, prompts: np.ndarray, max_new: int,
                 extra_inputs: dict | None = None) -> np.ndarray:
        """prompts: (B<=slots, S) int32 (padded to equal length).
        Returns generated tokens (B, max_new)."""
        b, s = prompts.shape
        assert b <= self.slots and s + max_new <= self.s_max
        pad = self.slots - b
        toks = np.pad(prompts, ((0, pad), (0, 0)))
        cache = M.init_cache(self.cfg, self.slots, self.s_max)
        batch = {"tokens": jnp.asarray(toks, jnp.int32)}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, cache = self._prefill(self.params, batch, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [np.asarray(nxt)]
        for _ in range(max_new - 1):
            step_batch = {"tokens": nxt[:, None]}
            if extra_inputs and self.cfg.family == "encdec":
                step_batch.update(extra_inputs)
            nxt2, _, cache = self._decode(self.params, step_batch, cache)
            nxt = nxt2[:, 0]
            out.append(np.asarray(nxt))
        return np.stack(out, axis=1)[:b]


def serving_transfer_rounds(cfg, *, batch: int, seq: int,
                            tp: int = 16) -> tuple[list[list[str]], str]:
    """Bandwidth rounds of the decode step's transfer plan.

    Builds the planner's transfer DFG for a TP-sharded decode step and
    peels it into contention-free rounds with
    `planner.schedule_transfer_rounds` — the ROADMAP's bridge from the
    CGRA binder to mesh collective scheduling, wired into the serving
    driver.  Returns (rounds, printable summary)."""
    from repro.core import planner
    from repro.launch.mesh import mesh_stub

    plan = planner.plan(cfg, "decode", seq, batch,
                        mesh_stub({"data": 1, "model": tp}),
                        arch=cfg.name, shape="serve")
    rounds = planner.schedule_transfer_rounds(plan)
    moving = [t for t in plan.transfers if t.bytes_per_step > 0]
    text = (f"transfer plan: {len(plan.transfers)} classes, "
            f"{len(moving)} moving bytes -> {len(rounds)} bandwidth "
            f"round(s) {rounds}")
    return rounds, text


def run_map_trace(n_requests: int = 64, *, scale: str = "8x8",
                  rows: int = 8, cols: int = 8, seed: int = 0,
                  max_workers: int | None = None,
                  art_dir: str | None = None,
                  quiet: bool = False) -> dict:
    """Serve a Zipf kernel-mapping trace through `MappingService`.

    This is the mapping-as-a-service loop: canonical-hash cache in
    front of the portfolio engine, batched admission, per-request
    metrics.  Returns the service metrics dict."""
    from repro.core.cgra import CGRAConfig
    from repro.core.workloads import make_request_trace
    from repro.serve import MappingService, MapRequest

    trace = make_request_trace(n_requests, scale=scale, seed=seed)
    cgra = CGRAConfig(rows=rows, cols=cols)
    svc = MappingService(max_workers=max_workers, art_dir=art_dir,
                         base_seed=seed)
    svc.map_batch([MapRequest(dfg=t.dfg, cgra=cgra, deadline=t.deadline,
                              tenant=t.tenant, req_id=f"r{i}")
                   for i, t in enumerate(trace)])
    metrics = svc.metrics()
    if not quiet:
        print(svc.summary())
        print(f"  sources: {metrics['sources']}")
        print(f"  cache:   {metrics['cache']}")
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--map-trace", type=int, default=0, metavar="N",
                    help="serve N kernel-mapping requests through "
                         "MappingService instead of LLM requests")
    ap.add_argument("--trace-scale", default="8x8",
                    choices=["4x4", "8x8", "16x16"])
    args = ap.parse_args(argv)

    if args.map_trace:
        from repro.serve import DEFAULT_ART_DIR
        rows = cols = int(args.trace_scale.split("x")[0])
        # Persistent artifact store: a second invocation hits the disk
        # tier for every kernel this one mapped.
        return run_map_trace(args.map_trace, scale=args.trace_scale,
                             rows=rows, cols=cols,
                             art_dir=DEFAULT_ART_DIR)

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    _, rounds_text = serving_transfer_rounds(
        cfg, batch=args.slots, seq=args.prompt_len + args.gen)
    print(rounds_text)
    params = M.init_params(cfg, 0)
    server = WaveServer(cfg, params, slots=args.slots,
                        s_max=args.prompt_len + args.gen + 8)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.requests, args.prompt_len),
                           dtype=np.int32)
    extra = {}
    if cfg.family == "encdec":
        extra = {"audio_embeds": jnp.zeros(
            (args.slots, cfg.enc_seq, cfg.d_model), jnp.bfloat16)}
    if cfg.n_vision_tokens:
        extra = {"vision_embeds": jnp.zeros(
            (args.slots, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}

    t0 = time.time()
    outs = []
    for lo in range(0, args.requests, args.slots):
        wave = prompts[lo:lo + args.slots]
        outs.append(server.run_wave(wave, args.gen, extra))
    dt = time.time() - t0
    total = args.requests * args.gen
    print(f"served {args.requests} requests × {args.gen} tokens in "
          f"{dt:.1f}s ({total / dt:.1f} tok/s); "
          f"sample: {outs[0][0][:8].tolist()}")
    return outs


if __name__ == "__main__":
    main()
