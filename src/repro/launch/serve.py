"""Batched serving driver: wave-scheduled batching — a wave of requests is
admitted together, prefilled in one fused call, then decoded in lockstep;
the next wave starts when the wave completes.  (Slot-level continuous
batching needs per-slot cache positions — noted as future work in
DESIGN.md; the dense shared-position cache is what the decode_32k dry-run
cells lower.)

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --requests 8 --gen 32

The BandMap framing: model weights are the highest-RD data at serving
time (reused by every request every step), so throughput is
weight-bandwidth-bound until the batch is large — the planner's multicast
allocation (TP-resident shards) is what amortises them.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model as M


class WaveServer:
    """Admit `slots` requests at a time; one prefill + N decode ticks."""

    def __init__(self, cfg, params, *, slots: int = 4, s_max: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.s_max = s_max
        self._prefill = jax.jit(
            lambda p, b, c: M.prefill_step(cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, b, c: M.serve_step(cfg, p, b, c))

    def run_wave(self, prompts: np.ndarray, max_new: int,
                 extra_inputs: dict | None = None) -> np.ndarray:
        """prompts: (B<=slots, S) int32 (padded to equal length).
        Returns generated tokens (B, max_new)."""
        b, s = prompts.shape
        assert b <= self.slots and s + max_new <= self.s_max
        pad = self.slots - b
        toks = np.pad(prompts, ((0, pad), (0, 0)))
        cache = M.init_cache(self.cfg, self.slots, self.s_max)
        batch = {"tokens": jnp.asarray(toks, jnp.int32)}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, cache = self._prefill(self.params, batch, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [np.asarray(nxt)]
        for _ in range(max_new - 1):
            step_batch = {"tokens": nxt[:, None]}
            if extra_inputs and self.cfg.family == "encdec":
                step_batch.update(extra_inputs)
            nxt2, _, cache = self._decode(self.params, step_batch, cache)
            nxt = nxt2[:, 0]
            out.append(np.asarray(nxt))
        return np.stack(out, axis=1)[:b]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    params = M.init_params(cfg, 0)
    server = WaveServer(cfg, params, slots=args.slots,
                        s_max=args.prompt_len + args.gen + 8)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.requests, args.prompt_len),
                           dtype=np.int32)
    extra = {}
    if cfg.family == "encdec":
        extra = {"audio_embeds": jnp.zeros(
            (args.slots, cfg.enc_seq, cfg.d_model), jnp.bfloat16)}
    if cfg.n_vision_tokens:
        extra = {"vision_embeds": jnp.zeros(
            (args.slots, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}

    t0 = time.time()
    outs = []
    for lo in range(0, args.requests, args.slots):
        wave = prompts[lo:lo + args.slots]
        outs.append(server.run_wave(wave, args.gen, extra))
    dt = time.time() - t0
    total = args.requests * args.gen
    print(f"served {args.requests} requests × {args.gen} tokens in "
          f"{dt:.1f}s ({total / dt:.1f} tok/s); "
          f"sample: {outs[0][0][:8].tolist()}")
    return outs


if __name__ == "__main__":
    main()
