"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the real single device.

Mesh axes:
- ``pod``   (2)  — cross-pod data parallelism (optical links; gradient
                   all-reduce, optionally int8-compressed);
- ``data``  (16) — in-pod data parallel / FSDP axis;
- ``model`` (16) — tensor-parallel axis (heads / d_ff / experts' d_ff).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


# Axis name -> size per mesh flavour.  Single source of truth for the
# production shapes: `make_production_mesh` builds the jax mesh from it
# (device state is only touched there), and planner-side consumers that
# must not instantiate a mesh (benchmarks/roofline.py's transfer-round
# column) read the same dict instead of hardcoding a copy.
PRODUCTION_MESH_AXES: dict[str, dict[str, int]] = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


def make_production_mesh(*, multi_pod: bool = False):
    axes = PRODUCTION_MESH_AXES["multi" if multi_pod else "single"]
    return _mk(tuple(axes.values()), tuple(axes))


def mesh_stub(axes: dict):
    """Planner-facing mesh stand-in: `core.planner.plan` only reads
    ``mesh.shape``, so consumers that must not instantiate a jax mesh
    (roofline's transfer-round column, the serving driver's plan
    report) pass this instead."""
    import types
    return types.SimpleNamespace(shape=dict(axes))


def make_smoke_mesh():
    """1-device mesh with the production axis names (unit tests)."""
    return _mk((1, 1), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes present in the mesh (pod first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
