"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the real single device.

Mesh axes:
- ``pod``   (2)  — cross-pod data parallelism (optical links; gradient
                   all-reduce, optionally int8-compressed);
- ``data``  (16) — in-pod data parallel / FSDP axis;
- ``model`` (16) — tensor-parallel axis (heads / d_ff / experts' d_ff).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (unit tests)."""
    return _mk((1, 1), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes present in the mesh (pod first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
