"""Multi-pod dry-run: prove the distribution config is coherent by
lowering + compiling every (arch × shape × mesh) cell against 512
placeholder host devices, then extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
      --shape train_4k --mesh single [--plan optimized] [--out artifacts/dryrun]

MUST stay the first two lines: jax locks the device count on first init.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, applicable, get_config, input_specs  # noqa: E402
from repro.core import planner as planner_mod  # noqa: E402
from repro.launch import hlo_analysis          # noqa: E402
from repro.launch import sharding as sh        # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_device_count  # noqa: E402
from repro.models import model as M            # noqa: E402
from repro.models.transformer import ModelConfig  # noqa: E402
from repro.optim import AdamW                  # noqa: E402

# TPU v5e-like hardware constants (roofline denominators).
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


# ------------------------------------------------------------ cache axes
def cache_axes_for_path(path: str, shape: tuple) -> tuple:
    p = path.lower()
    nd = len(shape)
    if "/c_kv/" in p:
        return ("layer", "batch", "seq", "kv_lora")
    if "/k_pe/" in p:
        return ("layer", "batch", "seq", None)
    if "/conv/" in p:
        return ("layer", "batch", None, "ssm_inner")
    if "/ssm/" in p:
        return ("layer", "batch", "ssm_heads", None, "ssm_state")
    if "cross_kv" in p:
        return ("layer", "batch", "seq", "heads", "head_dim")
    if p.endswith("/k/") or p.endswith("/v/"):
        return ("layer", "batch", "seq", "kv_heads", "head_dim")
    if "/pos/" in p:
        return ("layer",)[: nd]
    return (None,) * nd


def batch_axes_for_path(path: str, shape: tuple) -> tuple:
    if "embeds" in path:
        return ("batch", "seq", "embed")
    return ("batch", "seq")[: len(shape)]


def tree_shardings(tree, axes_fn, rules):
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: rules.sharding_for(
            axes_fn(sh.path_str(kp), a.shape), a.shape), tree)


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k of routed experts)."""
    n = M.count_params(cfg)
    if cfg.family == "moe" and cfg.n_experts:
        routed = 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff \
            * cfg.n_layers
        n -= routed * (cfg.n_experts - cfg.top_k) // cfg.n_experts
    return n


def model_flops(cfg: ModelConfig, kind: str, tokens: int) -> float:
    """Useful FLOPs: 6·N_active·D for training, 2·N_active·D forward."""
    n_act = active_params(cfg)
    return (6.0 if kind == "train" else 2.0) * n_act * tokens


# ------------------------------------------------------------- lowering
def build_step(cfg: ModelConfig, kind: str, rules, optimizer):
    """Returns (fn, in_specs, in_shardings, donate) ready to jit."""
    if kind == "train":
        train_step = M.make_train_step(cfg, optimizer)

        def fn(state, batch):
            with sh.use_rules(rules):
                return train_step(state, batch)
        return fn
    if kind == "prefill":
        def fn(params, batch, cache):
            with sh.use_rules(rules):
                return M.prefill_step(cfg, params, batch, cache)
        return fn

    def fn(params, batch, cache):
        with sh.use_rules(rules):
            return M.serve_step(cfg, params, batch, cache)
    return fn


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               optimized: bool = False, cfg: ModelConfig | None = None):
    """Lower + compile one cell; returns the result record dict."""
    cell = SHAPES[shape]
    cfg = cfg or get_config(arch)
    if optimized:
        # Beyond-paper §Perf variant (EXPERIMENTS.md logs each knob's
        # hypothesis → before/after): capacity MoE (active-FLOPs batched
        # matmuls), bf16 backward cotangents, absorbed-MLA decode.
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe_impl="capacity", logits_dtype="bfloat16",
            mla_absorbed=True)
    ok, reason = applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "skipped": True, "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_device_count(mesh)
    plan = planner_mod.plan(cfg, cell.kind, cell.seq_len, cell.global_batch,
                            mesh, optimized=optimized, arch=arch,
                            shape=shape)
    rules = sh.Rules(plan.rules, mesh)
    specs = input_specs(cfg, cell)
    optimizer = AdamW()

    param_specs = M.param_specs(cfg)
    p_shard = sh.params_shardings(param_specs, rules)
    t0 = time.time()

    if cell.kind == "train":
        opt_specs = jax.eval_shape(optimizer.init, param_specs)
        o_shard = sh.params_shardings(opt_specs, rules)
        step_spec = jax.ShapeDtypeStruct((), jnp.int32)
        state_specs = (param_specs, opt_specs, step_spec)
        state_shard = (p_shard, o_shard,
                       rules.sharding_for((), ()))
        b_shard = tree_shardings(specs["batch"], batch_axes_for_path, rules)
        fn = build_step(cfg, "train", rules, optimizer)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(state_shard, b_shard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,),
            ).lower(state_specs, specs["batch"])
    else:
        c_shard = tree_shardings(specs["cache"], cache_axes_for_path, rules)
        b_shard = tree_shardings(specs["batch"], batch_axes_for_path, rules)
        fn = build_step(cfg, cell.kind, rules, optimizer)
        out_sh = (None, c_shard) if cell.kind == "prefill" \
            else (None, None, c_shard)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=out_sh, donate_argnums=(2,),
            ).lower(param_specs, specs["batch"], specs["cache"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # Trip-count-corrected numerators (cost_analysis counts while bodies
    # once; see hlo_analysis.py).  All values are per-device.
    ana = hlo_analysis.analyze(compiled.as_text())

    flops_dev = float(ana["dot_flops"])
    bytes_dev = float(ana["hbm_bytes"])
    coll_dev = float(ana["collective_total_bytes"])
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    mf = model_flops(cfg, cell.kind, tokens)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "optimized": optimized, "chips": chips, "skipped": False,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "hlo_flops": flops_dev, "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "collective_detail": {
                "bytes": ana["collective_bytes"],
                "counts": ana["collective_counts"]},
            "cost_analysis_flops_uncorrected":
                float(cost.get("flops", 0.0)),
            "cost_analysis_bytes_uncorrected":
                float(cost.get("bytes accessed", 0.0)),
        },
        "memory_analysis": {
            k: getattr(mem, k, None) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")} if mem is not None else None,
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_total": mf,
            "hlo_flops_total": flops_dev * chips,
            "useful_flops_ratio": mf / max(flops_dev * chips, 1.0),
            "roofline_fraction": max(terms.values()) and
            compute_s / max(terms.values()),
        },
        "planner": {
            "rules": {k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in plan.rules.items()},
            "predicted_collective_bytes": plan.collective_bytes,
            "transfers": [dataclasses_to_dict(t) for t in plan.transfers],
        },
    }
    return rec


def dataclasses_to_dict(t):
    import dataclasses as dc
    return dc.asdict(t)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--plan", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    rec = lower_cell(args.arch, args.shape,
                     multi_pod=args.mesh == "multi",
                     optimized=args.plan == "optimized")
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.mesh}" + \
        ("__opt" if args.plan == "optimized" else "")
    path = os.path.join(args.out, tag + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec.get("skipped"):
        print(f"SKIP {tag}: {rec['reason']}")
    else:
        r = rec["roofline"]
        print(f"PASS {tag}: compile={rec['compile_s']}s "
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dom={r['dominant']} "
              f"useful={r['useful_flops_ratio']:.2f}")
    return rec


if __name__ == "__main__":
    main()
