"""Post-SPMD HLO analysis with while-loop trip-count correction.

XLA's ``cost_analysis()`` (and any naive text scan) counts a while-loop
body ONCE — a `lax.scan` over L layers under-reports FLOPs, HBM bytes and
collective bytes by ~L×.  This module re-derives the three roofline
numerators from ``compiled.as_text()``:

1. split the module into computations and build a per-computation symbol
   table (instruction name -> shape) including header parameters,
2. build call-graph multipliers: while bodies/conds inherit
   ``known_trip_count`` (conservative 1 when absent); fusions, reduces,
   calls, conditionals inherit their caller's multiplier,
3. count per computation, scaled by its multiplier:
   - dot FLOPs        2 · numel(result) · prod(lhs contracting dims),
   - HBM bytes        result + operand bytes per op at fusion boundaries
                      (fusion internals stay in registers/VMEM),
   - collective bytes result-shape bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute.

Exact for dot-dominated modules (transformer steps); elementwise FLOPs are
not counted (they are VPU, not MXU work) — documented in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_RE_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"\(?(\w+)\[([\d,]*)\]")
_RE_OPNAME = re.compile(r"\]\S*\s+([a-z][\w\-]*)\(")
_RE_PARAM = re.compile(r"%?([\w\.\-]+):\s*\(?(\w+)\[([\d,]*)\]")
_RE_OPERAND = re.compile(r"%([\w\.\-]+)")
_RE_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _nbytes(dtype: str, dims: str) -> float:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n)


def _numel(dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n)


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVES})
    calls: list = dataclasses.field(default_factory=list)


def _split_computations(text: str):
    """yields (name, is_entry, header, body_lines)"""
    comps = []
    cur = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            if ") -> " in stripped and stripped.endswith("{"):
                name = stripped.split()[1] if stripped.startswith("ENTRY") \
                    else stripped.split()[0]
                cur = [name.lstrip("%"), stripped.startswith("ENTRY"),
                       stripped, []]
        else:
            if stripped == "}":
                comps.append(tuple(cur))
                cur = None
            else:
                cur[3].append(line)
    if cur is not None:
        comps.append(tuple(cur))
    return comps


def _analyze_comp(name: str, header: str, body: list[str],
                  fusion_boundary: bool) -> CompStats:
    st = CompStats()
    shapes: dict[str, tuple[str, str]] = {}
    for m in _RE_PARAM.finditer(header):
        shapes[m.group(1)] = (m.group(2), m.group(3))

    # pass 1: symbol table
    parsed = []
    for line in body:
        md = _RE_DEF.match(line)
        if not md:
            continue
        iname, rtype, rdims = md.groups()
        shapes[iname] = (rtype, rdims)
        mo = _RE_OPNAME.search(line)
        op = mo.group(1) if mo else ""
        parsed.append((iname, rtype, rdims, op, line))

    # pass 2: counts
    for iname, rtype, rdims, op, line in parsed:
        if op == "while":
            trip = 1
            mt = _RE_TRIP.search(line)
            if mt:
                trip = int(mt.group(1))
            for mc in re.finditer(r"(?:body|condition)=%?([\w\.\-]+)",
                                  line):
                st.calls.append((mc.group(1), trip))
        else:
            for mc in re.finditer(
                    r"(?:calls|to_apply|true_computation|false_computation|"
                    r"branch_computations=\{[^}]*?)=%?([\w\.\-]+)", line):
                st.calls.append((mc.group(1), 1))

        if op in COLLECTIVES:
            st.coll_bytes[op] += _nbytes(rtype, rdims)
            st.coll_counts[op] += 1

        args = line[line.find("(", line.find(op)) :] if op else ""
        operands = [o for o in _RE_OPERAND.findall(args) if o in shapes]

        if op in ("dot", "ragged-dot") and operands:
            lhs_t, lhs_d = shapes[operands[0]]
            contract = 1.0
            mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if mcd and mcd.group(1):
                ldims = [int(d) for d in lhs_d.split(",") if d]
                for ci in mcd.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        contract *= ldims[int(ci)]
            st.dot_flops += 2.0 * _numel(rdims) * contract

        if fusion_boundary:
            # HBM traffic model (shared with the top_ops drill-down).
            # Sliced/aliased-access ops move only the slice: XLA aliases
            # dynamic-update-slice in place — counting the full operand
            # would overcount a lax.scan body by ~L×.
            _count_line(st, line, shapes)
    return st


def analyze(text: str) -> dict:
    comps = _split_computations(text)
    stats: dict[str, CompStats] = {}
    entry = None
    for name, is_entry, header, body in comps:
        stats[name] = _analyze_comp(
            name, header, body,
            fusion_boundary=not name.startswith("fused_"))
        if is_entry:
            entry = name

    mult: dict[str, float] = {}

    def visit(name: str, m: float, depth=0):
        if name not in stats or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, trip in stats[name].calls:
            visit(callee, m * trip, depth + 1)

    if entry:
        visit(entry, 1.0)

    total = CompStats()
    for name, st in stats.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total.dot_flops += m * st.dot_flops
        total.hbm_bytes += m * st.hbm_bytes
        for k in COLLECTIVES:
            total.coll_bytes[k] += m * st.coll_bytes[k]
            total.coll_counts[k] += int(m * st.coll_counts[k])
    return {
        "dot_flops": total.dot_flops,
        "hbm_bytes": total.hbm_bytes,
        "collective_bytes": total.coll_bytes,
        "collective_counts": total.coll_counts,
        "collective_total_bytes": sum(total.coll_bytes.values()),
        "n_computations": len(comps),
        "entry": entry,
    }


def top_ops(text: str, k: int = 15) -> list[tuple]:
    """Top-k instructions by multiplied HBM bytes — the §Perf drill-down.
    Returns (bytes, mult, op, instr, computation, result_type)."""
    comps = _split_computations(text)
    stats = {c[0]: _analyze_comp(c[0], c[2], c[3], True) for c in comps}
    entry = next((c[0] for c in comps if c[1]), None)
    mult: dict[str, float] = {}

    def visit(name, m, depth=0):
        if name not in stats or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, trip in stats[name].calls:
            visit(callee, m * trip, depth + 1)

    if entry:
        visit(entry, 1.0)
    rows = []
    for name, is_entry, header, body in comps:
        m = mult.get(name, 0.0)
        if not m or name.startswith("fused_"):
            continue
        one = CompStats()
        shapes = {}
        for mm in _RE_PARAM.finditer(header):
            shapes[mm.group(1)] = (mm.group(2), mm.group(3))
        for line in body:
            md = _RE_DEF.match(line)
            if not md:
                continue
            iname, rt, rd = md.groups()
            shapes[iname] = (rt, rd)
        for line in body:
            md = _RE_DEF.match(line)
            if not md:
                continue
            before = one.hbm_bytes
            _count_line(one, line, shapes)
            delta = one.hbm_bytes - before
            if delta:
                iname = md.group(1)
                mo = _RE_OPNAME.search(line)
                rows.append((m * delta, m,
                             mo.group(1) if mo else "?", iname, name,
                             f"{md.group(2)}[{md.group(3)}]"))
    rows.sort(reverse=True)
    return rows[:k]


def _count_line(st: CompStats, line: str, shapes: dict) -> None:
    """Single-line HBM accounting (same rules as _analyze_comp)."""
    md = _RE_DEF.match(line)
    if not md:
        return
    iname, rtype, rdims = md.groups()
    mo = _RE_OPNAME.search(line)
    op = mo.group(1) if mo else ""
    if op in ("parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", ""):
        return
    args = line[line.find("(", line.find(op)):] if op else ""
    operands = [o for o in _RE_OPERAND.findall(args) if o in shapes]
    res_b = _nbytes(rtype, rdims)
    if op == "dynamic-update-slice" or (
            op == "fusion" and "dynamic-update-slice" in iname):
        upd = [o for o in operands if shapes[o][1] != rdims]
        st.hbm_bytes += 2 * sum(_nbytes(*shapes[o]) for o in upd)
    elif op in ("dynamic-slice", "gather"):
        st.hbm_bytes += 2 * res_b
    elif op == "scatter":
        if operands:
            st.hbm_bytes += 3 * _nbytes(*shapes[operands[-1]])
    elif op == "while":
        pass
    elif op == "fusion":
        mk = re.search(r"kind=(k\w+)", line)
        kind = mk.group(1) if mk else "kLoop"
        if kind == "kInput":
            st.hbm_bytes += res_b + sum(
                _nbytes(*shapes[o]) for o in operands)
        elif kind == "kOutput":
            st.hbm_bytes += res_b + sum(
                _nbytes(*shapes[o]) for o in operands
                if shapes[o][1] != rdims)
        else:
            st.hbm_bytes += res_b + sum(
                min(_nbytes(*shapes[o]), res_b) for o in operands)
    else:
        st.hbm_bytes += res_b + sum(_nbytes(*shapes[o]) for o in operands)
