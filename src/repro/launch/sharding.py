"""Logical-axis sharding: rules map logical axis names to mesh axes.

Model code annotates activations with ``constrain(x, ("batch","seq",...))``
and parameters are annotated by path-based ``axes_for_path``.  The active
rule set is installed by the launcher (``use_rules``) from the planner's
output; with no rules installed every annotation is a no-op, so tests and
single-device smoke runs never touch the mesh machinery.

Divisibility fallback: a logical→mesh mapping is dropped (replicated) for a
tensor dimension the mesh axis does not divide — e.g. qwen1.5's 20 heads on
a 16-way model axis.  This is the planner's "multicast beats relay"
degradation: replication of a high-reuse tensor is preferred over padded
sharding (DESIGN.md §2).
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


class Rules:
    """logical axis name -> mesh axis (str | tuple | None)."""

    def __init__(self, mapping: dict, mesh: Mesh):
        self.mapping = dict(mapping)
        self.mesh = mesh

    def spec_for(self, names: tuple, shape: tuple | None = None) -> P:
        """PartitionSpec for logical ``names``; drops non-divisible and
        duplicate mesh-axis entries (first occurrence wins)."""
        parts = []
        used: set = set()
        for i, nm in enumerate(names):
            mx = self.mapping.get(nm)
            if mx is None:
                parts.append(None)
                continue
            axes = (mx,) if isinstance(mx, str) else tuple(mx)
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                parts.append(None)
                continue
            size = _axis_size(self.mesh, axes)
            if shape is not None and shape[i] % size != 0:
                # divisibility fallback: keep the divisible prefix
                keep = []
                for a in axes:
                    if shape[i] % _axis_size(self.mesh, tuple(keep + [a])) \
                            == 0:
                        keep.append(a)
                axes = tuple(keep)
                if not axes:
                    parts.append(None)
                    continue
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
        return P(*parts)

    def sharding_for(self, names: tuple, shape: tuple | None = None):
        return NamedSharding(self.mesh, self.spec_for(names, shape))


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def active_rules() -> Rules | None:
    return getattr(_state, "rules", None)


def constrain(x, names: tuple):
    """with_sharding_constraint against the active rules (no-op without)."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.spec_for(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# --------------------------------------------------------- param axes map
def axes_for_path(path: str, shape: tuple) -> tuple:
    """Logical axis names for a parameter, from its pytree path.

    Conventions (see models/*.py): stacked layer params have a leading
    'layer' axis; expert weights lead with 'expert'; attention projections
    end with (heads|kv_heads, head_dim).
    """
    p = path.lower()
    nd = len(shape)

    def lead(*names):
        base = ("layer",) * (nd - len(names)) + tuple(names)
        return base

    if "embed/table" in p:
        return ("vocab", "embed")
    if "unembed" in p:
        return lead("embed", "vocab")
    if any(k in p for k in ("norm", "ln", "scale", "a_log", "dt_bias",
                            "d_skip")) and nd <= 2:
        return ("layer",) * (nd - 1) + ("embed",)
    if "router" in p:
        return lead("embed", "expert")
    if "w_gate" in p or "w_up" in p:
        return lead("expert", "embed", "mlp")
    if "w_down" in p:
        return lead("expert", "mlp", "embed")
    if "/q/" in p or "/k/" in p or "/v/" in p:
        if nd >= 3 and shape[-1] <= 512:
            return lead("embed", "heads", "head_dim") if "/q/" in p \
                else lead("embed", "kv_heads", "head_dim")
        return lead("heads", "head_dim") if nd >= 2 else lead("head_dim")
    if "/uk/" in p or "/uv/" in p:
        return lead("kv_lora", "heads", "head_dim")
    if "/dkv/" in p:
        return lead("embed", "kv_lora")
    if "/kpe/" in p:
        return lead("embed", "head_dim")
    if "/o/" in p:
        return lead("heads_merged", "embed")
    if "gate/" in p or "up/" in p:
        return lead("embed", "mlp")
    if "down/" in p:
        return lead("mlp", "embed")
    if "in_x" in p or "in_z" in p:
        return lead("embed", "ssm_inner")
    if "in_b" in p or "in_c" in p:
        return lead("embed", "ssm_state")
    if "in_dt" in p:
        return lead("embed", "ssm_heads")
    if "conv/w" in p:
        return lead("conv_w", "ssm_inner")
    if "conv/b" in p:
        return lead("ssm_inner")
    if "out/" in p:
        return lead("ssm_inner", "embed")
    # bias vectors and anything else: replicate non-layer dims
    return ("layer",) * (nd - 1) + (None,) if nd else ()


def path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/" + "/".join(parts) + "/"


def param_axes_tree(params):
    """Pytree of logical-axis tuples parallel to ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: axes_for_path(path_str(kp), a.shape), params)


def params_shardings(params, rules: Rules):
    """NamedSharding pytree for a param (or ShapeDtypeStruct) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: rules.sharding_for(
            axes_for_path(path_str(kp), a.shape), a.shape), params)
