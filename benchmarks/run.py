"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Outputs CSV blocks (name,value columns) and writes
artifacts/bench/<name>.csv.  Functions:

  fig5_ii        — II vs MII per CnKm, BandMap vs BusMap, ±GRF (Fig. 5)
  routing_pes    — routing-PE counts + reduction stats (§IV-B)
  mis_stats      — conflict-graph sizes / SBTS+repair solve stats (§III-B)
  ports          — allocated ports vs ceil(RD/M) (the §III-A policy)
  planner        — transfer-DFG bandwidth allocation per arch × shape,
                   predicted vs compiled collective bytes (beyond-paper)
  conflict_kernel— conflict-matrix build: bitset rows / Pallas kernel
                   vs python loops
  mis_engine     — bitset+portfolio engine vs seed dense engine
                   (details in artifacts/bench/bench_mis.json)
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (EXTRA_KERNELS, PAPER_KERNELS, cnkm_name,  # noqa: E402
                        make_cnkm, map_dfg)
from repro.core.cgra import CGRAConfig  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _emit(name: str, header: list[str], rows: list[list]):
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(header)
    w.writerows(rows)
    text = buf.getvalue()
    print(f"\n== {name} ==")
    print(text)
    os.makedirs(os.path.join(ART, "bench"), exist_ok=True)
    with open(os.path.join(ART, "bench", f"{name}.csv"), "w") as f:
        f.write(text)
    return rows


def _map_all(kernels, grf: int, quick: bool):
    out = {}
    cgra = CGRAConfig(grf=grf)
    for (n, m) in kernels:
        for mode in ("bandmap", "busmap"):
            kw = dict(mis_restarts=4, mis_iters=8000, max_ii=8) \
                if quick else dict(max_ii=12)
            out[(n, m, mode)] = map_dfg(make_cnkm(n, m), cgra, mode=mode,
                                        **kw)
    return out


def bench_fig5_ii(quick: bool = False):
    """Fig. 5: realized II vs MII (ratio = MII/II; 1.0 is best)."""
    rows = []
    for grf in (0, 8):
        res = _map_all(PAPER_KERNELS, grf, quick)
        for (n, m) in PAPER_KERNELS:
            rb = res[(n, m, "bandmap")]
            ru = res[(n, m, "busmap")]
            rows.append([cnkm_name(n, m), grf, rb.mii, rb.ii, ru.ii,
                         f"{rb.ii_ratio:.2f}", f"{ru.ii_ratio:.2f}",
                         int(rb.ok), int(ru.ok)])
    return _emit("fig5_ii",
                 ["kernel", "grf", "mii", "bandmap_ii", "busmap_ii",
                  "bandmap_ratio", "busmap_ratio", "bandmap_ok",
                  "busmap_ok"], rows)


def bench_routing_pes(quick: bool = False):
    """§IV-B: routing-PE counts; reduction for m>4 kernels."""
    rows = []
    res = _map_all(PAPER_KERNELS, 0, quick)
    reductions = []
    for (n, m) in PAPER_KERNELS:
        rb, ru = res[(n, m, "bandmap")], res[(n, m, "busmap")]
        red = (1 - rb.n_routing_pes / ru.n_routing_pes) * 100 \
            if ru.n_routing_pes else 0.0
        if m > 4 and ru.n_routing_pes:
            reductions.append(red)
        rows.append([cnkm_name(n, m), m, rb.n_routing_pes,
                     ru.n_routing_pes, f"{red:.1f}"])
    avg = sum(reductions) / len(reductions) if reductions else 0.0
    rows.append(["avg_reduction_m>4", "", "", "", f"{avg:.1f}"])
    rows.append(["max_reduction_m>4", "", "", "",
                 f"{max(reductions, default=0):.1f}"])
    return _emit("routing_pes",
                 ["kernel", "m", "bandmap_routing", "busmap_routing",
                  "reduction_pct"], rows)


def bench_mis_stats(quick: bool = False):
    """§III-B: conflict-graph sizes and MIS solve effort."""
    rows = []
    for (n, m) in PAPER_KERNELS:
        for mode in ("bandmap", "busmap"):
            r = map_dfg(make_cnkm(n, m), CGRAConfig(), mode=mode,
                        mis_restarts=4 if quick else 10,
                        mis_iters=8000 if quick else 20000,
                        max_ii=8 if quick else 12)
            rows.append([cnkm_name(n, m), mode, r.cg_size[0], r.cg_size[1],
                         r.mis_size, r.n_ops, r.attempts,
                         f"{r.wall_s:.2f}"])
    return _emit("mis_stats",
                 ["kernel", "mode", "V_C", "E_C", "mis", "n_ops",
                  "attempts", "wall_s"], rows)


def bench_ports(quick: bool = False):
    """§III-A policy: allocated ports Q vs ceil(RD/M); the port-starved
    extra kernel (C8K6) exercises the routing fallback."""
    rows = []
    kernels = PAPER_KERNELS + ([] if quick else EXTRA_KERNELS)
    for (n, m) in kernels:
        r = map_dfg(make_cnkm(n, m), CGRAConfig(), mode="bandmap",
                    mis_restarts=4 if quick else 8,
                    mis_iters=8000, max_ii=8)
        q_policy = math.ceil(m / 4)
        total = sum(r.ports_per_vio.values())
        rows.append([cnkm_name(n, m), m, q_policy, total,
                     n * q_policy, r.n_routing_pes, int(r.ok)])
    return _emit("ports",
                 ["kernel", "RD", "ceil(RD/M)", "ports_allocated",
                  "policy_total", "routing_fallback", "ok"], rows)


def bench_planner(quick: bool = False):
    """Beyond-paper: planner transfer DFG per arch×shape; predicted vs
    compiled collective bytes (from the dry-run artifacts)."""
    from repro.configs import ARCHS, SHAPES, get_config
    from repro.core import planner as planner_mod

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rows = []
    dr_dir = os.path.join(ART, "dryrun")
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape, cell in SHAPES.items():
            rec_path = os.path.join(dr_dir,
                                    f"{arch}__{shape}__single.json")
            compiled = None
            if os.path.exists(rec_path):
                with open(rec_path) as f:
                    rec = json.load(f)
                if not rec.get("skipped"):
                    compiled = rec["per_device"]["collective_bytes"]
            if compiled is None:
                continue
            plan = planner_mod.plan(cfg, cell.kind, cell.seq_len,
                                    cell.global_batch, FakeMesh(),
                                    arch=arch, shape=shape)
            top = max(plan.transfers, key=lambda t: t.bytes_per_step,
                      default=None)
            pred = plan.collective_bytes / 256    # per device
            rows.append([arch, shape, f"{pred:.3e}", f"{compiled:.3e}",
                         f"{pred / max(compiled, 1):.2f}",
                         top.tensor if top else "", top.rd if top else 0,
                         top.strategy if top else ""])
    return _emit("planner",
                 ["arch", "shape", "predicted_dev_bytes",
                  "compiled_dev_bytes", "ratio", "top_transfer", "rd",
                  "strategy"], rows)


def bench_conflict_kernel(quick: bool = False):
    """Conflict-matrix construction: packed-bitset rows (the engine's
    path) and the vectorised Pallas kernel vs python loops (the
    O(|V_C|²) hot spot)."""
    from repro.core import schedule_dfg
    from repro.core.conflict import (bitset_group_conflicts,
                                     build_conflict_graph,
                                     dense_conflicts_python)
    from repro.kernels.conflict_matrix.ops import conflict_matrix
    rows = []
    for (n, m) in [(2, 6), (5, 5), (4, 8)]:
        sched = schedule_dfg(make_cnkm(n, m), CGRAConfig())
        cg = build_conflict_graph(sched, CGRAConfig())
        t0 = time.perf_counter()
        for _ in range(3):
            bitset_group_conflicts(cg.vertices, cg.op_vertices, sched.ii)
        t_bits = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter()
        for _ in range(3):
            conflict_matrix(cg.vertices)
        t_fast = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter()
        dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
        t_slow = time.perf_counter() - t0
        rows.append([cnkm_name(n, m), cg.n, f"{t_bits*1e3:.2f}",
                     f"{t_fast*1e3:.2f}", f"{t_slow*1e3:.2f}",
                     f"{t_slow/t_bits:.1f}x"])
    return _emit("conflict_kernel",
                 ["kernel", "V_C", "bitset_ms", "vectorised_ms",
                  "python_ms", "bitset_speedup"], rows)


def bench_mis_engine(quick: bool = False):
    """Bitset + portfolio engine benchmark (full detail in
    artifacts/bench/bench_mis.json)."""
    from benchmarks.bench_mis import run_all
    bench = run_all(quick=quick)
    sp = bench["engine_speedup"]
    rows = [["engine_speedup_c5k5_ii2", sp["speedup"]],
            ["bitset_build_s", sp["bitset_build_s"]],
            ["seed_build_s", sp["seed_build_s"]],
            ["bitset_solve_s", sp["bitset_solve_s"]],
            ["seed_solve_s", sp["seed_solve_s"]]]
    for row in bench["straggler"]:
        rows.append([f"straggler_{row['kernel']}_{row['mode']}_wall_s",
                     row["wall_s"]])
        rows.append([f"straggler_{row['kernel']}_{row['mode']}_"
                     f"cert_total_s", row["cert_total_s"]])
    for row in bench["exact"]:
        rows.append([f"exact_{row['kernel']}_{row['mode']}_wall_s",
                     row["exact_wall_s"]])
        rows.append([f"exact_{row['kernel']}_{row['mode']}_gap",
                     row["gap"]])
        rows.append([f"race_{row['kernel']}_{row['mode']}_winner",
                     row["race_winner"]])
    for row in bench["cgra_8x8"]:
        rows.append([f"map8x8_{row['kernel']}_{row['mode']}_wall_s",
                     row["wall_s"]])
    for row in bench["comap"]:
        rows.append([f"{row['mode']}_{row['kernel']}_wall_s",
                     row["wall_s"]])
    for row in bench["group_move"]:
        rows.append([f"group_move_{row['kernel']}_{row['mode']}_wall_s",
                     row["wall_s"]])
        cov = row.get("coverage")
        if isinstance(cov, dict):
            last = sorted(cov, key=int)[-1]
            rows.append([f"group_move_{row['kernel']}_{row['mode']}_"
                         f"coverage@{last}", f"{cov[last]}/{row['n_ops']}"])
    for row in bench["device_engine"]:
        rows.append([f"device_{row['kernel']}_{row['mode']}_wall_s",
                     row["wall_s"]])
        rows.append([f"device_{row['kernel']}_{row['mode']}_coverage",
                     row["coverage"]])
    for row in bench["serve"]:
        rows.append([f"serve_{row['kernel']}_{row['mode']}_rps",
                     row["rps"]])
        if "hit_rate" in row:
            rows.append([f"serve_{row['kernel']}_{row['mode']}_hit_rate",
                         row["hit_rate"]])
        if "speedup" in row:
            rows.append([f"serve_{row['kernel']}_{row['mode']}_speedup",
                         row["speedup"]])
    return _emit("mis_engine", ["name", "value"], rows)


BENCHES = {
    "fig5_ii": bench_fig5_ii,
    "routing_pes": bench_routing_pes,
    "mis_stats": bench_mis_stats,
    "ports": bench_ports,
    "planner": bench_planner,
    "conflict_kernel": bench_conflict_kernel,
    "mis_engine": bench_mis_engine,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(quick=args.quick)


if __name__ == "__main__":
    main()
