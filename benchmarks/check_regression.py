"""Bench-regression gate for CI: diff a fresh ``bench_mis.json`` against
the committed baseline and fail on a >2x wall-time regression of any
kernel (kernel_table, straggler, exact, cgra_8x8, comap, group_move,
device_engine and serve rows are all keyed by (kernel, mode) — the
exact section gates the complete prover and the exact-vs-portfolio
race, the comap section the 16x16 scale and the multi-kernel
co-mapping path, group_move the kick neighbourhood's flag-on/off
engine comparison, device_engine the accelerator-resident portfolio's
K-sweep walls against the numpy oracle, serve the Zipf-trace
cacheless/cached throughput pair of the mapping service).

  python benchmarks/check_regression.py \
      --baseline /tmp/bench_baseline.json \
      --fresh artifacts/bench/bench_mis.json [--factor 2.0]

Sub-``--floor``-second entries are compared against the floor instead of
their raw baseline so scheduler noise on millisecond-scale maps cannot
trip the gate.  Individual rows missing on either side are reported but
do not fail (new kernels appear, old ones retire); a whole *section*
present in the baseline but absent from the fresh JSON fails loudly —
that is a benchmark that silently stopped running, not a retired
kernel.  A slower-than-2x row also fails.

The committed baseline is produced on a developer machine while the gate
runs on shared CI runners, so raw wall-clock comparison would conflate
machine speed with engine regressions.  The frozen seed-engine solver
(``engine_speedup.seed_solve_s`` — dense numpy, kept verbatim precisely
so it never changes with the live engine) is timed in both runs and used
as a machine-speed reference: budgets are scaled up by
``fresh_seed_solve / baseline_seed_solve`` when the current machine is
slower (never tightened when it is faster).

Counter gate
------------
Rows carrying a ``counters`` dict (kernel_table and device_engine do,
via the traced `repro.obs` runs) are additionally gated on each
counter's value — certify CSP nodes and portfolio iterations today.
These are seed-determined and machine-independent, so the gate is
*tighter* than the wall gate (``--counter-factor``, default 1.25, no
machine-speed scaling) with its own absolute floor
(``--counter-floor``, default 500: a jump from 10 to 40 nodes is
noise-free but meaningless).  A counter present in the baseline row
but absent from the fresh row fails — an engine path silently lost its
instrumentation.

Phase-presence gate
-------------------
Rows carrying a ``phases`` breakdown (kernel_table) are checked for
*presence*: a phase recorded in the baseline row but absent from the
fresh row fails the same instrumentation-loss way.  Per-phase walls
are NOT value-gated — they are sub-second slices where scheduler noise
dominates; the row's total wall already rides the wall gate.
"""

from __future__ import annotations

import argparse
import json
import sys


SECTIONS = ("kernel_table", "straggler", "exact", "cgra_8x8", "comap",
            "group_move", "device_engine", "serve")


def _rows(bench: dict) -> dict[tuple, float]:
    out = {}
    for section in SECTIONS:
        for row in bench.get(section, []):
            out[(section, row["kernel"], row["mode"])] = row["wall_s"]
    return out


def _counter_rows(bench: dict) -> dict[tuple, float]:
    """(section, kernel, mode, counter) -> value, for every row that
    carries a ``counters`` dict."""
    out = {}
    for section in SECTIONS:
        for row in bench.get(section, []):
            for name, value in (row.get("counters") or {}).items():
                out[(section, row["kernel"], row["mode"], name)] = value
    return out


def _phase_names(bench: dict) -> set[tuple]:
    """(section, kernel, mode, phase) for every row that carries a
    traced ``phases`` breakdown — presence only (see module docstring)."""
    out = set()
    for section in SECTIONS:
        for row in bench.get(section, []):
            for name in (row.get("phases") or {}):
                out.add((section, row["kernel"], row["mode"], name))
    return out


def check(baseline: dict, fresh: dict, factor: float = 2.0,
          floor: float = 0.2, counter_factor: float = 1.25,
          counter_floor: float = 500.0) -> list[str]:
    old, new = _rows(baseline), _rows(fresh)
    failures = []
    for section in SECTIONS:
        if baseline.get(section) and not fresh.get(section):
            failures.append(
                f"section {section!r} present in baseline but missing "
                f"from fresh run — a benchmark silently stopped running")
    scale = 1.0
    ref_old = baseline.get("engine_speedup", {}).get("seed_solve_s")
    ref_new = fresh.get("engine_speedup", {}).get("seed_solve_s")
    if ref_old and ref_new:
        scale = max(ref_new / ref_old, 1.0)
        print(f"machine-speed scale (frozen seed solver "
              f"{ref_old:.2f}s -> {ref_new:.2f}s): x{scale:.2f}")
    for key in sorted(old.keys() | new.keys()):
        section, kernel, mode = key
        if key not in old or key not in new:
            side = "baseline" if key not in old else "fresh run"
            print(f"note: {section}:{kernel}:{mode} missing from {side}")
            continue
        budget = factor * scale * max(old[key], floor)
        status = "FAIL" if new[key] > budget else "ok"
        print(f"{status}: {section}:{kernel}:{mode} "
              f"{old[key]:.3f}s -> {new[key]:.3f}s (budget {budget:.3f}s)")
        if new[key] > budget:
            failures.append(
                f"{section}:{kernel}:{mode}: {old[key]:.3f}s -> "
                f"{new[key]:.3f}s exceeds {factor}x budget")
    # Deterministic counter gate — no machine-speed scaling (CSP nodes
    # and portfolio iterations are seed-determined), tighter factor.
    old_c, new_c = _counter_rows(baseline), _counter_rows(fresh)
    for key in sorted(old_c):
        section, kernel, mode, name = key
        label = f"{section}:{kernel}:{mode}:{name}"
        if key not in new_c:
            failures.append(
                f"{label}: counter present in baseline but missing "
                f"from fresh run — instrumentation silently lost")
            continue
        budget = counter_factor * max(old_c[key], counter_floor)
        status = "FAIL" if new_c[key] > budget else "ok"
        print(f"{status}: {label} {old_c[key]:.0f} -> {new_c[key]:.0f} "
              f"(budget {budget:.0f})")
        if new_c[key] > budget:
            failures.append(
                f"{label}: {old_c[key]:.0f} -> {new_c[key]:.0f} "
                f"exceeds {counter_factor}x counter budget")
    # Phase-presence gate: a traced phase that vanished from a row the
    # baseline recorded it on is lost instrumentation, not noise.  Only
    # rows present on both sides participate (retired kernels are the
    # wall gate's "note", not a failure).
    old_p, new_p = _phase_names(baseline), _phase_names(fresh)
    fresh_rows = _rows(fresh)
    for key in sorted(old_p - new_p):
        section, kernel, mode, name = key
        if (section, kernel, mode) not in fresh_rows:
            continue
        failures.append(
            f"{section}:{kernel}:{mode}: phase {name!r} present in "
            f"baseline but missing from fresh run — phase "
            f"instrumentation silently lost")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--factor", type=float, default=2.0)
    ap.add_argument("--floor", type=float, default=0.2)
    ap.add_argument("--counter-factor", type=float, default=1.25)
    ap.add_argument("--counter-floor", type=float, default=500.0)
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = check(baseline, fresh, args.factor, args.floor,
                     args.counter_factor, args.counter_floor)
    if failures:
        print("\nbench regression gate FAILED:")
        for msg in failures:
            print(" -", msg)
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
