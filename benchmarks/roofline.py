"""Roofline aggregation: read artifacts/dryrun/*.json (written by
launch/dryrun.py) and print/write the §Roofline table — per (arch × shape
× mesh): three roofline terms in seconds, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, the roofline fraction
(compute_term / max(all terms) — the score §Perf drives up), and the
transfer-plan **bandwidth-round depth** (`schedule_transfer_rounds`):
how many serialized rounds the cell's per-step collectives need when
same-axis transfers cannot overlap.  A collective-bound cell with round
depth > 1 is one whose collective term the planner could shrink by
overlapping rounds across axes.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single|multi|both]
"""

from __future__ import annotations

import argparse
import csv
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_records(mesh: str = "both", include_opt: bool = True):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "dryrun", "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh != "both" and r.get("mesh") != mesh:
            continue
        if not include_opt and r.get("optimized"):
            continue
        recs.append(r)
    return recs


def transfer_round_depth(arch: str, shape: str, mesh: str,
                         optimized: bool = False) -> int | None:
    """Bandwidth-round depth of a cell's transfer plan, or None when the
    cell cannot be planned (unknown arch/shape/mesh).  Mesh axes come
    from `launch.mesh.PRODUCTION_MESH_AXES` — the dict the dryrun
    records' meshes were actually built from."""
    try:
        from repro.configs import SHAPES, get_config
        from repro.core.planner import plan, schedule_transfer_rounds
        from repro.launch.mesh import PRODUCTION_MESH_AXES, mesh_stub
        axes = PRODUCTION_MESH_AXES.get(mesh)
        cfg = get_config(arch)
        cell = SHAPES[shape]
    except (ImportError, KeyError, ModuleNotFoundError):
        return None
    if axes is None:
        return None
    p = plan(cfg, cell.kind, cell.seq_len, cell.global_batch,
             mesh_stub(axes), optimized=optimized, arch=arch,
             shape=shape)
    return len(schedule_transfer_rounds(p))


def fmt_row(r) -> list:
    if r.get("skipped"):
        return [r["arch"], r["shape"], r["mesh"], "SKIP", "", "", "", "",
                "", "", r["reason"][:40]]
    ro = r["roofline"]
    frac = ro["compute_s"] / max(ro["compute_s"], ro["memory_s"],
                                 ro["collective_s"])
    depth = transfer_round_depth(r["arch"], r["shape"], r["mesh"],
                                 bool(r.get("optimized")))
    return [r["arch"], r["shape"], r["mesh"],
            ("opt" if r.get("optimized") else "base"),
            f"{ro['compute_s']:.4f}", f"{ro['memory_s']:.4f}",
            f"{ro['collective_s']:.4f}",
            ro["dominant"].replace("_s", ""),
            f"{ro['useful_flops_ratio']:.2f}", f"{frac:.3f}",
            "" if depth is None else depth]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    args = ap.parse_args()
    recs = load_records(args.mesh)
    header = ["arch", "shape", "mesh", "plan", "compute_s", "memory_s",
              "collective_s", "dominant", "useful_ratio",
              "roofline_fraction", "xfer_rounds"]
    rows = [fmt_row(r) for r in recs]
    widths = [max(len(str(x)) for x in [h] + [row[i] for row in rows])
              for i, h in enumerate(header)]
    print("  ".join(h.ljust(w) for h, w in zip(header, widths)))
    for row in rows:
        print("  ".join(str(x).ljust(w) for x, w in zip(row, widths)))

    os.makedirs(os.path.join(ART, "bench"), exist_ok=True)
    with open(os.path.join(ART, "bench", "roofline.csv"), "w") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)

    live = [r for r in recs if not r.get("skipped")]
    if live:
        worst = min(live, key=lambda r: r["roofline"]["compute_s"] /
                    max(r["roofline"].values() if False else
                        [r["roofline"]["compute_s"],
                         r["roofline"]["memory_s"],
                         r["roofline"]["collective_s"]]))
        coll = max(live, key=lambda r: r["roofline"]["collective_s"] /
                   max(r["roofline"]["compute_s"],
                       r["roofline"]["memory_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} × "
              f"{worst['shape']} ({worst['mesh']})")
        print(f"most collective-bound:  {coll['arch']} × "
              f"{coll['shape']} ({coll['mesh']})")


if __name__ == "__main__":
    main()
