"""Engine benchmark: packed-bitset conflict build + multi-seed SBTS
portfolio vs the seed (dense numpy) formulation, plus the per-kernel
mapping table the paper's figures summarise.

  PYTHONPATH=src python -m benchmarks.bench_mis [--quick]

Sections (all written to artifacts/bench/bench_mis.json):

  engine_speedup — C5K5 BusMap at II=2 (the densest feasible instance):
                   graph build + K-restart MIS solve, seed dense engine
                   vs bitset portfolio at an equal iteration budget.
                   The acceptance bar is >= 3x.
  kernel_table   — map wall-time, II, MII, routing PEs per CnKm kernel
                   and mode under the default mapper parameters.
  straggler      — the BusMap II=MII infeasibility stragglers (C2K8,
                   C5K5): end-to-end wall time with the certificate +
                   pressure-edge pipeline, per-certificate stats, and
                   the wall time of the certificate-less seed pipeline
                   for comparison.
  exact          — the complete prover (`repro.exact`) and the
                   exact-vs-portfolio race per paper kernel: wall
                   times side by side, the portfolio's optimality gap
                   against the proven-optimal II, and the race winner.
  cgra_8x8       — end-to-end maps on an 8x8 CGRAConfig, the scenario
                   the dense engine could not reach comfortably
                   (|V_C| > 2000).
  comap          — 16x16 scale: a |V_C| > 10^4 generated loop kernel
                   mapped solo (row-cache fallback regime), plus
                   two/three-kernel co-mapping through `repro.comap`
                   (regions + common II + arbitration + merged
                   validator replay).
  group_move     — the tightly-coupled family (high-fan-out VIOs,
                   cross-row consumer pressure): coverage vs iterations
                   for the cold-started portfolio with the group-move
                   kick off/on at equal budget, plus the end-to-end
                   map at pinned II (flag off stalls below full
                   coverage; flag on binds and validates).
  device_engine  — the accelerator-resident portfolio
                   (`core.mis_device.DeviceSBTS`, vmapped Pallas SBTS,
                   interpret mode on CPU) vs the numpy oracle at an
                   equal lock-step iteration budget: coverage-at-budget
                   and wall on an 8x8-fabric conflict graph with the
                   device side swept over the vmapped seed count K
                   (32/256/1024 — the knob a real accelerator scales
                   almost for free), plus a reduced 16x16-scale row.
  serve          — mapping-as-a-service: a ~200-request Zipf-popularity
                   trace of permuted 8x8-scale kernels, served
                   cacheless (one `map_dfg` per request) vs through
                   `repro.serve.MappingService` (canonical-hash cache
                   + batched scheduler, every hit validator-replayed).
                   The acceptance bar is >= 5x throughput for the
                   cached path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (PAPER_KERNELS, cnkm_name, make_cnkm,  # noqa: E402
                        map_dfg, schedule_dfg)
from repro.core.cgra import CGRAConfig  # noqa: E402
from repro.core.conflict import (_dep_ok,  # noqa: E402
                                 build_conflict_graph, constructive_init,
                                 dense_conflicts_python)
from repro.core.mis import solve_mis_portfolio  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


# --------------------------------------------------------------------------
# Frozen seed-engine reference (dense bool adjacency, single-trajectory
# SBTS) — kept verbatim so the speedup comparison stays honest as the
# live engine evolves.
# --------------------------------------------------------------------------
def _seed_dense_build(cg, sched) -> np.ndarray:
    """Seed conflict-rule evaluation over prebuilt vertices.  Vertex
    enumeration is excluded from both sides' timings (conservative: it
    is charged to the bitset side only, inside build_conflict_graph)."""
    adj = dense_conflicts_python(cg.vertices, cg.op_vertices, sched.ii)
    for src, dst in {(e.src, e.dst) for e in sched.dfg.edges}:
        for i in cg.op_vertices[src]:
            for j in cg.op_vertices[dst]:
                if not _dep_ok(cg.vertices[i], cg.vertices[j]):
                    adj[i, j] = adj[j, i] = True
    return adj


def _seed_greedy_mis(adj, rng):
    n = adj.shape[0]
    deg = adj.sum(axis=1).astype(np.int64)
    alive = np.ones(n, dtype=bool)
    in_s = np.zeros(n, dtype=bool)
    while alive.any():
        cand = np.flatnonzero(alive)
        d = deg[cand] + rng.random(cand.size)
        v = cand[int(np.argmin(d))]
        in_s[v] = True
        kill = adj[v] & alive
        alive[v] = False
        alive[kill] = False
        deg -= adj[:, kill].sum(axis=1)
    return in_s


def _seed_solve_mis(adj, *, target=None, max_iters=20000, tenure=7,
                    seed=0, init=None):
    n = adj.shape[0]
    rng = np.random.default_rng(seed)
    in_s = init.copy() if init is not None else _seed_greedy_mis(adj, rng)
    conf = adj[:, in_s].sum(axis=1).astype(np.int64)
    best = in_s.copy()
    best_size = int(in_s.sum())
    if target is not None and best_size >= target:
        return best
    tabu = np.zeros(n, dtype=np.int64)
    stall = 0
    for it in range(1, max_iters + 1):
        size = int(in_s.sum())
        addable = (~in_s) & (conf == 0)
        if addable.any():
            order = np.flatnonzero(addable)
            rng.shuffle(order)
            for v in order:
                if not in_s[v] and conf[v] == 0:
                    in_s[v] = True
                    conf += adj[v]
            size = int(in_s.sum())
            if size > best_size:
                best_size, best = size, in_s.copy()
                stall = 0
                if target is not None and best_size >= target:
                    return best
            continue
        cand = np.flatnonzero((~in_s) & (conf == 1) & (tabu <= it))
        if cand.size:
            v = int(rng.choice(cand))
            u = int(np.flatnonzero(adj[v] & in_s)[0])
            in_s[u] = False
            conf -= adj[u]
            in_s[v] = True
            conf += adj[v]
            tabu[u] = it + tenure + int(rng.integers(0, 4))
            stall += 1
        else:
            stall += 3
        if stall > 60:
            members = np.flatnonzero(in_s)
            k = max(1, members.size // 10)
            for u in rng.choice(members, size=k, replace=False):
                in_s[u] = False
                conf -= adj[u]
                tabu[u] = it + tenure
            stall = 0
    return best


# --------------------------------------------------------------------------
def bench_engine_speedup(quick: bool = False) -> dict:
    """C5K5 BusMap at II=2 (the densest feasible instance): graph build
    plus the MIS restart budget `map_dfg` actually deploys at II = MII
    (2 x mis_restarts = 20 trajectories x mis_iters iterations), seed
    dense engine vs bitset portfolio.  Min of ``reps`` timings per side
    to damp machine noise."""
    cgra = CGRAConfig()
    iters = 4000 if quick else 20000
    k = 6 if quick else 20
    reps = 1 if quick else 2
    sched = schedule_dfg(make_cnkm(5, 5), cgra, mode="busmap", ii=2,
                         max_ii=2)
    n_ops = len(sched.dfg.ops)

    cg_for_inits = build_conflict_graph(sched, cgra)
    inits = [constructive_init(cg_for_inits, sched, cgra, seed=s)
             if s % 3 != 2 else None for s in range(k)]

    t_seed_build, t_seed_solve = 1e9, 1e9
    seed_sizes = []
    for _ in range(reps):
        t0 = time.perf_counter()
        adj = _seed_dense_build(cg_for_inits, sched)
        t_seed_build = min(t_seed_build, time.perf_counter() - t0)
        t0 = time.perf_counter()
        seed_sizes = []
        for s in range(k):
            sol = _seed_solve_mis(adj, target=n_ops, max_iters=iters,
                                  seed=s, init=inits[s])
            seed_sizes.append(int(sol.sum()))
        t_seed_solve = min(t_seed_solve, time.perf_counter() - t0)

    t_bit_build, t_bit_solve = 1e9, 1e9
    for _ in range(reps):
        t0 = time.perf_counter()
        cg = build_conflict_graph(sched, cgra)
        t_bit_build = min(t_bit_build, time.perf_counter() - t0)
        t0 = time.perf_counter()
        bests = solve_mis_portfolio(cg.bits, inits=inits, target=n_ops,
                                    max_iters=iters, seed=0)
        t_bit_solve = min(t_bit_solve, time.perf_counter() - t0)

    assert (cg.bits.to_dense() == adj).all(), "engines disagree on CG"
    seed_total = t_seed_build + t_seed_solve
    bit_total = t_bit_build + t_bit_solve
    out = dict(
        kernel="C5K5", mode="busmap", ii=2, n_vertices=cg.n,
        n_edges=cg.n_edges, restarts=k, iters_per_restart=iters,
        seed_build_s=round(t_seed_build, 4),
        seed_solve_s=round(t_seed_solve, 4),
        bitset_build_s=round(t_bit_build, 4),
        bitset_solve_s=round(t_bit_solve, 4),
        seed_best=max(seed_sizes),
        bitset_best=int(bests.sum(axis=1).max()),
        speedup=round(seed_total / bit_total, 2),
    )
    print(f"engine_speedup: seed {seed_total:.2f}s -> bitset "
          f"{bit_total:.2f}s = {out['speedup']}x "
          f"(best {out['seed_best']}/{out['bitset_best']} of {n_ops})")
    return out


def bench_kernel_table(quick: bool = False) -> list[dict]:
    """Map wall-time / II / routing PEs per kernel and mode, plus the
    traced per-phase wall breakdown and the deterministic engine
    counters `check_regression.py` gates (CSP nodes and portfolio
    iterations are seed-determined, so they gate far tighter than the
    noisy walls).

    Every run is recorded under a live `FlightRecorder` — flight-on is
    the production default, so its overhead deliberately rides these
    walls and the existing regression gate.  The per-run flight dumps
    and Perfetto traces land in ``artifacts/bench/`` for the nightly
    workflow to upload."""
    from repro.obs import FlightRecorder, Tracer, write_chrome_trace

    trace_dir = os.path.join(ART, "traces")
    os.makedirs(trace_dir, exist_ok=True)
    flights: dict[str, list] = {}
    rows = []
    kw = dict(mis_restarts=4, mis_iters=8000, max_ii=8) if quick else {}
    for (n, m) in PAPER_KERNELS:
        for mode in ("bandmap", "busmap"):
            tr = Tracer()
            rec = FlightRecorder()
            r = map_dfg(make_cnkm(n, m), CGRAConfig(), mode=mode,
                        tracer=tr, record=rec, **kw)
            phases = {name: dict(count=agg["count"],
                                 total_s=round(agg["total_s"], 4))
                      for name, agg in tr.phase_breakdown().items()}
            counters = tr.registry.snapshot()["counters"]
            rows.append(dict(
                kernel=cnkm_name(n, m), mode=mode, ok=r.ok, ii=r.ii,
                mii=r.mii, routing_pes=r.n_routing_pes,
                v_c=r.cg_size[0], e_c=r.cg_size[1],
                attempts=r.attempts, wall_s=round(r.wall_s, 3),
                phases=phases,
                counters=dict(
                    certify_csp_nodes=int(
                        counters.get("certify.csp_nodes", 0)),
                    portfolio_iters=int(
                        counters.get("portfolio.iters", 0)))))
            print(f"kernel_table: {rows[-1]}")
            label = f"{cnkm_name(n, m)}_{mode}"
            flights[label] = list(rec.dump())
            write_chrome_trace(
                tr, os.path.join(trace_dir, f"{label}.json"),
                process_name=label)
    with open(os.path.join(ART, "flight_kernel_table.json"), "w") as f:
        json.dump(flights, f, indent=1)
    return rows


def bench_stragglers(quick: bool = False) -> list[dict]:
    """C2K8/C5K5 BusMap end to end: the certificate stages prove every
    doomed (II, jitter) schedule — all of II=2 plus the II=3 jitters the
    portfolio used to grind on — unbindable in tens of milliseconds
    each, instead of spending the full portfolio budget per combination
    (~40-50 s in the seed engine).  ``seed_wall_s`` re-runs with
    certificates and pressure edges disabled for an in-place comparison
    (skipped under --quick)."""
    rows = []
    for (n, m) in [(2, 8), (5, 5)]:
        r = map_dfg(make_cnkm(n, m), CGRAConfig(), mode="busmap")
        cert_walls = [c.wall_s for c in r.certificates]
        row = dict(
            kernel=cnkm_name(n, m), mode="busmap", ok=r.ok, ii=r.ii,
            mii=r.mii, routing_pes=r.n_routing_pes,
            wall_s=round(r.wall_s, 3),
            combos_certified=len(r.certificates),
            cert_stages=sorted({c.stage for c in r.certificates}),
            cert_total_s=round(sum(cert_walls), 3),
            cert_max_s=round(max(cert_walls, default=0.0), 3))
        if not quick:
            r_seed = map_dfg(make_cnkm(n, m), CGRAConfig(), mode="busmap",
                             certify=False, bus_pressure=False)
            row["seed_wall_s"] = round(r_seed.wall_s, 3)
            row["speedup"] = round(r_seed.wall_s / max(r.wall_s, 1e-9), 2)
        print(f"straggler: {row}")
        rows.append(row)
    return rows


def bench_8x8(quick: bool = False) -> list[dict]:
    """End-to-end maps on an 8x8 PEA — out of reach for the dense path."""
    big = CGRAConfig(rows=8, cols=8)
    cases = [(3, 6, "bandmap"), (4, 8, "busmap")]
    if not quick:
        cases.append((5, 5, "bandmap"))
    rows = []
    for (n, m, mode) in cases:
        r = map_dfg(make_cnkm(n, m), big, mode=mode)
        rows.append(dict(kernel=cnkm_name(n, m), mode=mode, ok=r.ok,
                         ii=r.ii, mii=r.mii,
                         routing_pes=r.n_routing_pes, v_c=r.cg_size[0],
                         e_c=r.cg_size[1], wall_s=round(r.wall_s, 3)))
        print(f"cgra_8x8: {rows[-1]}")
    return rows


def bench_comap(quick: bool = False) -> list[dict]:
    """16x16-scale scenarios: the single |V_C| > 10^4 generated kernel
    (the engine's row-cache fallback regime) and multi-kernel co-mapping
    with the merged binding replayed through the global validator."""
    from repro.comap import co_map
    from repro.core import COMAP_16X16_SPECS, scale_16x16_loop

    big = CGRAConfig(rows=16, cols=16)
    kw = dict(max_bus_fanout=4, mis_restarts=4, mis_iters=4000)
    rows = []

    r = map_dfg(scale_16x16_loop(), big, max_ii=8, **kw)
    rows.append(dict(kernel="loop40", mode="map16x16", ok=r.ok, ii=r.ii,
                     mii=r.mii, v_c=r.cg_size[0], e_c=r.cg_size[1],
                     wall_s=round(r.wall_s, 3)))
    print(f"comap: {rows[-1]}")

    k1, k2, st = (spec.build() for spec in COMAP_16X16_SPECS)
    cm = co_map([k1, k2], big, max_ii=10, **kw)
    rows.append(dict(kernel="loop2", mode="comap16x16", ok=cm.ok,
                     ii=cm.ii, rounds=cm.attempts,
                     valid=bool(cm.report and cm.report.ok),
                     wall_s=round(cm.wall_s, 3)))
    print(f"comap: {rows[-1]}")

    if not quick:
        cm3 = co_map([k1, k2, st], big, max_ii=10, **kw)
        rows.append(dict(kernel="loop2stencil", mode="comap16x16",
                         ok=cm3.ok, ii=cm3.ii, rounds=cm3.attempts,
                         valid=bool(cm3.report and cm3.report.ok),
                         wall_s=round(cm3.wall_s, 3)))
        print(f"comap: {rows[-1]}")
    return rows


def bench_group_move(quick: bool = False) -> list[dict]:
    """Tightly-coupled family (8 VIOs x 8 consumers on an 8x8 PEA,
    consumer slot exactly packed): the cold-started (1,1)-swap
    portfolio stalls at ~90 % coverage, the group-move kick completes.
    Engine rows report coverage at iteration checkpoints under one
    budget; map rows run `map_dfg` end to end at pinned II=2 with the
    flag off/on (certificates off so the portfolio does the work)."""
    from repro.core import GroupMoveConfig, make_tightly_coupled
    from repro.core.conflict import build_conflict_graph
    from repro.core.mis import PortfolioSBTS

    big = CGRAConfig(rows=8, cols=8)
    dfg = make_tightly_coupled(8, 8, 2, link_run=6, seed=0)
    sched = schedule_dfg(dfg, big, ii=2, max_ii=2)
    cg = build_conflict_graph(sched, big, bus_pressure=True)
    n_ops = len(sched.dfg.ops)
    op_of = cg.op_of
    checkpoints = [500, 1000, 2000, 3000]
    n_seeds = 1 if quick else 3
    rows = []
    for mode, gm in (("engine_off", None),
                     ("engine_on", GroupMoveConfig())):
        t0 = time.perf_counter()
        covs = {c: 0 for c in checkpoints}
        iters_used = []
        for seed in range(n_seeds):
            sbts = PortfolioSBTS(cg.bits, [None] * 8, seed=seed,
                                 op_of=op_of, group_move=gm)
            for c in checkpoints:
                if not (sbts.best_size >= n_ops).any():
                    sbts.run(c - sbts.it, target=n_ops)
                covs[c] = max(covs[c], int(sbts.best_size.max()))
            iters_used.append(sbts.it)
        rows.append(dict(
            kernel="tight8x8", mode=mode, n_ops=n_ops, v_c=cg.n,
            coverage={str(c): covs[c] for c in checkpoints},
            iters=iters_used, wall_s=round(time.perf_counter() - t0, 3)))
        print(f"group_move: {rows[-1]}")
    for mode, flag in (("map_off", False), ("map_on", True)):
        t0 = time.perf_counter()
        r = map_dfg(dfg, big, certify=False, mis_restarts=4,
                    mis_iters=2500, min_ii=2, max_ii=2, seed=0,
                    group_move=flag)
        rows.append(dict(
            kernel="tight8x8", mode=mode, ok=r.ok, ii=r.ii,
            coverage=f"{r.mis_size}/{r.n_ops}",
            wall_s=round(time.perf_counter() - t0, 3)))
        print(f"group_move: {rows[-1]}")
    return rows


def bench_serve(quick: bool = False) -> list[dict]:
    """Zipf request trace, cacheless vs cached serving (see module
    docstring).  Both sides consume the *same* trace instances — each a
    freshly permuted DFG, so the cached side's hits come only from
    canonical (isomorphism-invariant) hashing.  The cacheless side is
    serial `map_dfg` per request — exactly what a client without the
    serving layer would run."""
    from repro.core import make_request_trace
    from repro.serve import MappingService, MapRequest

    n = 40 if quick else 200
    cgra = CGRAConfig(rows=8, cols=8)
    # Bounded per-request search budgets, like the co-mapper's region
    # runs: a serving deployment trades a notch of II optimality on the
    # hardest kernels for a bounded per-miss latency.  Both sides get
    # the same options.
    opts = dict(mis_restarts=4, mis_iters=4000)
    rows = []

    trace = make_request_trace(n, scale="8x8", seed=0)
    t0 = time.perf_counter()
    n_ok = sum(map_dfg(t.dfg, cgra, seed=i, **opts).ok
               for i, t in enumerate(trace))
    cold_wall = time.perf_counter() - t0
    rows.append(dict(
        kernel=f"zipf{n}", mode="serve_cacheless", ok=n_ok == n,
        requests=n, rps=round(n / cold_wall, 2),
        wall_s=round(cold_wall, 3)))
    print(f"serve: {rows[-1]}")

    # Min of ``reps`` cold-cache runs, like engine_speedup: the serve
    # side is an order of magnitude shorter than the cacheless side, so
    # scheduler noise on this box distorts its ratio far more.
    warm_wall, outs, m = 1e9, None, None
    for _ in range(1 if quick else 2):
        svc = MappingService()      # worker pool sized to the machine
        trace = make_request_trace(n, scale="8x8", seed=0)
        t0 = time.perf_counter()
        rep_outs = svc.map_batch([
            MapRequest(dfg=t.dfg, cgra=cgra, options=dict(opts),
                       deadline=t.deadline, req_id=f"r{i}")
            for i, t in enumerate(trace)])
        rep_wall = time.perf_counter() - t0
        if rep_wall < warm_wall:
            warm_wall, outs, m = rep_wall, rep_outs, svc.metrics()
    rows.append(dict(
        kernel=f"zipf{n}", mode="serve_cached",
        ok=all(o.ok for o in outs), requests=n,
        rps=round(n / warm_wall, 2), hit_rate=m["hit_rate"],
        p50_ms=m["p50_ms"], p95_ms=m["p95_ms"],
        replay_rejects=m["cache"]["replay_rejects"],
        speedup=round(cold_wall / warm_wall, 2),
        wall_s=round(warm_wall, 3)))
    print(f"serve: {rows[-1]}")
    return rows


def _device_graph(dfg, cgra, mode: str = "busmap", min_ii: int = 1):
    """Conflict graph at the first schedulable (II, jitter=0) from
    max(MII, min_ii) — the same fixed-point the differential tests
    bench against, so coverage numbers are comparable across runs."""
    from repro.core.schedule import mii
    start = max(mii(dfg, cgra), min_ii)
    for ii in range(start, start + 8):
        try:
            sched = schedule_dfg(dfg, cgra, ii=ii, max_ii=ii, mode=mode,
                                 jitter=0, seed=0)
        except RuntimeError:
            continue
        return build_conflict_graph(sched, cgra), len(sched.dfg.ops)
    raise RuntimeError("no schedulable II found")


def bench_device_engine(quick: bool = False) -> list[dict]:
    """Device engine vs numpy oracle at an equal lock-step budget (see
    module docstring).  Walls include engine construction and the
    one-off jit trace — the real per-deployment cost at these sizes.
    The numpy side runs its deployment-realistic seed count (8); the
    device side sweeps K, where extra trajectories cost only lane
    width.  Interpret mode on CPU is the CI-validated path; walls here
    bound the worst case, not accelerator throughput."""
    from repro.core.mis import PortfolioSBTS
    from repro.core.mis_device import DeviceSBTS
    from repro.obs import Tracer

    iters = 48
    rows = []
    big = CGRAConfig(rows=8, cols=8)
    cg, n_ops = _device_graph(make_cnkm(4, 8), big)
    t0 = time.perf_counter()
    tr = Tracer()
    ref = PortfolioSBTS(cg.bits, [None] * 8, seed=0)
    ref.run(iters, target=n_ops, tracer=tr)
    rows.append(dict(
        kernel="C4K8@8x8", mode="numpy_k8", v_c=cg.n, k=8, iters=iters,
        coverage=f"{int(ref.best_size.max())}/{n_ops}",
        counters=dict(portfolio_iters=int(
            tr.counter_value("portfolio.iters"))),
        wall_s=round(time.perf_counter() - t0, 3)))
    print(f"device_engine: {rows[-1]}")
    for k in (32, 256) if quick else (32, 256, 1024):
        t0 = time.perf_counter()
        tr = Tracer()
        dev = DeviceSBTS(cg.bits, k=k, seed=0)
        dev.run(iters, target=n_ops, tracer=tr)
        rows.append(dict(
            kernel="C4K8@8x8", mode=f"device_k{k}", v_c=cg.n, k=k,
            iters=iters,
            coverage=f"{int(dev.best_size.max())}/{n_ops}",
            counters=dict(portfolio_iters=int(
                tr.counter_value("portfolio.iters"))),
            wall_s=round(time.perf_counter() - t0, 3)))
        print(f"device_engine: {rows[-1]}")
    if not quick:
        from repro.core import scale_16x16_loop
        huge = CGRAConfig(rows=16, cols=16)
        cg16, n16 = _device_graph(
            scale_16x16_loop(n_chains=4, chain_len=4), huge,
            mode="bandmap", min_ii=5)
        for mode, engine, k in (("numpy_k4", PortfolioSBTS, 4),
                                ("device_k64", DeviceSBTS, 64)):
            t0 = time.perf_counter()
            tr = Tracer()
            if engine is PortfolioSBTS:
                eng = PortfolioSBTS(cg16.bits, [None] * k, seed=0)
            else:
                eng = DeviceSBTS(cg16.bits, k=k, seed=0)
            eng.run(iters, target=n16, tracer=tr)
            rows.append(dict(
                kernel="loop16@16x16", mode=mode, v_c=cg16.n, k=k,
                iters=iters,
                coverage=f"{int(eng.best_size.max())}/{n16}",
                counters=dict(portfolio_iters=int(
                    tr.counter_value("portfolio.iters"))),
                wall_s=round(time.perf_counter() - t0, 3)))
            print(f"device_engine: {rows[-1]}")
    return rows


def bench_exact(quick: bool = False) -> list[dict]:
    """Exact prover and the race vs the portfolio, per paper kernel:
    wall times side by side, the portfolio's optimality gap against the
    proven-optimal II (``gap`` = portfolio II - exact II, 0 everywhere
    the engine's defaults are already optimal), and which side won the
    race.  The acceptance bar behind the differential suite: the prover
    decides every paper kernel (``optimal`` true on all rows)."""
    rows = []
    kernels = PAPER_KERNELS if not quick \
        else [k for k in PAPER_KERNELS if k not in [(2, 8), (5, 5)]]
    for (n, m) in kernels:
        for mode in ("bandmap", "busmap"):
            dfg = make_cnkm(n, m)
            po = map_dfg(dfg, CGRAConfig(), mode=mode)
            ex = map_dfg(dfg, CGRAConfig(), mode=mode, backend="exact")
            ra = map_dfg(dfg, CGRAConfig(), mode=mode, backend="race")
            rows.append(dict(
                kernel=cnkm_name(n, m), mode=mode, ok=ex.ok,
                ii=ex.ii, mii=ex.mii, optimal=ex.optimal,
                gap=(po.ii - ex.ii) if po.ok and ex.ok else None,
                portfolio_wall_s=round(po.wall_s, 3),
                exact_wall_s=round(ex.wall_s, 3),
                race_winner=ra.backend,
                race_wall_s=round(ra.wall_s, 3),
                wall_s=round(ex.wall_s + ra.wall_s, 3)))
            print(f"exact: {rows[-1]}")
    # 8x8-fabric characterization (ROADMAP exact-engine rung (c)): the
    # prover's candidate space is ops x 64 PEs, but the bigger fabric
    # *relaxes* contention — every paper kernel proves optimal at II=1
    # in tens of milliseconds, so the wall is dominated by conflict-
    # graph construction, not search.  Rows are keyed "CnKm@8x8" and
    # gated by check_regression like any other exact row.
    big = CGRAConfig(rows=8, cols=8)
    big_kernels = [(2, 6), (4, 8)] if quick \
        else [(2, 6), (3, 6), (4, 8), (5, 5)]
    for (n, m) in big_kernels:
        for mode in ("bandmap", "busmap"):
            dfg = make_cnkm(n, m)
            po = map_dfg(dfg, big, mode=mode)
            ex = map_dfg(dfg, big, mode=mode, backend="exact")
            ra = map_dfg(dfg, big, mode=mode, backend="race")
            rows.append(dict(
                kernel=f"{cnkm_name(n, m)}@8x8", mode=mode, ok=ex.ok,
                ii=ex.ii, mii=ex.mii, optimal=ex.optimal,
                gap=(po.ii - ex.ii) if po.ok and ex.ok else None,
                portfolio_wall_s=round(po.wall_s, 3),
                exact_wall_s=round(ex.wall_s, 3),
                race_winner=ra.backend,
                race_wall_s=round(ra.wall_s, 3),
                wall_s=round(ex.wall_s + ra.wall_s, 3)))
            print(f"exact: {rows[-1]}")
    return rows


def run_all(quick: bool = False) -> dict:
    bench = dict(
        engine_speedup=bench_engine_speedup(quick),
        kernel_table=bench_kernel_table(quick),
        straggler=bench_stragglers(quick),
        exact=bench_exact(quick),
        cgra_8x8=bench_8x8(quick),
        comap=bench_comap(quick),
        group_move=bench_group_move(quick),
        device_engine=bench_device_engine(quick),
        serve=bench_serve(quick),
    )
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, "bench_mis.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {path}")
    return bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run_all(quick=args.quick)


if __name__ == "__main__":
    main()
