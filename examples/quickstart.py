"""Quickstart: the paper in 60 seconds.

Maps one CNN kernel loop (C2K6) onto the 4x4 CGRA with BandMap and with
the BusMap baseline, prints the II / routing-PE comparison (the paper's
headline result), and shows the mapping placement.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import compare_modes, make_cnkm          # noqa: E402
from repro.core.cgra import CGRAConfig                   # noqa: E402

dfg = make_cnkm(2, 6)      # 2 input channels, 6 output channels: RD = 6
print(f"DFG: {dfg}  (each input reused by {dfg.rd(dfg.v_i[0])} MACs)\n")

results = compare_modes(dfg, CGRAConfig())
for mode, r in results.items():
    print(r.summary())

rb, ru = results["bandmap"], results["busmap"]
print(f"\nBandMap allocated {sum(rb.ports_per_vio.values())} input ports "
      f"(policy Q = ceil(RD/M) = ceil(6/4) = 2 per datum)")
print(f"BusMap used {ru.n_routing_pes} routing PEs instead -> "
      f"{(1 - rb.n_routing_pes / max(ru.n_routing_pes, 1)) * 100:.0f}% "
      f"routing-PE reduction at the same II={rb.ii}")

print("\nBandMap placement (op -> resource):")
for oid, v in sorted(rb.placement.items()):
    op = rb.sched.dfg.ops[oid]
    where = (f"IPORT{v.port}" if v.kind == "tin" else
             f"OPORT{v.port}" if v.kind == "tout" else f"PE{v.pe}")
    print(f"  {op.name:8s} t={rb.sched.time[oid]:2d} slot={v.m} {where}")
