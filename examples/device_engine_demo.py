"""Accelerator-resident portfolio quickstart: `MapOptions` with
``engine="device"``.

The device engine (`repro.core.mis_device.DeviceSBTS`) runs the SBTS
local search as ONE vmapped Pallas kernel step over K independent
trajectories in lock step — counter-based RNG (`jax.random.fold_in`
streams keyed on (seed, trajectory, iteration)), so runs are
bit-reproducible and resume-safe.  On CPU the kernel executes in
interpret mode (the CI-validated path); on a real accelerator the same
program scales K with lane width.  `map_dfg` keeps the harvest loop
(dedupe -> repair -> validate) on the host — only the MIS search moves
on-device — so golden (II, routing-PE) results are unchanged.

  PYTHONPATH=src python examples/device_engine_demo.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (MapOptions, PortfolioOptions,     # noqa: E402
                        make_cnkm, map_dfg)
from repro.core.cgra import CGRAConfig                    # noqa: E402
from repro.core.conflict import build_conflict_graph      # noqa: E402
from repro.core.mis_device import (DeviceSBTS,            # noqa: E402
                                   differential_vs_numpy)
from repro.core.schedule import schedule_dfg              # noqa: E402

cgra = CGRAConfig()
dfg = make_cnkm(2, 6)

# --- end to end: the consolidated options object selects the engine ---
opts = MapOptions(
    mode="bandmap",
    portfolio=PortfolioOptions(engine="device", device_seeds=64,
                               iters=4000))
t0 = time.perf_counter()
res = map_dfg(dfg, cgra, opts)
print(f"map_dfg(engine=device): {res.summary()}")
print(f"  II={res.ii} (MII={res.mii}), routing PEs={res.n_routing_pes}, "
      f"wall={time.perf_counter() - t0:.2f}s")

# The same mapping through the numpy engine — identical (II, routing):
base = map_dfg(dfg, cgra, opts.replace(engine="numpy"))
print(f"map_dfg(engine=numpy) : II={base.ii}, "
      f"routing PEs={base.n_routing_pes}")
assert (res.ii, res.n_routing_pes) == (base.ii, base.n_routing_pes)

# --- engine level: differential harness against the numpy oracle -----
sched = schedule_dfg(dfg, cgra, ii=res.ii, max_ii=res.ii)
cg = build_conflict_graph(sched, cgra)
diff = differential_vs_numpy(cg.bits, iters=256, k=4, seed=0,
                             target=len(sched.dfg.ops))
print(f"\ndifferential on |V_C|={diff['n']} (k={diff['k']}, "
      f"iters={diff['iters']}):")
print(f"  device coverage {diff['device_cov']} vs "
      f"numpy {diff['numpy_cov']} "
      f"(independent sets: device={diff['device_independent']}, "
      f"numpy={diff['numpy_independent']})")

# --- reproducibility: counter RNG makes resume bit-identical ---------
split = DeviceSBTS(cg.bits, k=8, seed=7)
whole = DeviceSBTS(cg.bits, k=8, seed=7)
split.run(32)
split.run(64)
whole.run(96)
same = (split.best == whole.best).all() and \
    (split.in_s == whole.in_s).all()
print(f"\nrun(32)+run(64) == run(96) bit-identical: {same}")
print(f"best coverage per seed: {sorted(split.best_size.tolist())}")
