"""Exact-vs-portfolio race quickstart.

Three runs of the same engine on the paper kernels:

1. the stochastic portfolio (the default `map_dfg` path),
2. the complete prover (`backend="exact"`) — proven-optimal II or a
   certified UNSAT,
3. the race (`backend="race"`) — both at once, first *sound* answer
   wins, the loser is cancelled mid-search through a CancelToken.

Plus the negative side: C5K5 BusMap capped below its proven-optimal
II, where the race returns a certificate-backed infeasibility proof —
the entry the serving cache stores to short-circuit every isomorphic
request.

  PYTHONPATH=src python examples/race_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (PAPER_KERNELS, CGRAConfig,  # noqa: E402
                        cnkm_name, make_cnkm, map_dfg)

cgra = CGRAConfig()

print(f"{'kernel':8s} {'portfolio':>12s} {'exact':>16s} {'race':>22s}")
for (n, m) in PAPER_KERNELS:
    dfg = make_cnkm(n, m)
    po = map_dfg(dfg, cgra, mode="busmap")
    ex = map_dfg(dfg, cgra, mode="busmap", backend="exact")
    ra = map_dfg(dfg, cgra, mode="busmap", backend="race")
    opt = "optimal" if ex.optimal else "best-effort"
    print(f"{cnkm_name(n, m):8s} "
          f"II={po.ii} {po.wall_s*1e3:6.1f}ms "
          f"II={ex.ii} ({opt}) {ex.wall_s*1e3:6.1f}ms "
          f"II={ra.ii} [{ra.backend}] {ra.wall_s*1e3:6.1f}ms")

print("\n-- certified infeasibility through the race --")
r = map_dfg(make_cnkm(5, 5), cgra, mode="busmap", max_ii=2,
            backend="race")
print(f"C5K5 busmap max_ii=2: ok={r.ok} "
      f"proved_infeasible={r.proved_infeasible} winner={r.backend} "
      f"certificates={len(r.certificates)} "
      f"({sorted({c.stage for c in r.certificates})})")
print("-> a serving cache stores this as a sound negative entry: every "
      "isomorphic request short-circuits without mapping.")
