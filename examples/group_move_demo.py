"""Group-move neighbourhood demo: mapping a tightly-coupled kernel.

The workload is 8 high-fan-out VIOs, each bus-feeding 8 consumers on an
8x8 PEA (the consumer slot is exactly packed), with two consumer lanes
chained across groups.  Bus delivery pins a whole group to its VIO's
row, so a cold-started swap search packs the computes with each group's
consumers scattered over rows — after which no single-vertex move can
place any VIO: the ~90 % coverage stall.  The portfolio's group-move
kick (`GroupMoveConfig`) ejects the whole blocking cluster and
re-places it atomically.

  PYTHONPATH=src python examples/group_move_demo.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (CGRAConfig, GroupMoveConfig,       # noqa: E402
                        make_tightly_coupled, map_dfg)

cgra = CGRAConfig(rows=8, cols=8)
dfg = make_tightly_coupled(n_vios=8, fanout=8, cross_links=2,
                           link_run=6, seed=0)
print(f"tightly-coupled kernel: {dfg}")

kw = dict(certify=False, mis_restarts=4, mis_iters=2500,
          min_ii=2, max_ii=2, seed=0)

t0 = time.perf_counter()
r_off = map_dfg(dfg, cgra, **kw)
t_off = time.perf_counter() - t0
print(f"\n(1,1)-swap portfolio : ok={r_off.ok}  coverage "
      f"{r_off.mis_size}/{r_off.n_ops}  ({t_off:.1f}s)")

t0 = time.perf_counter()
r_on = map_dfg(dfg, cgra, group_move=GroupMoveConfig(), **kw)
t_on = time.perf_counter() - t0
print(f"with group-move kick : ok={r_on.ok}  coverage "
      f"{r_on.mis_size}/{r_on.n_ops}  II={r_on.ii}  ({t_on:.1f}s)")

rows = {}
for oid, v in r_on.placement.items():
    if v.kind == "tin":
        rows[r_on.sched.dfg.ops[oid].name] = v.port
print(f"\nVIO -> row assignment of the valid binding: {rows}")
print("knobs: GroupMoveConfig(cadence=40, max_cluster=24, tenure=30)")
