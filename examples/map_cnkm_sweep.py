"""Paper-evaluation sweep: all seven CnKm kernels x {BandMap, BusMap} x
{no GRF, GRF=8}; prints the Fig.5-style table (II ratios) and the
routing-PE comparison (§IV-B).

  PYTHONPATH=src python examples/map_cnkm_sweep.py [--quick]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import PAPER_KERNELS, cnkm_name, make_cnkm, map_dfg  # noqa: E402
from repro.core.cgra import CGRAConfig                    # noqa: E402

quick = "--quick" in sys.argv
kw = dict(mis_restarts=4, mis_iters=8000, max_ii=8) if quick else {}

print(f"{'kernel':8s} {'grf':4s} {'MII':4s} "
      f"{'Band II':8s} {'Bus II':7s} {'Band rPE':9s} {'Bus rPE':8s}")
for grf in (0, 8):
    cgra = CGRAConfig(grf=grf)
    for n, m in PAPER_KERNELS:
        rb = map_dfg(make_cnkm(n, m), cgra, mode="bandmap", **kw)
        ru = map_dfg(make_cnkm(n, m), cgra, mode="busmap", **kw)
        print(f"{cnkm_name(n, m):8s} {grf:<4d} {rb.mii:<4d} "
              f"{rb.ii:<8d} {ru.ii:<7d} {rb.n_routing_pes:<9d} "
              f"{ru.n_routing_pes:<8d}")
