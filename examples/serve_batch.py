"""Batched serving example: wave-scheduled batched decode of a smoke-size
gemma3 across 8 requests (prefill + lockstep decode ticks).

  PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main                       # noqa: E402

if __name__ == "__main__":
    main(["--arch", "gemma3-4b", "--requests", "8", "--gen", "24",
          "--slots", "4", "--prompt-len", "12"])
