"""Mapping-as-a-service example: serve a Zipf-popularity batch of kernel
mapping requests through `repro.serve.MappingService` and report the
cache hit-rate and latency percentiles.

Every request is a freshly *permuted* DFG instance (random vertex
relabeling), so the hit-rate below is earned purely by the canonical
(isomorphism-invariant) hashing in `repro.serve.canon`; each hit is
replayed through the validator before release.  The warm wave replays
the same trace under fresh per-request permutations and hits on every
request.

  PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (CGRAConfig, make_request_trace,    # noqa: E402
                        permute_dfg)
from repro.serve import MappingService, MapRequest         # noqa: E402


def main(n_requests: int = 48, scale: str = "8x8"):
    rows = cols = int(scale.split("x")[0])
    cgra = CGRAConfig(rows=rows, cols=cols)
    svc = MappingService()          # worker pool sized to the machine

    for wave_no, wave in enumerate(("cold", "warm")):
        # Same trace both waves; each instance gets a wave-specific
        # relabeling so warm hits can only come from canonical hashing.
        trace = make_request_trace(n_requests, scale=scale, seed=0)
        t0 = time.time()
        outs = svc.map_batch([
            MapRequest(dfg=permute_dfg(t.dfg, seed=wave_no * 1000 + i),
                       cgra=cgra, deadline=t.deadline,
                       req_id=f"{wave}{i}")
            for i, t in enumerate(trace)])
        dt = time.time() - t0
        hits = sum(o.hit for o in outs)
        ok = sum(o.ok for o in outs)
        print(f"{wave} wave: {len(outs)} requests in {dt:.2f}s "
              f"({len(outs) / dt:.1f} req/s), {hits} cache hits, "
              f"{ok} mapped ok")

    m = svc.metrics()
    print(f"\n{svc.summary()}")
    print(f"cache hit-rate {m['hit_rate']:.0%}  "
          f"p50 {m['p50_ms']:.2f} ms  p95 {m['p95_ms']:.2f} ms")
    print(f"sources: {m['sources']}")
    return m


if __name__ == "__main__":
    main()
