"""Elastic re-mesh demonstration: lose 8 hosts (32 chips) from the
single-pod 16x16 mesh, compute the degraded mesh, re-plan sharding with
the SAME planner, and prove the train step still lowers + compiles on the
survivor mesh (the restore path is checkpoint/ckpt.py — mesh-agnostic).

  PYTHONPATH=src python examples/elastic_replan.py [arch]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

import sys                                                # noqa: E402
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                # noqa: E402
import jax.numpy as jnp                                   # noqa: E402

from repro.configs import SHAPES, get_config, input_specs  # noqa: E402
from repro.core import planner as planner_mod             # noqa: E402
from repro.launch import sharding as sh                   # noqa: E402
from repro.launch.dryrun import batch_axes_for_path, tree_shardings  # noqa: E402
from repro.models import model as M                       # noqa: E402
from repro.optim import AdamW                             # noqa: E402
from repro.runtime import plan_elastic_restart            # noqa: E402

arch = sys.argv[1] if len(sys.argv) > 1 else "glm4-9b"
cell = SHAPES["train_4k"]
cfg = get_config(arch)

old_shape = {"data": 16, "model": 16}
new_shape, new_batch, notes = plan_elastic_restart(
    cfg, "train", cell.seq_len, cell.global_batch, old_shape,
    n_failed_hosts=8, chips_per_host=4)
print(f"failure: 8 hosts (32 chips) lost")
for n in notes:
    print("  ", n)

from jax.sharding import AxisType                          # noqa: E402
mesh = jax.make_mesh(tuple(new_shape.values()), tuple(new_shape),
                     axis_types=(AxisType.Auto,) * len(new_shape))
plan = planner_mod.plan(cfg, "train", cell.seq_len, new_batch, mesh,
                        arch=arch, shape="train_4k")
rules = sh.Rules(plan.rules, mesh)
optimizer = AdamW()
param_specs = M.param_specs(cfg)
opt_specs = jax.eval_shape(optimizer.init, param_specs)
state_specs = (param_specs, opt_specs, jax.ShapeDtypeStruct((), jnp.int32))
state_shard = (sh.params_shardings(param_specs, rules),
               sh.params_shardings(opt_specs, rules),
               rules.sharding_for((), ()))
specs = input_specs(cfg, cell)
batch = {k: jax.ShapeDtypeStruct((new_batch,) + v.shape[1:], v.dtype)
         for k, v in specs["batch"].items()}
b_shard = tree_shardings(batch, batch_axes_for_path, rules)

step = M.make_train_step(cfg, optimizer)


def fn(state, b):
    with sh.use_rules(rules):
        return step(state, b)


with mesh:
    compiled = jax.jit(fn, in_shardings=(state_shard, b_shard),
                       out_shardings=(state_shard, None),
                       donate_argnums=(0,)).lower(state_specs,
                                                  batch).compile()
print(f"re-plan OK: {arch} train step compiles on degraded mesh "
      f"{dict(mesh.shape)} with global_batch={new_batch}")
print("restore path: checkpoint/ckpt.py load_checkpoint(..., shardings=) "
      "re-device_puts each leaf against the new mesh")
