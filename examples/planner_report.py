"""Beyond-paper example: the bandwidth-allocating planner applied to the
TPU mesh — per-arch transfer DFG, reuse degrees, and the multicast/relay
allocation (DESIGN.md §2 maps each column back to the CGRA concept).

  PYTHONPATH=src python examples/planner_report.py [arch] [shape]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config              # noqa: E402
from repro.core import planner as planner_mod             # noqa: E402


class Mesh:
    shape = {"pod": 2, "data": 16, "model": 16}


arch = sys.argv[1] if len(sys.argv) > 1 else "mixtral-8x7b"
shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
cell = SHAPES[shape]
cfg = get_config(arch)
for optimized in (False, True):
    plan = planner_mod.plan(cfg, cell.kind, cell.seq_len,
                            cell.global_batch, Mesh(), arch=arch,
                            shape=shape, optimized=optimized)
    print(("OPTIMIZED" if optimized else "BASELINE") + " " + "=" * 60)
    print(plan.summary())
    print(f"total predicted collective bytes/step: "
          f"{plan.collective_bytes / 2**30:.2f} GiB\n")
