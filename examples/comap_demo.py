"""Co-mapping quickstart: several kernels resident on one 16x16 PEA.

Generates two loop kernels with loop-carried accumulators (RecMII > 1)
and a stencil, partitions the array into rectangular regions, maps every
kernel at one common II, arbitrates the row/column buses the regions
share, and replays the merged binding through the global validator.

  PYTHONPATH=src python examples/comap_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.comap import co_map                            # noqa: E402
from repro.core import COMAP_16X16_SPECS, CGRAConfig      # noqa: E402

big = CGRAConfig(rows=16, cols=16)
kernels = {spec.name: spec.build() for spec in COMAP_16X16_SPECS}
for name, d in kernels.items():
    print(f"{name}: {d}  RecMII={d.rec_mii()}")

cm = co_map(list(kernels.values()), big, max_ii=10, max_bus_fanout=4,
            mis_restarts=4, mis_iters=4000)
print(f"\n{cm.summary()}\n")

for name, reg, res in zip(kernels, cm.regions, cm.results):
    print(f"{name:9s} region {reg}: II={res.ii} (MII={res.mii}), "
          f"routingPEs={res.n_routing_pes}, |V_C|={res.cg_size[0]}")

print(f"\ncommon II          : {cm.ii}")
print(f"co-mapping rounds  : {cm.attempts}")
print(f"merged validator ok: {cm.report.ok}")
print(f"merged ops placed  : {len(cm.placement)} "
      f"(LRF peak {cm.report.lrf_peak}, GRF peak {cm.report.grf_peak})")
print(f"wall               : {cm.wall_s:.2f}s")

# A few placements, translated to global coordinates:
print("\nsample of the merged binding (op -> global resource):")
for oid, v in list(sorted(cm.placement.items()))[:8]:
    op = cm.sched.dfg.ops[oid]
    where = (f"IPORT{v.port}" if v.kind == "tin" else
             f"OPORT{v.port}" if v.kind == "tout" else f"PE{v.pe}")
    print(f"  {op.name:10s} t={cm.sched.time[oid]:2d} slot={v.m} {where}")
