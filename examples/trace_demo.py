"""Tracing demo: map two kernels with a live `repro.obs.Tracer`, write
Perfetto-openable Chrome trace JSON under ``artifacts/trace/``, and
print the per-phase wall breakdown.

Two workloads, deliberately different phase profiles:

- **C5K5** (paper kernel, 4x4 fabric): certificate stages + the exact
  CSP fast path dominate — the portfolio barely runs.
- **tight 16x16** (`make_tightly_coupled` on a 16x16 PEA, group-move
  kick on): the portfolio harvest rounds dominate, and the coverage
  gauge shows the kick breaking the stall.

A third leg demos the rest of the observability surface: a
flight-recorded failure rendered as an explain report
(`MappingResult.explain()`), and a small serve batch's Prometheus
exposition + JSONL access log (`serve.MappingService`).

Open the written ``.trace.json`` files at https://ui.perfetto.dev (or
chrome://tracing) to see the span timelines.

  PYTHONPATH=src python examples/trace_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (CGRAConfig, cnkm_name, make_cnkm,  # noqa: E402
                        make_request_trace, make_tightly_coupled,
                        map_dfg)
from repro.obs import (FlightRecorder, Tracer,             # noqa: E402
                       write_chrome_trace)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "trace")


def _print_breakdown(name: str, tracer: Tracer) -> None:
    bd = tracer.phase_breakdown()
    total = sum(a["total_s"] for n, a in bd.items() if n == "map-dfg")
    print(f"\n{name}: phase breakdown "
          f"({len(tracer.finished)} spans, map-dfg {total * 1e3:.1f} ms)")
    print(f"  {'phase':<16} {'count':>6} {'total ms':>10} {'share':>7}")
    for phase, agg in bd.items():
        share = agg["total_s"] / total if total else 0.0
        print(f"  {phase:<16} {agg['count']:>6} "
              f"{agg['total_s'] * 1e3:>10.2f} {share:>6.1%}")
    counters = tracer.registry.snapshot()["counters"]
    if counters:
        print("  counters: " + ", ".join(
            f"{k}={int(v)}" for k, v in sorted(counters.items())))


def main() -> None:
    runs = []

    # Paper kernel on the default 4x4 fabric.
    tr = Tracer()
    r = map_dfg(make_cnkm(5, 5), CGRAConfig(), tracer=tr)
    print(f"{cnkm_name(5, 5)}: {r.summary()}")
    runs.append((cnkm_name(5, 5), "c5k5", tr))

    # Tightly-coupled workload on a 16x16 PEA: the portfolio (with the
    # group-move kick) does the heavy lifting, so the breakdown tilts
    # the other way.
    big = CGRAConfig(rows=16, cols=16)
    tight = make_tightly_coupled(8, 8, 2, link_run=4, seed=0)
    tr2 = Tracer()
    r2 = map_dfg(tight, big, certify=False, mis_restarts=4,
                 mis_iters=2500, min_ii=2, max_ii=2, group_move=True,
                 max_bus_fanout=4, seed=0, tracer=tr2)
    print(f"tight16x16: {r2.summary()}")
    runs.append(("tight16x16", "tight16x16", tr2))

    for name, slug, tracer in runs:
        path = write_chrome_trace(
            tracer, os.path.join(ART, f"{slug}.trace.json"),
            process_name=name)
        print(f"wrote {os.path.relpath(path)} "
              f"(open at https://ui.perfetto.dev)")
        _print_breakdown(name, tracer)

    explain_and_serve_demo()


def explain_and_serve_demo() -> None:
    """Explain report on a flight-recorded infeasibility proof, then a
    small serve batch's Prometheus + access-log exposition."""
    from repro.serve import MappingService, MapRequest

    print("\n--- explain report (proved-infeasible C2K8 BusMap) ---")
    rec = FlightRecorder()
    res = map_dfg(make_cnkm(2, 8), CGRAConfig(), mode="busmap",
                  max_ii=2, record=rec)
    print(res.explain().render())

    print("\n--- serve exposition (8-request Zipf batch) ---")
    svc = MappingService(shard="demo", trace_sample=0.25)
    trace = make_request_trace(8, scale="4x4", seed=3)
    svc.map_batch([MapRequest(dfg=t.dfg, cgra=CGRAConfig(),
                              deadline=t.deadline, req_id=f"r{i}")
                   for i, t in enumerate(trace)])
    print(svc.prometheus(), end="")
    print("access log (last 3 lines):")
    for entry in svc.access_log.tail(3):
        print(f"  {entry}")
    print(f"sampled traces: {len(svc.traces)} "
          f"(head-sampled at rate {svc.trace_sample})")


if __name__ == "__main__":
    main()
