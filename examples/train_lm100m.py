"""End-to-end driver: train a ~130M-param dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing and a simulated
mid-run host failure (recovery is exercised live).

  PYTHONPATH=src python examples/train_lm100m.py [--steps 300]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main                       # noqa: E402

if __name__ == "__main__":
    args = sys.argv[1:] or ["--steps", "300"]
    main(["--arch", "lm100m", "--batch", "4", "--seq", "256",
          "--ckpt-every", "100", "--inject-failure-at", "150",
          "--log-every", "20"] + args)
